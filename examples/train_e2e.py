"""End-to-end driver (assignment deliverable b): train a ~100M-param
model for a few hundred steps with LSM checkpointing, a simulated crash,
and an elastic resume.

The default runs smollm-135m's REDUCED config for CPU CI speed; pass
``--full-135m`` to train the real 135M-parameter architecture (slower,
still CPU-feasible: ~135M params, short sequences).

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--full-135m]
"""
import argparse
import tempfile

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-135m", action="store_true",
                    help="train the real 135M config instead of reduced")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    if args.full_135m:
        import dataclasses
        cfg = dataclasses.replace(get_config("smollm-135m"),
                                  dtype="float32", remat="none",
                                  microbatches=1)
    else:
        cfg = get_smoke("smollm-135m")
    mesh = make_host_mesh()
    ckpt = tempfile.mkdtemp(prefix="repro_e2e_")
    phase1 = args.steps * 2 // 3
    print(f"[e2e] phase 1: {phase1} steps of {cfg.name}")
    _, losses1, store = run_training(
        cfg, mesh, steps=phase1, global_batch=args.global_batch,
        seq_len=args.seq_len, ckpt_dir=ckpt, ckpt_every=25,
        log_every=25, learning_rate=1e-3)
    print(f"[e2e] simulated crash after step {phase1 - 1}; "
          f"store has {store.num_components()} components")

    print(f"[e2e] phase 2: resume for {args.steps - phase1} steps")
    _, losses2, _ = run_training(
        cfg, mesh, steps=args.steps - phase1,
        global_batch=args.global_batch, seq_len=args.seq_len,
        ckpt_dir=ckpt, ckpt_every=25, resume=True, log_every=25,
        learning_rate=1e-3)
    print(f"[e2e] loss: {losses1[0]:.3f} -> {losses1[-1]:.3f} "
          f"(crash) -> {losses2[-1]:.3f}")
    assert losses2[-1] < losses1[0]
    print("[e2e] OK")


if __name__ == "__main__":
    main()
