"""Batched serving example: decode a reduced model behind the paged-KV
pool, calibrating the admission rate with the paper's two-phase method.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import get_smoke
from repro.models import init_params
from repro.serving import BatchServer, ServerConfig, two_phase_admission


def main():
    cfg = get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServerConfig(batch_size=4, max_len=96, n_pages=96,
                        page_tokens=8, max_new_tokens=12)
    report = two_phase_admission(
        lambda: BatchServer(cfg, params, scfg),
        testing_steps=150, running_steps=300)
    print("two-phase admission calibration:")
    for k, v in report.items():
        print(f"  {k}: {v}")
    assert report["completed"] > 0
    print("OK")


if __name__ == "__main__":
    main()
