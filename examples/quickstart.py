"""Quickstart: train a reduced SmolLM on CPU through the full stack —
data pipeline -> pjit train step -> LSM delta checkpoints -> restore.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.train import run_training

def main():
    cfg = get_smoke("smollm-135m")
    mesh = make_host_mesh()
    ckpt = tempfile.mkdtemp(prefix="repro_quickstart_")
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} ckpt={ckpt}")
    metrics, losses, store = run_training(
        cfg, mesh, steps=40, global_batch=8, seq_len=64,
        ckpt_dir=ckpt, ckpt_every=16, log_every=5, learning_rate=1e-3)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoint components={store.num_components()} "
          f"(compactions={store.stats['compactions']})")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
