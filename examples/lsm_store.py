"""The LSM engine as a standalone key-value store: write a workload
through the greedy scheduler under an I/O budget, then query it —
Bloom probes and merges execute through the Pallas kernels
(interpret mode on CPU).  A second phase serves the same store behind
the wall-clock ``BackgroundDriver``: the pump thread holds the engine
lock around each quantum, and the foreground read/write path takes the
same lock (``with eng.lock():``) so serving traffic never races
background I/O.  A third phase serves the SAME workload through a
4-shard ``LSMFleet``: the batched router scatters keys across shards,
the ``FleetBackgroundDriver`` splits one global I/O budget via the fair
arbiter, and no external locking is needed — engines lock internally.
A final phase makes the store durable: writes (and tombstoned deletes)
go through a group-committed WAL, a snapshot is taken mid-workload, the
process is "killed" at a fault-injection crash point with a torn WAL
tail, and a fresh engine recovers — snapshot restore + budgeted replay
— to a state bit-identical to a reference fed the durable prefix.

    PYTHONPATH=src python examples/lsm_store.py
"""
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.checkpoint import EngineSnapshotStore
from repro.core.constraints import GlobalConstraint
from repro.core.engine import BackgroundDriver, LSMEngine
from repro.core.faults import (FaultInjector, SimulatedCrash, WorkloadLog,
                               apply_entries, apply_torn_tail,
                               assert_reads_equal)
from repro.core.fleet import FleetBackgroundDriver, LSMFleet
from repro.core.policies import TieringPolicy
from repro.core.scheduler import GreedyScheduler
from repro.core.wal import RecoverySession, WriteAheadLog


def main():
    rng = np.random.default_rng(0)
    eng = LSMEngine(TieringPolicy(3, 512, 8192), GreedyScheduler(),
                    GlobalConstraint(48), memtable_entries=512,
                    unique_keys=8192, merge_block=128)
    ref = {}
    stalls = 0
    keys = rng.integers(0, 8192, 10_000).astype(np.uint32)
    vals = rng.integers(0, 1 << 30, 10_000).astype(np.int32)
    # bulk admission: slice-at-a-time, pumping only when admission stalls
    done = 0
    while done < len(keys):
        chunk_k, chunk_v = keys[done:done + 512], vals[done:done + 512]
        n = eng.put_batch(chunk_k, chunk_v)
        ref.update(zip(chunk_k[:n].tolist(), chunk_v[:n].tolist()))
        done += n
        if n < len(chunk_k):
            stalls += 1
        eng.pump(512)                 # background I/O quantum
    eng.drain()
    qs = rng.choice(8192, 500, replace=False).astype(np.uint32)
    found, got = eng.get_batch(qs)    # one fused multi-table probe
    wrong = sum((int(got[i]) if found[i] else None) != ref.get(int(k))
                for i, k in enumerate(qs))
    print(f"writes={eng.stats['puts']} flushes={eng.stats['flushes']} "
          f"merges={eng.stats['merges']} components={eng.num_components()} "
          f"write-stall-retries={stalls}")
    print(f"point lookups: {len(qs)} queried, {wrong} wrong; "
          f"bloom skipped {eng.stats['bloom_skips']} component probes")
    sk, sv = eng.scan_range(1000, 1100)    # one k-way newest-wins merge
    want = {k: v for k, v in ref.items() if 1000 <= k < 1100}
    scan_ok = dict(zip(sk.tolist(), sv.tolist())) == want
    print(f"range scan [1000,1100): {len(sk)} keys, correct={scan_ok}")
    assert wrong == 0 and scan_ok

    # ---- serve the store behind the wall-clock background driver ----
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=8e6, quantum_s=0.002)
    drv.start()
    served_wrong = 0
    try:
        for k in rng.integers(0, 8192, 2000).astype(np.uint32):
            v = int(rng.integers(0, 1 << 30))
            with eng.lock():              # foreground vs pump thread
                if eng.put(int(k), v):
                    ref[int(k)] = v
        qs = rng.choice(8192, 200, replace=False).astype(np.uint32)
        with eng.lock():
            found, got = eng.get_batch(qs)
            sk, sv = eng.scan_range(4000, 4200)
        served_wrong = sum(
            (int(got[i]) if found[i] else None) != ref.get(int(k))
            for i, k in enumerate(qs))
        want = {k: v for k, v in ref.items() if 4000 <= k < 4200}
        served_wrong += dict(zip(sk.tolist(), sv.tolist())) != want
    finally:
        drv.stop()
    print(f"served phase: {served_wrong} wrong under concurrent pump")
    assert served_wrong == 0

    # ---- the same store as a key-partitioned fleet behind the router ----
    # Four shards, one global I/O budget split by the fair arbiter; the
    # router scatters each batch by hash(key) % 4 and serves shards on a
    # worker pool, so NO external locking is needed (engines lock
    # internally).
    fleet = LSMFleet(4, lambda s: LSMEngine(
        TieringPolicy(3, 512, 8192), GreedyScheduler(),
        GlobalConstraint(48), memtable_entries=512, unique_keys=8192,
        merge_block=128), arbiter="fair")
    fdrv = FleetBackgroundDriver(fleet, bandwidth_bytes_per_s=8e6,
                                 quantum_s=0.002)
    fdrv.start()
    fref = {}
    try:
        with fleet:
            # a stalled shard rejects only ITS sub-batch, so the
            # admitted set is not a prefix of the caller's batch:
            # retry by mask, keeping rejected keys ahead of the rest
            # (preserves per-key write order)
            pend = np.arange(len(keys))
            while len(pend):
                sel = pend[:512]
                mask = fleet.put_batch_admitted(keys[sel], vals[sel])
                ok = sel[mask]
                fref.update(zip(keys[ok].tolist(), vals[ok].tolist()))
                pend = np.concatenate([sel[~mask], pend[512:]])
                if not mask.all():  # stalled shard: the driver drains it
                    time.sleep(0.001)
            found, got = fleet.get_batch(qs)
            fleet_wrong = sum(
                (int(got[i]) if found[i] else None) != fref.get(int(k))
                for i, k in enumerate(qs))
            sk, sv = fleet.scan_range(4000, 4200)
            want = {k: v for k, v in fref.items() if 4000 <= k < 4200}
            fleet_wrong += dict(zip(sk.tolist(), sv.tolist())) != want
    finally:
        fdrv.stop()
    st = fleet.stats
    print(f"fleet phase (4 shards): {fleet_wrong} wrong, "
          f"{st['flushes']} flushes, {st['merges']} merges fleet-wide")
    assert fleet_wrong == 0

    # ---- kill -9 and recover: WAL + snapshot + fault injection ----
    # The WAL logs every admitted write/delete in order (group commit:
    # one fsync per 256 entries or per pump epoch, the sync charged to
    # the same I/O budget as flushes and merges).  A crash loses at
    # most the unsynced tail; recovery = restore the snapshot's tables,
    # then replay the WAL suffix under a budgeted session.
    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)
        faults = FaultInjector()
        mk = lambda w, f=None: LSMEngine(
            TieringPolicy(3, 512, 8192), GreedyScheduler(),
            GlobalConstraint(48), memtable_entries=512, unique_keys=8192,
            merge_block=128, wal=w, group_commit_entries=256, faults=f)
        eng = mk(WriteAheadLog(tmp / "wal"), faults)
        store = EngineSnapshotStore(tmp / "snap")
        log = WorkloadLog()           # admitted history, in order

        def feed(ks, vs=None):        # record exactly what was admitted
            done = 0
            try:
                while done < len(ks):
                    if vs is None:
                        n = eng.delete_batch(ks[done:])
                        log.record_deletes(ks[done:done + n])
                    else:
                        n = eng.put_batch(ks[done:], vs[done:])
                        log.record(ks[done:done + n], vs[done:done + n])
                    done += n
                    if done < len(ks):
                        eng.pump(512)
            except SimulatedCrash:    # unacked tail: WAL holds a prefix
                log.record(ks[done:], vs[done:]) if vs is not None \
                    else log.record_deletes(ks[done:])
                raise

        try:
            for r in range(12):
                feed(rng.integers(0, 8192, 400, dtype=np.uint32),
                     rng.integers(0, 1 << 30, 400, dtype=np.int32))
                feed(rng.integers(0, 8192, 80, dtype=np.uint32))  # deletes
                eng.pump(1024)
                if r == 5:
                    eng.snapshot(store)   # fsync + persist + truncate WAL
                if r == 8:
                    faults.arm("pre-flush")   # next flush never finishes
        except SimulatedCrash as e:
            print(f"durability phase: simulated crash at {e.point!r} "
                  f"after {log.n} admitted ops")
        apply_torn_tail(eng.wal, 0.5)     # half the unsynced tail survives

        eng2 = mk(WriteAheadLog(tmp / "wal"))
        sess = RecoverySession(eng2, store)
        epochs = sess.run(budget_per_epoch=2048)
        rec = eng2._lsn
        assert eng2.wal.synced_lsn <= rec <= log.n
        # a reference store fed exactly the recovered prefix must agree
        ref = mk(None)
        ks, vs = log.prefix(rec)
        apply_entries(ref, ks, vs)
        ref.drain()
        assert_reads_equal(eng2, ref, 8192)
        print(f"recovered {rec}/{log.n} ops in {epochs} budgeted epochs "
              f"(replayed {eng2.stats['replayed']} from WAL, "
              f"{eng2.live_entries()} keys live); reads match the "
              f"durable prefix")
        eng2.close()
    print("OK")


if __name__ == "__main__":
    main()
