"""The LSM engine as a standalone key-value store: write a workload
through the greedy scheduler under an I/O budget, then query it —
Bloom probes and merges execute through the Pallas kernels
(interpret mode on CPU).  A second phase serves the same store behind
the wall-clock ``BackgroundDriver``: the pump thread holds the engine
lock around each quantum, and the foreground read/write path takes the
same lock (``with eng.lock():``) so serving traffic never races
background I/O.  A third phase serves the SAME workload through a
4-shard ``LSMFleet``: the batched router scatters keys across shards,
the ``FleetBackgroundDriver`` splits one global I/O budget via the fair
arbiter, and no external locking is needed — engines lock internally.

    PYTHONPATH=src python examples/lsm_store.py
"""
import time

import numpy as np

from repro.core.constraints import GlobalConstraint
from repro.core.engine import BackgroundDriver, LSMEngine
from repro.core.fleet import FleetBackgroundDriver, LSMFleet
from repro.core.policies import TieringPolicy
from repro.core.scheduler import GreedyScheduler


def main():
    rng = np.random.default_rng(0)
    eng = LSMEngine(TieringPolicy(3, 512, 8192), GreedyScheduler(),
                    GlobalConstraint(48), memtable_entries=512,
                    unique_keys=8192, merge_block=128)
    ref = {}
    stalls = 0
    keys = rng.integers(0, 8192, 10_000).astype(np.uint32)
    vals = rng.integers(0, 1 << 30, 10_000).astype(np.int32)
    # bulk admission: slice-at-a-time, pumping only when admission stalls
    done = 0
    while done < len(keys):
        chunk_k, chunk_v = keys[done:done + 512], vals[done:done + 512]
        n = eng.put_batch(chunk_k, chunk_v)
        ref.update(zip(chunk_k[:n].tolist(), chunk_v[:n].tolist()))
        done += n
        if n < len(chunk_k):
            stalls += 1
        eng.pump(512)                 # background I/O quantum
    eng.drain()
    qs = rng.choice(8192, 500, replace=False).astype(np.uint32)
    found, got = eng.get_batch(qs)    # one fused multi-table probe
    wrong = sum((int(got[i]) if found[i] else None) != ref.get(int(k))
                for i, k in enumerate(qs))
    print(f"writes={eng.stats['puts']} flushes={eng.stats['flushes']} "
          f"merges={eng.stats['merges']} components={eng.num_components()} "
          f"write-stall-retries={stalls}")
    print(f"point lookups: {len(qs)} queried, {wrong} wrong; "
          f"bloom skipped {eng.stats['bloom_skips']} component probes")
    sk, sv = eng.scan_range(1000, 1100)    # one k-way newest-wins merge
    want = {k: v for k, v in ref.items() if 1000 <= k < 1100}
    scan_ok = dict(zip(sk.tolist(), sv.tolist())) == want
    print(f"range scan [1000,1100): {len(sk)} keys, correct={scan_ok}")
    assert wrong == 0 and scan_ok

    # ---- serve the store behind the wall-clock background driver ----
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=8e6, quantum_s=0.002)
    drv.start()
    served_wrong = 0
    try:
        for k in rng.integers(0, 8192, 2000).astype(np.uint32):
            v = int(rng.integers(0, 1 << 30))
            with eng.lock():              # foreground vs pump thread
                if eng.put(int(k), v):
                    ref[int(k)] = v
        qs = rng.choice(8192, 200, replace=False).astype(np.uint32)
        with eng.lock():
            found, got = eng.get_batch(qs)
            sk, sv = eng.scan_range(4000, 4200)
        served_wrong = sum(
            (int(got[i]) if found[i] else None) != ref.get(int(k))
            for i, k in enumerate(qs))
        want = {k: v for k, v in ref.items() if 4000 <= k < 4200}
        served_wrong += dict(zip(sk.tolist(), sv.tolist())) != want
    finally:
        drv.stop()
    print(f"served phase: {served_wrong} wrong under concurrent pump")
    assert served_wrong == 0

    # ---- the same store as a key-partitioned fleet behind the router ----
    # Four shards, one global I/O budget split by the fair arbiter; the
    # router scatters each batch by hash(key) % 4 and serves shards on a
    # worker pool, so NO external locking is needed (engines lock
    # internally).
    fleet = LSMFleet(4, lambda s: LSMEngine(
        TieringPolicy(3, 512, 8192), GreedyScheduler(),
        GlobalConstraint(48), memtable_entries=512, unique_keys=8192,
        merge_block=128), arbiter="fair")
    fdrv = FleetBackgroundDriver(fleet, bandwidth_bytes_per_s=8e6,
                                 quantum_s=0.002)
    fdrv.start()
    fref = {}
    try:
        with fleet:
            # a stalled shard rejects only ITS sub-batch, so the
            # admitted set is not a prefix of the caller's batch:
            # retry by mask, keeping rejected keys ahead of the rest
            # (preserves per-key write order)
            pend = np.arange(len(keys))
            while len(pend):
                sel = pend[:512]
                mask = fleet.put_batch_admitted(keys[sel], vals[sel])
                ok = sel[mask]
                fref.update(zip(keys[ok].tolist(), vals[ok].tolist()))
                pend = np.concatenate([sel[~mask], pend[512:]])
                if not mask.all():  # stalled shard: the driver drains it
                    time.sleep(0.001)
            found, got = fleet.get_batch(qs)
            fleet_wrong = sum(
                (int(got[i]) if found[i] else None) != fref.get(int(k))
                for i, k in enumerate(qs))
            sk, sv = fleet.scan_range(4000, 4200)
            want = {k: v for k, v in fref.items() if 4000 <= k < 4200}
            fleet_wrong += dict(zip(sk.tolist(), sv.tolist())) != want
    finally:
        fdrv.stop()
    st = fleet.stats
    print(f"fleet phase (4 shards): {fleet_wrong} wrong, "
          f"{st['flushes']} flushes, {st['merges']} merges fleet-wide")
    assert fleet_wrong == 0
    print("OK")


if __name__ == "__main__":
    main()
