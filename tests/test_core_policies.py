"""Unit tests for merge policies, constraints and schedulers."""
import pytest

from repro.core import (Component, GlobalConstraint, L0Constraint, LSMTree,
                        LevelingPolicy, LocalConstraint, MergeOp,
                        PartitionedLevelingPolicy, SizeTieredPolicy,
                        TieringPolicy, FairScheduler, GreedyScheduler,
                        SingleThreadedScheduler)

M = 131072.0
U = 100e6


def make_tree():
    return LSMTree(unique_keys=U)


# ---------------------------------------------------------------- tiering
class TestTiering:
    def test_no_merge_below_threshold(self):
        pol = TieringPolicy(3, M, U)
        tree = make_tree()
        tree.add(Component(size=M, level=0))
        tree.add(Component(size=M, level=0))
        assert pol.collect_merges(tree, 0.0) == []

    def test_merge_at_threshold_takes_oldest_T(self):
        pol = TieringPolicy(3, M, U)
        tree = make_tree()
        for i in range(4):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        ops = pol.collect_merges(tree, 4.0)
        assert len(ops) == 1
        op = ops[0]
        assert len(op.inputs) == 3
        assert op.output_level == 1
        assert [c.created_at for c in op.inputs] == [0.0, 1.0, 2.0]

    def test_one_merge_per_level(self):
        pol = TieringPolicy(2, M, U)
        tree = make_tree()
        for i in range(4):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        ops = pol.collect_merges(tree, 0.0)
        assert len(ops) == 1  # second pair must wait (S 5.1.3)

    def test_multi_level_concurrent(self):
        pol = TieringPolicy(2, M, U)
        tree = make_tree()
        for i in range(2):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        for i in range(2):
            tree.add(Component(size=2 * M, level=1, created_at=float(i)))
        ops = pol.collect_merges(tree, 0.0)
        assert len(ops) == 2
        assert {op.output_level for op in ops} == {1, 2}

    def test_complete_merge_replaces_inputs(self):
        pol = TieringPolicy(2, M, U)
        tree = make_tree()
        tree.add(Component(size=M, level=0))
        tree.add(Component(size=M, level=0))
        (op,) = pol.collect_merges(tree, 0.0)
        outs = pol.complete_merge(tree, op, 1.0)
        assert tree.num_at(0) == 0
        assert tree.num_at(1) == 1
        assert outs[0].size == pytest.approx(op.output_size)
        assert outs[0].size <= 2 * M  # dedup can only shrink


# --------------------------------------------------------------- leveling
class TestLeveling:
    def test_l0_merges_into_l1(self):
        pol = LevelingPolicy(10, M, U)
        tree = make_tree()
        tree.add(Component(size=M, level=0))
        tree.add(Component(size=5 * M, level=1))
        ops = pol.collect_merges(tree, 0.0)
        assert len(ops) == 1
        assert ops[0].output_level == 1
        assert len(ops[0].inputs) == 2

    def test_full_level_promotes(self):
        pol = LevelingPolicy(10, M, U)
        tree = make_tree()
        tree.add(Component(size=pol.capacity(1), level=1))
        tree.add(Component(size=3 * M, level=2))
        ops = pol.collect_merges(tree, 0.0)
        assert any(op.output_level == 2 for op in ops)

    def test_dynamic_level_size_caps(self):
        pol = LevelingPolicy(10, M, U, dynamic_level_size=True)
        assert pol.capacity(pol.L) == pytest.approx(U)
        assert pol.capacity(pol.L - 1) == pytest.approx(U / 10)

    def test_merge_time_variance_structural(self):
        # the paper's variance source: level-i component size varies in
        # [0, (T-1) * M * T^(i-1)]
        pol = LevelingPolicy(10, M, U)
        assert pol.capacity(1) == pytest.approx(M * 10)


# ------------------------------------------------------------ size-tiered
class TestSizeTiered:
    def figure18_sizes(self):
        gb = 1024 * 1024.0  # entries per GB at 1KB
        return [100 * gb, 10 * gb, 5 * gb, 5 * gb, 5 * gb, 1 * gb,
                0.125 * gb, 0.0625 * gb, 0.0625 * gb]

    def test_figure18_example(self):
        """The Figure 18 walk-through: first merge = 4 components starting
        at the 10GB one; second = 3 components starting at 128MB."""
        pol = SizeTieredPolicy(1.2, M, U, min_merge=2, max_merge=4)
        tree = make_tree()
        for i, s in enumerate(self.figure18_sizes()):
            tree.add(Component(size=s, level=0, created_at=float(i)))
        ops = pol.collect_merges(tree, 10.0)
        assert len(ops) >= 1
        first = ops[0]
        sizes = sorted(c.size for c in first.inputs)
        gb = 1024 * 1024.0
        assert len(first.inputs) == 4
        assert max(sizes) == pytest.approx(10 * gb)
        second = ops[1]
        assert len(second.inputs) == 3
        assert max(c.size for c in second.inputs) == pytest.approx(0.125 * gb)

    def test_force_min_merges_exactly_min(self):
        pol = SizeTieredPolicy(1.2, M, U, min_merge=2, max_merge=10,
                               force_min=True)
        tree = make_tree()
        for i in range(6):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        ops = pol.collect_merges(tree, 0.0)
        assert all(len(op.inputs) == 2 for op in ops)

    def test_output_keeps_age_position(self):
        pol = SizeTieredPolicy(1.2, M, U)
        tree = make_tree()
        comps = [Component(size=M, level=0, created_at=float(i)) for i in range(4)]
        for c in comps:
            tree.add(c)
        (op, *_) = pol.collect_merges(tree, 5.0)
        out = pol.complete_merge(tree, op, 6.0)[0]
        seq = tree.level(0)
        assert seq.index(out) == 0  # output replaces the oldest inputs


# ------------------------------------------------------------- partitioned
class TestPartitionedLeveling:
    def make_policy(self, **kw):
        return PartitionedLevelingPolicy(10, M, U, **kw)

    def test_l0_merge_includes_all_l1(self):
        pol = self.make_policy()
        tree = make_tree()
        for i in range(4):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        for k in range(4):
            tree.add(Component(size=65536, level=1, key_lo=k * 0.25,
                               key_hi=(k + 1) * 0.25))
        ops = pol.collect_merges(tree, 0.0)
        assert len(ops) == 1
        assert len(ops[0].inputs) == 8
        assert ops[0].output_level == 1

    def test_l0_exact_min_under_fix(self):
        pol = self.make_policy(l0_merge_all=False)
        tree = make_tree()
        for i in range(9):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        ops = pol.collect_merges(tree, 0.0)
        l0_inputs = [c for c in ops[0].inputs if c.level == 0]
        assert len(l0_inputs) == 4  # exactly l0_min_merge (the paper's fix)

    def test_output_files_bounded(self):
        pol = self.make_policy()
        tree = make_tree()
        for i in range(4):
            tree.add(Component(size=M, level=0, created_at=float(i)))
        (op,) = pol.collect_merges(tree, 0.0)
        outs = pol.complete_merge(tree, op, 1.0)
        assert all(o.size <= pol.file_entries + 1 for o in outs)
        assert all(o.level == 1 for o in outs)
        los = [o.key_lo for o in outs]
        assert los == sorted(los)

    def test_choose_best_picks_fewest_overlaps(self):
        pol = self.make_policy(selection="choose_best", l1_capacity=131072.0)
        tree = make_tree()
        # L1 over capacity -> eligible. file A overlaps 2 L2 files, B overlaps 1
        a = Component(size=131072, level=1, key_lo=0.0, key_hi=0.5)
        b = Component(size=131072, level=1, key_lo=0.5, key_hi=1.0)
        tree.add(a)
        tree.add(b)
        tree.add(Component(size=65536, level=2, key_lo=0.0, key_hi=0.25))
        tree.add(Component(size=65536, level=2, key_lo=0.25, key_hi=0.5))
        tree.add(Component(size=65536, level=2, key_lo=0.5, key_hi=1.0))
        ops = pol.collect_merges(tree, 0.0)
        assert ops, "level over capacity must schedule a merge"
        assert b in ops[0].inputs

    def test_round_robin_cycles(self):
        pol = self.make_policy(selection="round_robin", l1_capacity=131072.0)
        tree = make_tree()
        a = Component(size=131072, level=1, key_lo=0.0, key_hi=0.5)
        b = Component(size=131072, level=1, key_lo=0.5, key_hi=1.0)
        tree.add(a)
        tree.add(b)
        f1 = pol._pick_file(tree, 1)
        f2 = pol._pick_file(tree, 1)
        f3 = pol._pick_file(tree, 1)
        assert (f1, f2) == (a, b) and f3 is a


# -------------------------------------------------------------- constraints
class TestConstraints:
    def test_global(self):
        tree = make_tree()
        for _ in range(3):
            tree.add(Component(size=M, level=0))
        assert not GlobalConstraint(3).violated(tree)
        assert GlobalConstraint(2).violated(tree)

    def test_local(self):
        tree = make_tree()
        tree.add(Component(size=M, level=0))
        tree.add(Component(size=M, level=0))
        tree.add(Component(size=M, level=1))
        assert not LocalConstraint(2).violated(tree)
        tree.add(Component(size=M, level=0))
        assert LocalConstraint(2).violated(tree)

    def test_local_exempts_partitioned_levels(self):
        tree = make_tree()
        for k in range(8):
            tree.add(Component(size=M, level=1, key_lo=k / 8, key_hi=(k + 1) / 8))
        assert not LocalConstraint(2).violated(tree)

    def test_l0(self):
        tree = make_tree()
        for _ in range(11):
            tree.add(Component(size=M, level=0))
        assert not L0Constraint(12).violated(tree)
        tree.add(Component(size=M, level=0))
        assert L0Constraint(12).violated(tree)


# --------------------------------------------------------------- schedulers
def ops_with_remaining(rem):
    out = []
    for r in rem:
        c = Component(size=r, level=0)
        out.append(MergeOp(inputs=[c], output_level=1, output_size=r))
    return out


class TestSchedulers:
    def test_fair_even_split(self):
        ops = ops_with_remaining([10, 20, 30])
        alloc = FairScheduler().allocate(ops)
        assert all(abs(v - 1 / 3) < 1e-12 for v in alloc.values())

    def test_greedy_smallest_first(self):
        ops = ops_with_remaining([30, 10, 20])
        alloc = GreedyScheduler().allocate(ops)
        assert alloc == {ops[1].op_id: 1.0}

    def test_greedy_k2(self):
        ops = ops_with_remaining([30, 10, 20])
        alloc = GreedyScheduler(k=2).allocate(ops)
        assert set(alloc) == {ops[1].op_id, ops[2].op_id}
        assert all(abs(v - 0.5) < 1e-12 for v in alloc.values())

    def test_single_threaded_no_preemption(self):
        s = SingleThreadedScheduler()
        ops = ops_with_remaining([30, 10])
        first = s.allocate(ops)
        assert first == {ops[0].op_id: 1.0}  # FIFO by creation
        ops2 = ops + ops_with_remaining([1])
        assert s.allocate(ops2) == {ops[0].op_id: 1.0}  # still the same op
        assert s.allocate(ops2[1:]) == {ops[1].op_id: 1.0}  # after completion
