"""Differential tests for the vectorized range-scan plane and the
scheduling-plane bugfixes that ride with it (ISSUE 3):

* ``scan_range`` (k-way newest-wins merge over the read view) must equal
  a brute-force dict replay of the write history — mid-merge and after
  drain, under tiering / leveling / partitioned policies, on BOTH merge
  backends (packed-sort host path and the Pallas tournament kernel).
* The partitioned-policy newest-wins inversion (stamp laundering through
  partial-overlap merges) is pinned by the original repro.
* ``pump`` apportions merge quanta by largest remainder: the allocated
  budget is spent in full and sub-1 fair shares no longer starve.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.component import MergeOp
from repro.core.constraints import GlobalConstraint
from repro.core.engine import LSMEngine, _RunningMerge
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import FairScheduler, GreedyScheduler


def _mk(policy: str, memtable=64, unique=1024, constraint=300,
        use_kernels=True, scan_use_kernels=None):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, unique),
        "leveling": lambda: LevelingPolicy(3, memtable, unique),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, unique, file_entries=32, l1_capacity=128),
    }[policy]()
    return LSMEngine(pol, GreedyScheduler(), GlobalConstraint(constraint),
                     memtable_entries=memtable, unique_keys=unique,
                     use_kernels=use_kernels, merge_block=64,
                     scan_use_kernels=scan_use_kernels)


def _scan_oracle(ref: dict, lo: int, hi: int):
    items = sorted((k, v) for k, v in ref.items() if lo <= k < hi)
    return (np.array([k for k, _ in items], np.uint32),
            np.array([v for _, v in items], np.int32))


def _assert_scan_equal(eng: LSMEngine, ref: dict, lo: int, hi: int, ctx):
    sk, sv = eng.scan_range(lo, hi)
    ok, ov = _scan_oracle(ref, lo, hi)
    np.testing.assert_array_equal(sk, ok, err_msg=str(ctx))
    np.testing.assert_array_equal(sv, ov, err_msg=str(ctx))


# ----------------------------------------------------------- scan plane
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("kernel_scan", [False, True])
def test_scan_range_equals_dict_replay(policy, kernel_scan):
    """Random workload with heavy key reuse, scanned MID-MERGE (memtables
    populated, merges in flight) and after drain: the k-way scan plane is
    byte-identical to the brute-force dict replay on both backends."""
    rng = np.random.default_rng(3)
    eng = _mk(policy, scan_use_kernels=kernel_scan)
    ref = {}
    for i in range(1500):
        k = int(rng.integers(0, 1024))
        v = int(rng.integers(0, 1 << 30))
        while not eng.put(k, v):
            eng.pump(192)
        ref[k] = v
        if i % 40 == 0:
            eng.pump(96)
        if i % 500 == 250:          # mid-stream: memtables + live merges
            lo = int(rng.integers(0, 900))
            _assert_scan_equal(eng, ref, lo, lo + 128,
                               (policy, kernel_scan, "mid", i))
    _assert_scan_equal(eng, ref, 0, 1024, (policy, kernel_scan, "pre-drain"))
    eng.drain()
    _assert_scan_equal(eng, ref, 0, 1024, (policy, kernel_scan, "drained"))
    _assert_scan_equal(eng, ref, 200, 300, (policy, kernel_scan, "window"))
    # empty + degenerate windows
    sk, sv = eng.scan_range(1024, 2048)
    assert len(sk) == 0 and len(sv) == 0
    sk, _ = eng.scan_range(5, 5)
    assert len(sk) == 0
    # full-key-space bounds clamp (hi = 2**32 overflows a raw uint32
    # cast; the sentinel key is never stored, so clamping is lossless)
    sk, sv = eng.scan_range(0, 1 << 32)
    ok, ov = _scan_oracle(ref, 0, 1 << 32)
    np.testing.assert_array_equal(sk, ok)
    np.testing.assert_array_equal(sv, ov)


def test_scan_range_memtable_only_and_single_run():
    """The 0-run and 1-run short-circuits: scans before any flush, and
    scans hitting exactly one run."""
    eng = _mk("tiering")
    assert len(eng.scan_range(0, 1024)[0]) == 0
    eng.put_batch(np.array([7, 3, 7], np.uint32),
                  np.array([1, 2, 9], np.int32))
    sk, sv = eng.scan_range(0, 1024)        # active memtable only
    assert sk.tolist() == [3, 7] and sv.tolist() == [2, 9]
    eng._seal_active()
    eng.pump(64)                            # one disk table, empty memtable
    sk, sv = eng.scan_range(0, 1024)
    assert sk.tolist() == [3, 7] and sv.tolist() == [2, 9]


def test_scan_dict_wrapper_matches_arrays():
    eng = _mk("leveling")
    rng = np.random.default_rng(0)
    ref = {}
    for k in rng.integers(0, 512, 700):
        v = int(rng.integers(0, 1 << 30))
        while not eng.put(int(k), v):
            eng.pump(128)
        ref[int(k)] = v
    sk, sv = eng.scan_range(100, 400)
    assert eng.scan_range_dict(100, 400) == dict(zip(sk.tolist(),
                                                     sv.tolist()))
    assert eng.scan_range_dict(100, 400) == \
        {k: v for k, v in ref.items() if 100 <= k < 400}


# ------------------------------------------- partitioned newest-wins fix
def test_partitioned_newest_wins_regression():
    """Regression (ISSUE 3 / ROADMAP PR 1 follow-up): partial-overlap
    merges at partitioned levels >= 1 stamped their output ``max`` over
    the inputs, laundering OLD deeper data above a shallower live file's
    stamp (and L0 picks ordered by ``created_at`` could skip an older
    tied run).  On the seed this exact workload returned stale values
    for several keys; the ``_age_safe`` audit + stamp-ordered L0 pick
    must keep every read fresh."""
    for seed in (5, 6):                     # seeds that reproduced on seed
        rng = np.random.default_rng(seed)
        eng = LSMEngine(
            PartitionedLevelingPolicy(4, 64, 2048, file_entries=32,
                                      l1_capacity=128),
            GreedyScheduler(), GlobalConstraint(400),
            memtable_entries=64, unique_keys=2048, use_kernels=False)
        ref = {}
        for i in range(4000):
            k = int(rng.integers(0, 2048))
            v = int(rng.integers(0, 1 << 30))
            while not eng.put(k, v):
                eng.pump(256)               # heavy pump
            ref[k] = v
            if i % 20 == 0:
                eng.pump(192)
        eng.drain()
        keys = np.fromiter(ref, dtype=np.uint32)
        found, vals = eng.get_batch(keys)
        assert found.all(), f"seed {seed}: lost keys"
        stale = [int(k) for k, f, v in zip(keys.tolist(), found,
                                           vals.tolist())
                 if v != ref[int(k)]]
        assert not stale, f"seed {seed}: stale reads {stale[:5]}"
        _assert_scan_equal(eng, ref, 0, 2048, ("partitioned", seed))


# ----------------------------------------------------- pump apportionment
def _fake_running_merges(eng: LSMEngine, n: int) -> dict[int, int]:
    """Install ``n`` fake running merges and record per-op quanta."""
    got: dict[int, int] = {}
    for _ in range(n):
        op = MergeOp(inputs=[], output_level=1, output_size=1e9,
                     output_ranges=[(0.0, 1.0)])
        eng.running[op.op_id] = _RunningMerge(op=op, inputs=[])
        got[op.op_id] = 0

    def advance(rm, quantum):
        got[rm.op.op_id] += quantum
        return quantum

    eng._advance_merge = advance
    return got


@pytest.mark.parametrize("n_ops,budget", [(3, 2), (3, 10), (4, 1),
                                          (7, 5), (2, 101)])
def test_pump_quanta_largest_remainder(n_ops, budget):
    """Fair shares must sum to the full budget (the seed's floor dropped
    every sub-1 share: pump(2) over 3 merges spent 0), and no op may
    exceed its ceiling share."""
    eng = _mk("tiering")
    eng.scheduler = FairScheduler()
    got = _fake_running_merges(eng, n_ops)
    spent = eng.pump(budget)
    assert spent == budget                  # nothing silently vanishes
    assert sum(got.values()) == budget
    assert max(got.values()) <= -(-budget // n_ops)   # ceil share
    assert min(got.values()) >= budget // n_ops


def test_pump_small_quanta_make_progress():
    """Integration: with TWO concurrent merges under the fair scheduler,
    pump(1) quanta starved forever on the seed (``int(1 * 0.5) == 0`` for
    both ops, so the budget vanished every pump); largest-remainder
    apportionment must complete them."""
    from repro.core.constraints import NoConstraint
    eng = LSMEngine(TieringPolicy(3, 32, 4096), FairScheduler(),
                    NoConstraint(), memtable_entries=32,
                    unique_keys=4096, use_kernels=False)
    base = 0

    def fill_and_flush():
        nonlocal base
        n = eng.put_batch(np.arange(base, base + 32, dtype=np.uint32),
                          np.full(32, 1, np.int32))
        assert n == 32
        base += 32
        eng._seal_active()
        eng.pump(32)                        # exactly the flush

    for _ in range(2):                      # two L0 rounds -> L1 x2
        for _ in range(3):
            fill_and_flush()
        eng.drain()
    for _ in range(3):                      # third round: L0 merge C
        fill_and_flush()
    eng.pump(288)                           # C completes -> L1 x3 -> D at L1
    assert len(eng.running) == 1            # D (L1 -> L2), zero progress
    for _ in range(3):                      # fresh L0 runs -> E at L0
        fill_and_flush()
    eng.pump(0)                             # collect E without advancing
    assert len(eng.running) == 2, "expected concurrent L0 + L1 merges"
    for _ in range(2000):                   # seed: no progress, ever
        eng.pump(1)
        if not eng.running:
            break
    assert not eng.running, "pump(1) quanta starved the fair merges"


def test_background_driver_shares_engine_lock():
    from repro.core.engine import BackgroundDriver
    eng = _mk("tiering")
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=1e6)
    assert drv._lock is eng.lock()
