"""Bounded-latency background plane (PR 5): streaming merge quanta +
incremental read-view maintenance.

What is pinned here:

* The streaming merge cursor's concatenated output is BIT-IDENTICAL to
  the one-shot k-way merge — for every merge the real policies generate
  ({tiering, leveling, partitioned} x {host, kernel} backends), and for
  a direct cursor unit drive under an adversarial quantum schedule.
* A single ``pump(q)`` touches O(q + k) merge entries and emits at most
  ``q`` — the bounded-lock-hold contract that makes the scheduler's
  quantum the actual knob (the one-shot path materialized the WHOLE
  merge at its first quantum).
* The read view is maintained incrementally: the insertion-maintained
  ``_order`` list always equals the full ``(-data_stamp, level)`` sort,
  the device filter stack reuses slots (one row write per flush, no
  restack), and scan-only workloads never build the filter stack at all.
* Regressions: constraint-induced write rejections count as
  ``stall_events`` (the seed only counted the memtable-full branch), and
  ``SSTable.build`` seeds host mirrors/bounds from its numpy inputs
  instead of round-tripping the device per flush.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.component import MergeOp
from repro.core.constraints import ComponentConstraint, NoConstraint
from repro.core.engine import LSMEngine, _RunningMerge
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import FairScheduler
from repro.core.sstable import SSTable


def _mk_engine(policy: str, use_kernels: bool, streaming: bool = True,
               memtable: int = 64, unique: int = 2048) -> LSMEngine:
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, unique),
        "leveling": lambda: LevelingPolicy(3, memtable, unique),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, unique, file_entries=64, l1_capacity=256),
    }[policy]()
    return LSMEngine(pol, FairScheduler(), NoConstraint(),
                     memtable_entries=memtable, unique_keys=unique,
                     use_kernels=use_kernels, merge_block=64,
                     streaming_merge=streaming)


def _oneshot_reference(eng: LSMEngine, inputs) -> tuple[np.ndarray,
                                                        np.ndarray]:
    """The one-shot k-way merge of ``inputs`` on the engine's backend."""
    tables = sorted(inputs, key=eng._order_key)
    if not any(len(t) for t in tables):
        return np.empty(0, np.uint32), np.empty(0, np.int32)
    if eng.use_kernels:
        from repro.kernels.merge.ops import merge_dedup_kway
        mk, mv = merge_dedup_kway([(t.keys, t.vals) for t in tables],
                                  block=eng.merge_block, interpret=True)
        return np.asarray(mk), np.asarray(mv)
    return LSMEngine._merge_kway_host(
        [t._host() for t in tables if len(t)])


# ------------------------------------------------- streaming differential
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["host", "kernel"])
def test_streaming_merge_bit_identical_under_policies(policy, use_kernels):
    """Every merge the policy schedules: the concatenation of the
    streaming cursor's per-quantum windows must equal the one-shot merge
    of the same inputs, bit for bit."""
    eng = _mk_engine(policy, use_kernels)
    orig_finish = eng._finish_merge
    checked = []

    def checking_finish(rm):
        got_k = rm.buf_keys[:rm.emitted] if rm.buf_keys is not None else \
            np.empty(0, np.uint32)
        got_v = rm.buf_vals[:rm.emitted] if rm.buf_vals is not None else \
            np.empty(0, np.int32)
        want_k, want_v = _oneshot_reference(eng, rm.inputs)
        assert np.array_equal(got_k, want_k), \
            (policy, use_kernels, len(got_k), len(want_k))
        assert np.array_equal(got_v, want_v)
        checked.append(len(got_k))
        orig_finish(rm)

    eng._finish_merge = checking_finish
    rng = np.random.default_rng(5)
    for i, (k, v) in enumerate(zip(rng.integers(0, 2048, 700),
                                   rng.integers(0, 1 << 30, 700))):
        while not eng.put(int(k), int(v)):
            eng.pump(53)            # odd quanta: windows never align
        if i % 17 == 0:
            eng.pump(29)
    eng.drain(budget_entries=97)
    assert checked, f"workload produced no merges under {policy}"


@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["host", "kernel"])
def test_streaming_cursor_unit_adversarial_quanta(use_kernels):
    """Direct cursor drive: heavily overlapping runs (every key present
    in every run — maximal dedup) under a quantum schedule mixing 1s with
    large steps; the streamed output must equal the one-shot merge and
    every advance must emit at most its quantum."""
    rng = np.random.default_rng(11)
    tables = []
    for i in range(4):
        keys = np.unique(rng.integers(0, 3000, 1500).astype(np.uint32))
        vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int32)
        t = SSTable.build(keys, vals, level=0)
        t.data_stamp = 10 - i
        t.component.stamp = float(10 - i)
        tables.append(t)

    eng = _mk_engine("tiering", use_kernels)
    op = MergeOp(inputs=[t.component for t in tables], output_level=1,
                 output_size=float(sum(len(t) for t in tables)))
    rm = _RunningMerge(op=op, inputs=tables)
    got = {}

    def fake_finish(r):
        got["k"] = r.buf_keys[:r.emitted]
        got["v"] = r.buf_vals[:r.emitted]

    eng._finish_merge = fake_finish
    quanta = [1, 2, 3, 257, 1, 5, 1000, 7, 1, 64]
    qi = 0
    while "k" not in got:
        q = quanta[qi % len(quanta)]
        qi += 1
        emitted = eng._advance_merge(rm, q)
        assert emitted <= q
        assert qi < 10_000, "cursor failed to make progress"
    want_k, want_v = _oneshot_reference(eng, tables)
    assert np.array_equal(got["k"], want_k)
    assert np.array_equal(got["v"], want_v)


def test_pump_touch_bound():
    """Bounded lock hold: a single ``pump(q)`` advancing a large k-way
    merge touches at most q + k merge entries on the host path (the
    one-shot baseline touches the ENTIRE merge at its first quantum) and
    emits at most q."""
    n, k = 4096, 4
    eng = LSMEngine(TieringPolicy(k, n, 1 << 20), FairScheduler(),
                    NoConstraint(), memtable_entries=n, num_memtables=2,
                    unique_keys=1 << 20, use_kernels=False)
    rng = np.random.default_rng(3)
    for i in range(k):
        keys = rng.choice(1 << 16, n, replace=False).astype(np.uint32)
        vals = rng.integers(0, 1 << 30, n).astype(np.int32)
        assert eng.put_batch(keys, vals) == n
        eng._seal_active()
        eng.pump(n)                       # flush exactly; merge collects
    assert eng.running, "expected a running k-way merge"
    for q in (1, 100, 257):
        before = eng.stats["merge_touched"]
        spent = eng.pump(q)
        assert spent <= q
        assert eng.stats["merge_touched"] - before <= q + k, \
            f"pump({q}) touched {eng.stats['merge_touched'] - before}"

    # the one-shot baseline materializes everything at the first quantum
    eng2 = LSMEngine(TieringPolicy(k, n, 1 << 20), FairScheduler(),
                     NoConstraint(), memtable_entries=n, num_memtables=2,
                     unique_keys=1 << 20, use_kernels=False,
                     streaming_merge=False)
    rng = np.random.default_rng(3)
    for i in range(k):
        keys = rng.choice(1 << 16, n, replace=False).astype(np.uint32)
        vals = rng.integers(0, 1 << 30, n).astype(np.int32)
        eng2.put_batch(keys, vals)
        eng2._seal_active()
        eng2.pump(n)
    eng2.pump(1)
    rm = next(iter(eng2.running.values()))
    assert rm.merged_keys is not None and len(rm.merged_keys) > n, \
        "baseline lost its one-shot materialization (benchmark invalid)"


# ------------------------------------------------- incremental read view
def test_order_list_matches_full_sort():
    """``_order`` (insertion-maintained) must always equal the full
    ``(-data_stamp, level)`` sort the seed recomputed per view."""
    for policy in ("tiering", "leveling", "partitioned"):
        eng = _mk_engine(policy, use_kernels=False)
        rng = np.random.default_rng(7)
        for i, k in enumerate(rng.integers(0, 2048, 900)):
            while not eng.put(int(k), i):
                eng.pump(41)
            if i % 11 == 0:
                eng.pump(23)
                want = sorted(
                    eng.tables.values(),
                    key=lambda t: (-t.data_stamp, t.component.level))
                got = [t.component.cid for t in eng._order]
                assert got == [t.component.cid for t in want], (policy, i)
        eng.drain()


def test_filter_stack_incremental_slot_reuse():
    """A flush adds ONE row to the persistent stack (no rebuild while
    capacity lasts); a merge frees its input slots for reuse; the stack's
    device buffer object survives row writes only via replacement."""
    eng = _mk_engine("tiering", use_kernels=False, memtable=32,
                     unique=1 << 16)
    rng = np.random.default_rng(1)

    def flush_one():
        keys = rng.choice(1 << 16, 32, replace=False).astype(np.uint32)
        assert eng.put_batch(keys, np.ones(32, np.int32)) == 32
        eng._seal_active()
        eng.pump(32)

    flush_one()
    eng.get_batch(np.arange(8, dtype=np.uint32))      # builds the stack
    fs = eng._fstack
    assert fs.filts is not None
    cap0 = fs.cap
    slots0 = dict(fs.slots)
    flush_one()
    eng.get_batch(np.arange(8, dtype=np.uint32))      # one-row reconcile
    assert fs.cap == cap0, "flush should not rebuild the stack"
    assert slots0.items() <= fs.slots.items(), \
        "existing tables must keep their slots"
    assert len(fs.slots) == len(slots0) + 1
    # drive merges: departed inputs must free rows for reuse
    for _ in range(8):
        flush_one()
    eng.drain()
    eng.get_batch(np.arange(8, dtype=np.uint32))
    live = {t.component.cid for t in eng._read_view().tables}
    assert set(fs.slots) == live, "stack holds slots for departed tables"
    assert len(fs.free) == fs.cap - len(live)


def test_filter_stack_is_lazy_for_scans():
    """Scan-only / write-only workloads never pay for filter
    maintenance: the stack stays unbuilt until the first point read."""
    eng = _mk_engine("tiering", use_kernels=False, memtable=32,
                     unique=1 << 16)
    rng = np.random.default_rng(2)
    for _ in range(5):
        keys = rng.choice(1 << 16, 32, replace=False).astype(np.uint32)
        eng.put_batch(keys, np.ones(32, np.int32))
        eng._seal_active()
        eng.pump(32)
    eng.scan_range(0, 1 << 16)
    eng.scan_range(100, 5000)
    assert eng._fstack.filts is None, "scans built the filter stack"
    assert eng._read_view().filts is None
    eng.get(int(keys[0]))                             # first point read
    assert eng._fstack.filts is not None


# ------------------------------------------------------- satellite fixes
class _AlwaysViolated(ComponentConstraint):
    def violated(self, tree) -> bool:
        return True


def test_constraint_rejections_count_as_stall_events():
    """Seed bug: ``put``/``put_batch`` bumped ``stall_events`` only on
    the memtable-full branch; a constraint-induced rejection (the paper's
    actual stall mechanism) was invisible to the stats."""
    eng = _mk_engine("tiering", use_kernels=False)
    eng.constraint = _AlwaysViolated()
    assert eng.put(1, 1) is False
    assert eng.stats["stall_events"] == 1
    assert eng.put_batch(np.arange(4, dtype=np.uint32),
                         np.ones(4, np.int32)) == 0
    assert eng.stats["stall_events"] == 2


def test_sstable_build_seeds_host_mirrors():
    """``build`` must take its bounds and mirrors from the numpy inputs
    the flush path already has — not from a device round-trip."""
    keys = np.array([10, 20, 4000], np.uint32)
    vals = np.array([1, 2, 3], np.int32)
    t = SSTable.build(keys, vals, level=1)
    assert t.keys_np is keys and t.vals_np is vals, \
        "mirrors must BE the numpy inputs (no copy, no device sync)"
    assert t.component.key_lo == pytest.approx(10 / 2**32)
    assert t.component.key_hi == pytest.approx(4001 / 2**32)
    # empty build keeps the documented [0, 1) whole-range default
    e = SSTable.build(np.empty(0, np.uint32), np.empty(0, np.int32))
    assert (e.component.key_lo, e.component.key_hi) == (0.0, 1.0)
