"""Unified execution-backend layer (PR 8): measured host/kernel dispatch
+ device-resident merge→flush→probe data plane.

What is pinned here:

* All execution modes — host packed-sort, interpret Pallas, compiled
  Pallas (skipped where the XLA backend cannot lower it) — produce
  BIT-IDENTICAL merge/probe/scan results, for every merge policy and for
  the streaming ``merge_kway_window`` path.
* Dispatch decisions come from the measured crossover table: nearest
  size class at or below, forced modes win, compiled verdicts degrade
  when unsupported, and a missing/corrupt calibration artifact falls
  back to the built-in default without failing construction.
* ``ExecBackend.from_legacy`` reproduces the three historical engine
  booleans bit-for-bit as forced per-op modes.
* A fleet built with a forced backend actually routes every shard's
  launches through it (spy-counted).
* ``_finish_merge`` binds the finished table as VIEWS into the
  preallocated streaming output buffer — no O(merge-size) host
  concatenate+rebuild (``np.shares_memory``), the buffer is allocated
  once per merge, and kernel-mode merges hand the finished table a
  device-resident copy with no re-upload.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.backend import (COMPILED, HOST, INTERPRET, ExecBackend,
                                compiled_supported, load_calibration,
                                merge_kway_host, write_calibration)
from repro.core.constraints import NoConstraint
from repro.core.engine import LSMEngine
from repro.core.fleet import LSMFleet
from repro.core.memtable import TOMBSTONE
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import FairScheduler
from repro.core.sstable import SSTable

MODES = [HOST, INTERPRET] + ([COMPILED] if compiled_supported() else [])

needs_compiled = pytest.mark.skipif(
    not compiled_supported(),
    reason="compiled Pallas unsupported on this XLA backend")

ALL_MODES = [HOST, INTERPRET,
             pytest.param(COMPILED, marks=needs_compiled)]


def _mk_engine(policy: str, backend, memtable: int = 64,
               unique: int = 2048) -> LSMEngine:
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, unique),
        "leveling": lambda: LevelingPolicy(3, memtable, unique),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, unique, file_entries=64, l1_capacity=256),
    }[policy]()
    return LSMEngine(pol, FairScheduler(), NoConstraint(),
                     memtable_entries=memtable, unique_keys=unique,
                     merge_block=64, backend=backend)


def _runs(rng, k: int, n: int, space: int = 3000):
    """k newest-first sorted-unique runs, heavily overlapping."""
    runs = []
    for _ in range(k):
        keys = np.unique(rng.integers(0, space, n, dtype=np.uint32))
        vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int32)
        runs.append((keys, vals))
    return runs


# ------------------------------------------------ cross-mode differential
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
def test_engine_modes_bit_identical(policy):
    """The same workload (puts, deletes, odd streaming quanta) on one
    engine per execution mode: point reads and scans must agree bit for
    bit across every mode, and with the dict oracle."""
    engines = {m: _mk_engine(policy, m) for m in MODES}
    oracle = {}
    rng = np.random.default_rng(9)
    for step in range(6):
        ks = rng.integers(0, 2000, 150, dtype=np.uint32)
        vs = rng.integers(0, 1 << 30, 150).astype(np.int32)
        dels = rng.integers(0, 2000, 20, dtype=np.uint32)
        # admission is prefix-shaped and must not depend on dispatch
        # mode: every engine admits the same counts, the oracle follows
        # the admitted prefixes
        ns = {m: e.put_batch(ks, vs) for m, e in engines.items()}
        nds = {m: e.delete_batch(dels) for m, e in engines.items()}
        assert len(set(ns.values())) == 1, "admission depends on backend"
        assert len(set(nds.values())) == 1
        for eng in engines.values():
            eng.pump(97)            # odd quantum: windows never align
        n, nd = ns[HOST], nds[HOST]
        for k, v in zip(ks[:n].tolist(), vs[:n].tolist()):
            oracle[k] = v
        for k in dels[:nd].tolist():
            oracle.pop(k, None)
    for eng in engines.values():
        eng.drain(budget_entries=53)
    qs = np.arange(0, 2000, dtype=np.uint32)
    ref_f, ref_v = engines[HOST].get_batch(qs)
    ref_sk, ref_sv = engines[HOST].scan_range(0, 2000)
    assert dict(zip(ref_sk.tolist(), ref_sv.tolist())) == oracle
    got = {int(k): int(v) for k, v in zip(qs[ref_f], ref_v[ref_f])}
    assert got == oracle
    for m, eng in engines.items():
        if m == HOST:
            continue
        f, v = eng.get_batch(qs)
        assert np.array_equal(f, ref_f), (policy, m, "found mask")
        assert np.array_equal(v, ref_v), (policy, m, "values")
        sk, sv = eng.scan_range(0, 2000)
        assert np.array_equal(sk, ref_sk), (policy, m, "scan keys")
        assert np.array_equal(sv, ref_sv), (policy, m, "scan vals")


@pytest.mark.parametrize("mode", ALL_MODES)
def test_window_merge_composes_and_matches_host(mode):
    """``merge_kway_window`` under key-boundary cuts: the concatenated
    window outputs must equal the one-shot merge, in every mode, and
    every mode must equal the host reference."""
    rng = np.random.default_rng(4)
    runs = _runs(rng, k=4, n=700)
    be = ExecBackend(mode=mode, merge_block=64)
    want_k, want_v, _ = be.merge_kway(
        runs, runs_dev=lambda: runs)
    host_k, host_v = merge_kway_host(runs)
    assert np.array_equal(want_k, host_k), mode
    assert np.array_equal(want_v, host_v), mode
    # cut at global key boundaries (the engine's merge-path pivot rule)
    cuts = [0, 400, 1100, 1900, 3000]
    got_k, got_v = [], []
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        starts = [int(np.searchsorted(k, np.uint32(lo))) for k, _ in runs]
        stops = [int(np.searchsorted(k, np.uint32(hi))) for k, _ in runs]
        wk, wv, _ = be.merge_kway_window(runs, starts, stops,
                                         runs_dev=lambda: runs)
        got_k.append(wk)
        got_v.append(wv)
    assert np.array_equal(np.concatenate(got_k), want_k), mode
    assert np.array_equal(np.concatenate(got_v), want_v), mode


@pytest.mark.parametrize("mode", ALL_MODES)
def test_scan_merge_drops_tombstones_identically(mode):
    rng = np.random.default_rng(6)
    runs = _runs(rng, k=3, n=300)
    # newest run tombstones a slice of the key space
    tk = np.unique(rng.integers(0, 3000, 100, dtype=np.uint32))
    runs.insert(0, (tk, np.full(len(tk), TOMBSTONE, np.int32)))
    be = ExecBackend(mode=mode, merge_block=64)
    mk, mv = be.scan_merge(runs, drop_value=int(TOMBSTONE))
    ref = {}
    for k, v in reversed([(rk.tolist(), rv.tolist())
                          for rk, rv in runs]):
        ref.update(zip(k, v))
    ref = {k: v for k, v in ref.items() if v != TOMBSTONE}
    assert dict(zip(mk.tolist(), mv.tolist())) == ref, mode
    assert (mv != TOMBSTONE).all()


# ----------------------------------------------------- dispatch decisions
def _cal_table():
    return {"ops": {
        "merge_kway": {"sizes": [1000, 100000],
                       "best": [HOST, COMPILED],
                       "ms": {HOST: [0.1, 50.0],
                              INTERPRET: [5.0, 40.0],
                              COMPILED: [1.0, 2.0]}},
        "probe_multi": {"sizes": [4096], "best": [HOST],
                        "ms": {HOST: [0.2]}},
    }}


def test_decide_uses_size_classes():
    be = ExecBackend(mode="auto", calibration=_cal_table())
    assert be.decide("merge_kway", 500) == HOST       # below first class
    assert be.decide("merge_kway", 50_000) == HOST    # nearest at-or-below
    # window op aliases to merge_kway's calibration entry
    assert be.decide("merge_kway_window", 500) == HOST
    if compiled_supported():
        assert be.decide("merge_kway", 200_000) == COMPILED
    else:
        # compiled verdict degrades to the next measured best (interpret
        # beats host at this size class in the table above)
        assert be.decide("merge_kway", 200_000) == INTERPRET
    # unknown op: built-in default, never the interpreter
    assert be.decide("scan_merge", 10) in (HOST, COMPILED)


def test_decide_forced_wins_over_calibration():
    be = ExecBackend(mode="auto", calibration=_cal_table(),
                     forced={"merge_kway": INTERPRET})
    assert be.decide("merge_kway", 500) == INTERPRET
    assert be.decide("merge_kway", 10 ** 9) == INTERPRET


def test_calibration_absent_or_corrupt_falls_back(tmp_path):
    missing = tmp_path / "nope.json"
    assert load_calibration(missing) is None
    corrupt = tmp_path / "bad.json"
    corrupt.write_text("{not json")
    assert load_calibration(corrupt) is None
    be = ExecBackend(mode="auto", calibration=missing)
    assert be.calibration is None
    want = COMPILED if compiled_supported() else HOST
    for op in ("merge_kway", "probe_multi", "scan_merge"):
        got = be.decide(op, 1 << 20)
        assert got == (want if compiled_supported() else HOST)
        assert got != INTERPRET, "interpreter must never win by default"


def test_calibration_roundtrip(tmp_path):
    p = write_calibration(_cal_table(), tmp_path / "cal.json")
    loaded = load_calibration(p)
    assert loaded is not None and "ops" in loaded
    be = ExecBackend(mode="auto", calibration=p)
    assert be.calibration is not None
    assert be.decide("merge_kway", 500) == HOST


def test_committed_calibration_artifact_loads():
    """The committed artifact (acceptance criterion: dispatch is loaded
    from a MEASURED table, not guessed) must parse and drive decisions
    for every engine op."""
    cal = load_calibration()
    assert cal is not None, "artifacts/bench/backend_calibration.json " \
        "missing or unreadable (regenerate via benchmarks.kernels_bench)"
    be = ExecBackend(mode="auto", calibration=cal)
    for op in ("probe_multi", "merge_kway", "merge_kway_window",
               "scan_merge"):
        assert be.decide(op, 4096) in (HOST, INTERPRET, COMPILED)


def test_compiled_mode_raises_when_unsupported():
    if compiled_supported():
        pytest.skip("compiled Pallas available here")
    with pytest.raises(ValueError):
        ExecBackend(mode="compiled")


# ------------------------------------------------------- legacy mapping
def test_from_legacy_reproduces_old_dispatch():
    # use_kernels=True, interpret=True: merges+probe interpret, scan host
    be = ExecBackend.from_legacy(use_kernels=True, interpret=True)
    assert be.decide("merge_kway", 1) == INTERPRET
    assert be.decide("merge_kway_window", 10 ** 9) == INTERPRET
    assert be.decide("probe_multi", 1) == INTERPRET
    assert be.decide("scan_merge", 1) == HOST
    # use_kernels=False: merges+scan host; probe stays the fused kernel
    be = ExecBackend.from_legacy(use_kernels=False, interpret=True)
    assert be.decide("merge_kway", 1) == HOST
    assert be.decide("scan_merge", 1) == HOST
    assert be.decide("probe_multi", 1) == INTERPRET
    # explicit scan override forces the kernel side
    be = ExecBackend.from_legacy(use_kernels=False, interpret=True,
                                 scan_use_kernels=True)
    assert be.decide("scan_merge", 1) == INTERPRET
    assert be.decide("merge_kway", 1) == HOST


def test_engine_legacy_flags_are_backend_views():
    eng = _mk_engine("tiering", None)     # defaults: kernels, interpret
    assert eng.use_kernels is True
    assert eng.interpret is True
    assert eng.scan_use_kernels is False  # auto: kernel only if compiled
    eng2 = LSMEngine(TieringPolicy(3, 64, 2048), FairScheduler(),
                     NoConstraint(), memtable_entries=64,
                     unique_keys=2048, use_kernels=False)
    assert eng2.use_kernels is False
    assert eng2.backend.decide("merge_kway", 1) == HOST


# ------------------------------------------------------------ fleet pin
class _SpyBackend(ExecBackend):
    def __init__(self, **kw):
        super().__init__(**kw)
        self.calls = {"probe_multi": 0, "merge_kway": 0,
                      "merge_kway_window": 0, "scan_merge": 0}

    def probe_multi(self, *a, **kw):
        self.calls["probe_multi"] += 1
        return super().probe_multi(*a, **kw)

    def merge_kway(self, *a, **kw):
        self.calls["merge_kway"] += 1
        return super().merge_kway(*a, **kw)

    def merge_kway_window(self, *a, **kw):
        self.calls["merge_kway_window"] += 1
        return super().merge_kway_window(*a, **kw)

    def scan_merge(self, *a, **kw):
        self.calls["scan_merge"] += 1
        return super().scan_merge(*a, **kw)


def test_fleet_forced_backend_reaches_every_shard():
    """A fleet built with one forced backend must plumb THAT object to
    every shard and actually route shard launches through it."""
    spy = _SpyBackend(mode=HOST, merge_block=64)

    def factory(i):
        return _mk_engine("tiering", "interpret", memtable=32,
                          unique=1 << 14)

    with LSMFleet(3, factory, parallel=False, backend=spy) as fleet:
        assert fleet.backend is spy
        for e in fleet.engines:
            assert e.backend is spy, "shard kept its factory backend"
        rng = np.random.default_rng(2)
        for _ in range(6):
            ks = rng.integers(0, 1 << 14, 200, dtype=np.uint32)
            fleet.put_batch(ks, np.ones(200, np.int32))
            fleet.pump(300)
        fleet.drain()
        fleet.get_batch(rng.integers(0, 1 << 14, 64, dtype=np.uint32))
        fleet.scan_range(0, 1 << 14)
    assert spy.calls["merge_kway_window"] > 0, "merges bypassed backend"
    assert spy.calls["probe_multi"] > 0, "probes bypassed the backend"
    assert spy.calls["scan_merge"] > 0, "scans bypassed the backend"


# ------------------------------------- device residency / no-concat pins
def _spy_merge_outputs(eng):
    """Wrap ``_finish_merge`` to record, per finished merge, the
    ``_RunningMerge`` and the output tables it bound (the diff of
    ``eng.tables`` across the finish call)."""
    seen = []
    orig_finish = eng._finish_merge

    def spying_finish(rm):
        before = set(eng.tables)
        orig_finish(rm)
        outs = [t for c, t in eng.tables.items() if c not in before]
        seen.append((rm, outs))

    eng._finish_merge = spying_finish
    return seen


def _drive_merge(eng, rng, rounds=6, n=64):
    for _ in range(rounds):
        keys = rng.choice(1 << 16, n, replace=False).astype(np.uint32)
        eng.put_batch(keys, np.ones(n, np.int32))
        if len(eng.active):
            eng.seal_active()
        eng.pump(n)                      # flush; merges collect
    eng.drain(37)                        # odd quanta stream the merges
    assert eng.stats["merges"] > 0, "workload produced no merges"


def test_finish_merge_binds_buffer_views_no_concat():
    """Acceptance pin: the finished table's host mirrors are VIEWS into
    the streaming output buffer (no concatenate+rebuild), and the buffer
    is allocated exactly once per merge (same object every quantum)."""
    eng = _mk_engine("tiering", HOST, memtable=64, unique=1 << 16)
    seen = _spy_merge_outputs(eng)
    orig_advance = eng._advance_merge
    bufs = {}

    def spying_advance(rm, q):
        before = bufs.get(id(rm))
        out = orig_advance(rm, q)
        if rm.buf_keys is not None:
            if before is not None:
                assert rm.buf_keys is before, \
                    "output buffer was reallocated mid-merge"
            bufs[id(rm)] = rm.buf_keys
        return out

    eng._advance_merge = spying_advance
    _drive_merge(eng, np.random.default_rng(1))
    checked = 0
    for rm, outs in seen:
        if rm.emitted == 0 or rm.buf_keys is None:
            continue
        for t in outs:
            assert np.shares_memory(t.keys_np, rm.buf_keys), \
                "finished merge output is not a view into its buffer"
            assert np.shares_memory(t.vals_np, rm.buf_vals)
            checked += 1
    assert checked > 0, "no streamed merge output to pin view-binding on"


def test_partitioned_outputs_are_buffer_views():
    """Partitioned merges split the output into several files — each
    must still be a contiguous VIEW into the streaming buffer, and the
    concatenation of the views must reproduce the emitted stream."""
    eng = _mk_engine("partitioned", HOST, memtable=64, unique=1 << 16)
    seen = _spy_merge_outputs(eng)
    _drive_merge(eng, np.random.default_rng(8))
    split = 0
    for rm, outs in seen:
        if rm.emitted == 0 or rm.buf_keys is None:
            continue
        for t in outs:
            if len(t):
                assert np.shares_memory(t.keys_np, rm.buf_keys)
        if len(outs) > 1:
            glued = np.concatenate([t.keys_np for t in outs])
            assert np.array_equal(glued, rm.buf_keys[:rm.emitted])
            split += 1
    assert split > 0, "no partitioned (multi-file) merge ran"


@pytest.mark.parametrize("mode", ALL_MODES[1:])   # kernel modes only
def test_kernel_merge_output_is_device_resident(mode):
    """A merge whose every window ran on a kernel path hands the
    finished table an ADOPTED device array (no lazy re-upload), and the
    device copy equals the host mirror."""
    eng = _mk_engine("tiering", mode, memtable=64, unique=1 << 16)
    seen = _spy_merge_outputs(eng)
    _drive_merge(eng, np.random.default_rng(5), rounds=4)
    checked = 0
    for rm, outs in seen:
        for t in outs:
            if not len(t):
                continue
            assert t.device_resident, \
                "kernel-merged table did not adopt the device buffer"
            assert np.array_equal(np.asarray(t.keys), t.keys_np)
            assert np.array_equal(np.asarray(t.vals), t.vals_np)
            checked += 1
    assert checked > 0, "no kernel-merged output table to check"


def test_host_merge_output_stays_host_only():
    eng = _mk_engine("tiering", HOST, memtable=64, unique=1 << 16)
    _drive_merge(eng, np.random.default_rng(5), rounds=4)
    for t in eng.tables.values():
        assert not t.device_resident, \
            "host-mode merge paid for a device upload"


def test_sstable_build_lazy_and_adopted_device():
    keys = np.arange(10, dtype=np.uint32)
    vals = np.arange(10, dtype=np.int32)
    t = SSTable.build(keys, vals)
    assert not t.device_resident
    _ = t.keys                            # first kernel use materializes
    assert t._keys_dev is not None
    import jax.numpy as jnp
    dk, dv = jnp.asarray(keys), jnp.asarray(vals)
    t2 = SSTable.build(keys, vals, dev=(dk, dv))
    assert t2.device_resident
    assert t2.keys is dk and t2.vals is dv
