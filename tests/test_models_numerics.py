"""Model-layer numerics: chunked attention/SSD/loss equal their direct
implementations; decode path is consistent with full-sequence forward;
hypothesis property tests on model invariants."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_params, prefill, train_loss
from repro.models.layers import (cross_entropy_loss, flash_attention_jnp,
                                 rms_norm)
from repro.configs import get_smoke


def _ref_attn(q, k, v, causal=True, prefix=0):
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    # G-MAJOR head->kv-group convention (head = g*Hkv + kv): tile, not
    # repeat — matches the model layer's sharding-preserving layout.
    k = jnp.tile(k, (1, H // Hkv, 1, 1))
    v = jnp.tile(v, (1, H // Hkv, 1, 1))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D)
    Sk = k.shape[2]
    mask = (jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]) | \
        (jnp.arange(Sk) < prefix)[None, :]
    if causal:
        s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)


@settings(max_examples=20, deadline=None)
@given(
    S=st.integers(8, 160),
    block=st.sampled_from([16, 32, 64]),
    qblock=st.sampled_from([16, 64]),
    prefix=st.integers(0, 8),
)
def test_flash_attention_property(S, block, qblock, prefix):
    key = jax.random.PRNGKey(S * 31 + block)
    ks = jax.random.split(key, 3)
    B, H, Hkv, D = 2, 4, 2, 8
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    out = flash_attention_jnp(q, k, v, causal=True, prefix_len=prefix,
                              block=block, q_block=qblock)
    ref = _ref_attn(q, k, v, prefix=prefix)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("arch", ["smollm-135m", "whisper-base",
                                  "paligemma-3b", "phi3.5-moe-42b-a6.6b",
                                  "gemma-7b"])
def test_prefill_decode_consistency(arch):
    """logits(prefill(x[:t]))  ==  logits(decode steps over x[:t]) — the
    KV-cache contract, across cross-attention (whisper), prefix-LM
    (paligemma), MoE (phi) and dense decode paths."""
    cfg = get_smoke(arch)
    if cfg.n_experts:
        # capacity-based MoE routing is not causal (caps depend on token
        # count); consistency holds exactly only in the dropless regime
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    def mk_batch(t):
        b = {"tokens": t}
        if cfg.family == "encdec":
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_frames, cfg.d_model))
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model))
        return b
    cache_full, logits_full = prefill(cfg, params, mk_batch(toks), 32)
    # prefill the first S-3 tokens, then decode the last 3
    cache, _ = prefill(cfg, params, mk_batch(toks[:, :S - 3]), 32)
    logits = None
    for t in range(S - 3, S):
        cache, logits = decode_step(cfg, params, cache, toks[:, t])
    # the final decode consumed toks[:, S-1], so logits predict token S —
    # same as the full prefill's last-position logits
    err = float(jnp.max(jnp.abs(logits - logits_full)))
    assert err < 5e-3, (arch, err)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-2.7b"])
def test_ssm_prefill_decode_consistency(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    _, logits_full = prefill(cfg, params, {"tokens": toks}, 16)
    cache, _ = prefill(cfg, params, {"tokens": toks[:, :S - 2]}, 16)
    logits = None
    for t in range(S - 2, S):
        cache, logits = decode_step(cfg, params, cache, toks[:, t])
    err = float(jnp.max(jnp.abs(logits - logits_full)))
    assert err < 5e-3, err


def test_loss_decreases_under_training():
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import run_training
    cfg = get_smoke("smollm-135m")
    _, losses, _ = run_training(cfg, make_host_mesh(), steps=30,
                                global_batch=8, seq_len=32, log_every=1000,
                                learning_rate=1e-3)
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_rms_norm_invariance():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)),
                    jnp.float32)
    scale = jnp.zeros((16,))
    out = rms_norm(x, scale)
    # unit RMS per row
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]])
    labels = jnp.asarray([0, 2])
    loss, _ = cross_entropy_loss(logits, labels)
    manual = -(jax.nn.log_softmax(logits)[jnp.arange(2), labels]).mean()
    assert abs(float(loss) - float(manual)) < 1e-6


def test_moe_routes_all_tokens_with_capacity_slack():
    from repro.models.moe import moe_ffn
    cfg = get_smoke("phi3.5-moe-42b-a6.6b")
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    E, X, F = cfg.d_model, cfg.n_experts, cfg.d_ff
    G = 2
    x = jax.random.normal(key, (2, 16, E))
    rw = jax.random.normal(key, (E, X)) * 0.1
    wi = jax.random.normal(key, (X, G, E, F)) * 0.05
    wo = jax.random.normal(key, (X, F, E)) * 0.05
    y, aux = moe_ffn(cfg, x, rw, wi, wo)
    assert y.shape == x.shape
    assert float(aux["moe_drop_frac"]) < 1e-6   # ample capacity: no drops
    assert float(aux["moe_aux_loss"]) > 0.5     # ~1 when balanced
