"""Serving pool/server + data pipeline tests."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.data import DataConfig, ShardedTokenPipeline
from repro.serving import BatchServer, PagedKVPool, ServerConfig
from repro.serving.server import two_phase_admission


# ------------------------------------------------------------------ pool
def test_pool_admit_extend_retire():
    pool = PagedKVPool(n_pages=16, page_tokens=4)
    pages = pool.admit(1, prompt_tokens=6)
    assert pages is not None and len(pages) == 2
    assert pool.extend(1, 1) == -1          # still fits page 2
    pool.requests[1].length = 8
    new = pool.extend(1, 1)                 # crosses page boundary
    assert isinstance(new, int) and new >= 0
    pool.retire(1)
    assert pool.compactions
    freed = pool.pump(1 << 20)
    assert set(freed) <= set(pool.free)          # reclaimed into free list
    assert len(pool.free) == len(set(pool.free)) == 16


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 30), st.booleans()),
                min_size=1, max_size=40))
def test_pool_never_double_allocates(reqs):
    """Property: live pages are disjoint and |live| + |free| + |holes|
    == n_pages at every step."""
    pool = PagedKVPool(n_pages=32, page_tokens=4)
    live_rids = []
    for i, (ptoks, do_retire) in enumerate(reqs):
        if pool.admit(i, ptoks) is not None:
            live_rids.append(i)
        if do_retire and live_rids:
            pool.retire(live_rids.pop(0))
        pool.pump(8)
        live = [p for r in pool.requests.values() for p in r.pages]
        holes = [p for op in pool.compactions.values()
                 for p in getattr(op, "pages", [])]
        all_pages = live + holes + pool.free
        assert len(all_pages) == len(set(all_pages)) == 32


# ---------------------------------------------------------------- server
def test_server_decodes_and_completes():
    from repro.configs import get_smoke
    from repro.models import init_params
    cfg = get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchServer(cfg, params, ServerConfig(
        batch_size=2, max_len=32, n_pages=32, page_tokens=4,
        max_new_tokens=4))
    for t in range(20):
        if t < 6:
            srv.submit(float(t), 4)
        srv.step(float(t))
    assert len(srv.completed) >= 4
    assert srv.pool.stats["compact_pages"] > 0


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=3)
    p = ShardedTokenPipeline(cfg)
    b1 = p.batch(5)
    b2 = ShardedTokenPipeline(cfg).batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    assert b1["tokens"].max() < 64


def test_pipeline_sharding_partitions_batch():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=1)
    whole = ShardedTokenPipeline(cfg).batch(2)["tokens"]
    parts = [ShardedTokenPipeline(cfg, shard=s, n_shards=4).batch(2)["tokens"]
             for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


def test_pipeline_reshard_replays_same_samples():
    """Elasticity: changing n_shards preserves the global sample stream."""
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=12, seed=2)
    whole = ShardedTokenPipeline(cfg).batch(7)["tokens"]
    parts = [ShardedTokenPipeline(cfg, shard=s, n_shards=3).batch(7)["tokens"]
             for s in range(3)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)
