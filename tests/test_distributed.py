"""Distribution-layer tests: sharding rule resolution, optimizer state
axes, compression, and an 8-device end-to-end subprocess check (device
count must be set before jax initializes, hence the subprocess)."""
from __future__ import annotations

import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

SRC = str(Path(__file__).resolve().parent.parent / "src")


# ----------------------------------------------------------- rule logic
def test_spec_divisibility_fallback():
    from repro.distributed.sharding import spec_for
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"q_heads": ("model",), "embed": ("data",)}
    # trivially divisible by 1
    assert spec_for(mesh, rules, (9, 64), ("q_heads", "embed")) == \
        P("model", "data")


def test_spec_axis_used_once():
    from repro.distributed.sharding import spec_for
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"experts": ("model",), "ffn": ("model",), "embed": ("data",)}
    # model axis consumed by experts; ffn must stay unsharded
    spec = spec_for(mesh, rules, (16, 4, 128), ("experts", "embed", "ffn"))
    assert spec == P("model", "data", None)


def test_opt_state_axes_match_params():
    from repro.configs import get_smoke
    from repro.models import abstract_params, param_logical_axes
    from repro.optim import make_optimizer, opt_state_logical_axes
    for arch in ("smollm-135m", "llama3-405b"):
        cfg = get_smoke(arch)
        p_abs = abstract_params(cfg)
        p_axes = param_logical_axes(cfg)
        opt_init, _ = make_optimizer(cfg.optimizer)
        o_abs = jax.eval_shape(opt_init, p_abs)
        o_axes = opt_state_logical_axes(cfg.optimizer, p_axes, p_abs)
        # same tree structure => tree_shardings can zip them
        jax.tree.map(lambda a, b: None, o_abs, o_axes,
                     is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------- compression
def test_compression_error_feedback_converges():
    from repro.distributed.compression import (
        compress_grads_with_feedback, init_error_state)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    err = init_error_state(g)
    applied = jnp.zeros(1000)
    for _ in range(30):
        out, err = compress_grads_with_feedback(g, err)
        applied = applied + out["w"]
    # error feedback: accumulated applied updates track the true sum
    true = 30 * g["w"]
    rel = float(jnp.linalg.norm(applied - true) / jnp.linalg.norm(true))
    assert rel < 0.01


def test_compression_single_round_bounded_error():
    from repro.distributed.compression import (
        compress_grads_with_feedback, init_error_state)
    g = {"w": jnp.linspace(-1, 1, 512)}
    out, err = compress_grads_with_feedback(g, init_error_state(g))
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) < 1.5 / 127


# --------------------------------------------- 8-device subprocess e2e
_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke
    from repro.train.steps import (init_train_state, make_train_step,
                                   batch_shardings, input_specs)
    from repro.distributed.sharding import default_rules
    cfg = get_smoke("smollm-135m")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = default_rules(mesh)
    step_fn, shardings, _ = make_train_step(cfg, mesh)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32)}
    with mesh:
        jit_step = jax.jit(step_fn, in_shardings=(shardings, None),
                           out_shardings=(shardings, None),
                           donate_argnums=(0,))
        for _ in range(3):
            state, metrics = jit_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # elastic reshard: move restored state to a (2, 4) mesh
    from repro.checkpoint import LSMCheckpointStore, flatten_state
    from repro.checkpoint.restore import reshard_restore
    from repro.train.steps import train_state_axes
    import tempfile
    store = LSMCheckpointStore(tempfile.mkdtemp())
    host = jax.tree.map(np.asarray, state)
    store.put_delta(0, flatten_state(host))
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    restored, _ = reshard_restore(store, mesh2, train_state_axes(cfg))
    step_fn2, sh2, _ = make_train_step(cfg, mesh2)
    with mesh2:
        state2, m2 = jax.jit(step_fn2, in_shardings=(sh2, None),
                             out_shardings=(sh2, None))(restored, batch)
    assert np.isfinite(float(m2["loss"]))
    print("SUBPROC_OK")
""")


@pytest.mark.slow
def test_multidevice_train_and_elastic_reshard():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "SUBPROC_OK" in r.stdout, r.stdout + r.stderr


_SUBPROC_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke
    from repro.models import init_params, train_loss
    from repro.distributed.sharding import default_rules, make_constrainer

    cfg = get_smoke("phi3.5-moe-42b-a6.6b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # dropless
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, cfg.vocab)}
    # reference: single-device dispatch path
    ref_loss, _ = jax.jit(lambda p, b: train_loss(cfg, p, b))(params, batch)
    # expert-parallel shard_map path on a (4, 2) mesh (model axis = 2
    # divides the 4 smoke experts)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sh = make_constrainer(mesh, default_rules(mesh))
    with mesh:
        ep_loss, _ = jax.jit(lambda p, b: train_loss(cfg, p, b, sh=sh))(
            params, batch)
    err = abs(float(ref_loss) - float(ep_loss))
    assert err < 2e-4, (float(ref_loss), float(ep_loss))
    print("MOE_EP_OK", err)
""")


@pytest.mark.slow
def test_moe_expert_parallel_matches_single_device():
    """The shard_map EP dispatch computes the same loss as the pure path
    (dropless capacity so routing is identical)."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_MOE],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "MOE_EP_OK" in r.stdout, r.stdout + r.stderr
