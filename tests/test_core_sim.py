"""Simulator + two-phase evaluation behaviour tests: the paper's headline
claims, asserted."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BLSMSimulator, ClosedClient, ConstantArrival,
                        GlobalConstraint, GreedyScheduler, LSMSimulator,
                        LSMTree, LevelingPolicy, LocalConstraint, OpenClient,
                        PartitionedLevelingPolicy, L0Constraint, SimConfig,
                        SizeTieredPolicy, TieringPolicy, make_scheduler,
                        run_two_phase)

CFG = SimConfig()


def tiering_sim(sched="fair", T=3):
    pol = TieringPolicy(T, CFG.memtable_entries, CFG.unique_keys)
    return LSMSimulator(pol, make_scheduler(sched),
                        GlobalConstraint(2 * pol.expected_components()), CFG)


def leveling_sim(sched="fair", T=10, constraint=None):
    pol = LevelingPolicy(T, CFG.memtable_entries, CFG.unique_keys)
    cons = constraint or GlobalConstraint(2 * pol.expected_components())
    return LSMSimulator(pol, make_scheduler(sched), cons, CFG)


class TestConservation:
    def test_closed_system_served_equals_arrived(self):
        tr = tiering_sim().run(ClosedClient(), 600.0)
        assert tr.service_v[-1] == pytest.approx(tr.arrival_v[-1], rel=1e-9)

    def test_open_system_served_le_arrived(self):
        sim = tiering_sim()
        tr = sim.run(OpenClient(ConstantArrival(30000.0)), 600.0)
        assert tr.service_v[-1] <= tr.arrival_v[-1] + 1e-6

    def test_monotone_curves(self):
        tr = tiering_sim().run(ClosedClient(), 600.0)
        assert np.all(np.diff(tr.service_t) >= 0)
        assert np.all(np.diff(tr.service_v) >= -1e-9)

    def test_write_budget_respected(self):
        """Total flush+merge bytes cannot exceed bandwidth * time."""
        sim = tiering_sim("fair")
        dur = 1800.0
        tr = sim.run(ClosedClient(), dur)
        flushed = tr.service_v[-1]  # every served entry is flushed once
        merged = sum(tr.merge_sizes)
        assert (flushed + merged) * 1.0 <= CFG.bandwidth * dur * 1.02

    def test_low_rate_no_stalls(self):
        sim = tiering_sim("fair")
        tr = sim.run(OpenClient(ConstantArrival(1000.0)), 3600.0)
        assert tr.stall_time() == 0.0
        assert tr.write_latency_percentiles((99,))[99] < 0.1


@pytest.mark.slow
class TestPaperClaims:
    """Each test pins one empirical claim from the paper.  These replay
    multi-hour fluid simulations per figure — the heavyweight end of the
    suite, so the CI fast lane (-m "not slow") skips them."""

    def test_greedy_overreports_in_testing(self):
        """S 5.2.2: greedy measures a higher (unsustainable) max than fair."""
        fair = tiering_sim("fair").run(ClosedClient(), 7200.0)
        greedy = tiering_sim("greedy").run(ClosedClient(), 7200.0)
        assert greedy.throughput(1200) > fair.throughput(1200) * 1.05

    def test_leveling_only_greedy_sustainable(self):
        """Figure 10: fair stalls under 95% leveling load, greedy does not."""
        res = {}
        for sched in ("single", "fair", "greedy"):
            res[sched] = run_two_phase(
                testing_system=lambda: leveling_sim("fair"),
                running_system=lambda s=sched: leveling_sim(s))
        assert res["greedy"].running.stall_time() < res["fair"].running.stall_time()
        assert res["fair"].running.stall_time() < res["single"].running.stall_time()
        # "small" = the paper's sustainability bar (p99 < 10 s); fair and
        # single must be clearly worse than greedy as in Figure 10c
        assert res["greedy"].write_latencies[99] < 10.0
        assert res["fair"].write_latencies[99] > \
            5 * res["greedy"].write_latencies[99]
        assert res["single"].write_latencies[99] > 10.0

    def test_tiering_fair_and_greedy_sustainable(self):
        """Figure 9: with tiering both fair and greedy avoid stalls; the
        single-threaded scheduler does not."""
        out = {}
        for sched in ("single", "fair", "greedy"):
            out[sched] = run_two_phase(
                testing_system=lambda: tiering_sim("fair"),
                running_system=lambda s=sched: tiering_sim(s))
        assert out["fair"].sustainable
        assert out["greedy"].sustainable
        assert not out["single"].sustainable

    def test_greedy_minimizes_components_running(self):
        """Figure 9b: greedy keeps fewer disk components than fair."""
        r_fair = run_two_phase(testing_system=lambda: tiering_sim("fair"),
                               running_system=lambda: tiering_sim("fair"))
        r_greedy = run_two_phase(testing_system=lambda: tiering_sim("fair"),
                                 running_system=lambda: tiering_sim("greedy"))
        mean = lambda r: np.mean(r.running.comp_v)
        assert mean(r_greedy) < mean(r_fair)

    def test_global_beats_local_constraint_leveling(self):
        """Figure 12: local constraints inflate leveling write latencies."""
        def mk(cons):
            return lambda: leveling_sim("greedy", constraint=cons())
        r_global = run_two_phase(testing_system=lambda: leveling_sim("fair"),
                                 running_system=mk(lambda: GlobalConstraint(6)))
        r_local = run_two_phase(testing_system=lambda: leveling_sim("fair"),
                                running_system=mk(lambda: LocalConstraint(2)))
        assert r_global.write_latencies[99] <= r_local.write_latencies[99]

    def test_size_tiered_unsustainable_then_fixed(self):
        """Figures 19-20: default size-tiered testing over-reports; the
        force-min fix yields a lower but sustainable rate."""
        def st_sim(force_min, sched="fair"):
            pol = SizeTieredPolicy(1.2, CFG.memtable_entries, CFG.unique_keys,
                                   2, 10, force_min=force_min)
            return LSMSimulator(pol, make_scheduler(sched),
                                GlobalConstraint(50), CFG)
        r_default = run_two_phase(testing_system=lambda: st_sim(False),
                                  running_system=lambda: st_sim(False))
        r_fixed = run_two_phase(testing_system=lambda: st_sim(True),
                                running_system=lambda: st_sim(False))
        assert r_fixed.max_throughput < r_default.max_throughput
        assert r_fixed.sustainable
        assert not r_default.sustainable

    def test_partitioned_unsustainable_then_fixed(self):
        """Figures 21/23: LevelDB-style L0 merge-all over-reports; exact-T0
        testing is sustainable (and ~10-40% lower)."""
        def pt(l0_all, sched="single"):
            pol = PartitionedLevelingPolicy(10, CFG.memtable_entries,
                                            CFG.unique_keys, l0_merge_all=l0_all)
            return LSMSimulator(pol, make_scheduler(sched), L0Constraint(12), CFG)
        r_default = run_two_phase(testing_system=lambda: pt(True),
                                  running_system=lambda: pt(True))
        r_fixed = run_two_phase(testing_system=lambda: pt(False),
                                running_system=lambda: pt(True))
        assert r_fixed.max_throughput < r_default.max_throughput
        assert r_fixed.sustainable
        assert not r_default.sustainable

    def test_blsm_bounds_processing_not_write_latency(self):
        """Figure 6: bLSM's processing latency stays tiny but queuing blows
        up the write latency at 95% utilization."""
        r = run_two_phase(testing_system=lambda: BLSMSimulator())
        assert r.processing_latencies[99] < 0.01
        assert r.write_latencies[99] > 1.0

    def test_blsm_sawtooth(self):
        """Figure 6a: the write-rate cap peaks after each C1 swap."""
        sim = BLSMSimulator()
        tr = sim.run(ClosedClient(), 7200.0)
        _, tps = tr.windowed_throughput(30.0)
        # periodic resets: max/min within the trace differ noticeably
        assert tps.max() > tps[tps > 0].min() * 1.3


class TestMergedSizeModel:
    @given(sizes=st.lists(st.floats(1.0, 1e8), min_size=1, max_size=6))
    @settings(deadline=None, max_examples=100)
    def test_bounds(self, sizes):
        tree = LSMTree(unique_keys=100e6)
        out = tree.merged_size(sizes)
        assert out <= sum(sizes) + 1e-6
        assert out <= tree.unique_keys + 1e-6
        assert out >= max(min(s, tree.unique_keys) for s in sizes) - 1e-6

    def test_small_components_no_dedup(self):
        tree = LSMTree(unique_keys=100e6)
        assert tree.merged_size([100.0, 100.0]) == pytest.approx(200.0, rel=1e-3)

    def test_full_dedup_at_capacity(self):
        tree = LSMTree(unique_keys=1000.0)
        assert tree.merged_size([1000.0, 1000.0]) == pytest.approx(1000.0)
