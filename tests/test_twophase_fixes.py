"""Regression tests for the two-phase measurement-plane bugfixes.

Each test pins a bug that the pre-fix code exhibits:

1. ``run_two_phase`` excluded warm-up from the testing-phase throughput
   but NOT from the running-phase latency percentiles, so cold-start
   transients polluted p99 and the ``sustainable`` verdict (and
   ``processing_latency_percentiles`` had no warm-up cutoff at all).
2. ``BackgroundDriver._run`` computed a fixed per-quantum budget and
   slept a fixed quantum per iteration, so pump compute time / lock
   contention / sleep overshoot silently under-delivered the configured
   bandwidth.
3. ``TwoPhaseResult.sustainable`` read ``write_latencies.get(99, inf)``
   — callers passing custom ``pcts`` without 99 silently got
   "unsustainable".
4. ``LSMEngine.pump`` flushed whole memtables while ``spent < budget``,
   overshooting the quantum for free — at pacing quanta smaller than a
   memtable the configured I/O budget did not throttle flush-bound work.
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.core.constraints import NoConstraint
from repro.core.engine import ENTRY_BYTES, BackgroundDriver, LSMEngine
from repro.core.metrics import Trace
from repro.core.policies import TieringPolicy
from repro.core.scheduler import GreedyScheduler
from repro.core.twophase import run_two_phase


# --------------------------------------------------------------------------
# synthetic systems: traces crafted so warm-up and steady state differ
# --------------------------------------------------------------------------
class _CannedSystem:
    """TwoPhaseSystem stub returning a pre-built trace."""

    def __init__(self, trace: Trace):
        self._trace = trace
        self.write_capacity = 1000.0

    def run(self, client, duration: float) -> Trace:
        return self._trace


def _steady_trace(duration=100.0, rate=100.0) -> Trace:
    """Arrivals == service at ``rate``: zero-latency baseline."""
    tr = Trace(duration=duration)
    tr.record_arrival(duration, rate * duration)
    tr.record_service(duration, rate * duration)
    tr.record_capacity(0.0, rate)
    return tr


def _coldstart_trace(duration=100.0, rate=100.0, slow_until=60.0) -> Trace:
    """Cold start: service crawls at rate/10 (with ~zero instantaneous
    capacity => huge per-write processing delay) until ``slow_until``,
    then catches up to the arrival curve instantly and tracks it exactly
    — so every write completed before ``slow_until`` sees a huge latency
    and every steady-state write ~none."""
    tr = Trace(duration=duration)
    tr.record_arrival(duration, rate * duration)
    tr.record_service(slow_until, rate / 10 * slow_until)
    # instant catch-up: back on the arrival curve half a second later
    tr.record_service(slow_until + 0.5, rate * (slow_until + 0.5))
    tr.record_service(duration, rate * duration)
    tr.record_capacity(0.0, 1e-3)       # processing delay 1000 s ...
    tr.record_capacity(slow_until, rate)    # ... until steady state
    return tr


def test_running_phase_percentiles_exclude_warmup():
    """Bugfix 1: with warm-up >= the cold-start transient, the running
    phase's p99 write AND processing latencies must reflect steady state
    only (pre-fix: both were dominated by the transient)."""
    res = run_two_phase(
        testing_system=lambda: _CannedSystem(_steady_trace()),
        running_system=lambda: _CannedSystem(_coldstart_trace()),
        testing_duration=100.0, running_duration=100.0, warmup=60.0)
    assert res.write_latencies[99] < 1.0          # pre-fix: ~40 s
    assert res.processing_latencies[99] < 1.0     # pre-fix: ~1000 s
    assert res.sustainable


def test_processing_latency_percentiles_t_from():
    """The new warm-up cutoff on processing percentiles, directly."""
    tr = _coldstart_trace()
    cold = tr.processing_latency_percentiles((99,))
    warm = tr.processing_latency_percentiles((99,), t_from=60.0)
    assert cold[99] > 100.0
    assert warm[99] < 1.0


def test_closed_stall_extras_respect_t_from():
    """Closed-system stall contributions before the cutoff are excluded,
    and a stall straddling the cutoff contributes only its in-window
    part."""
    tr = _steady_trace()
    tr.closed_system = True
    tr.stalls = [(10.0, 30.0)]          # 20 s stall inside warm-up
    # small n so the single in-flight stall write is >1% of the samples
    cold = tr.processing_latency_percentiles((99,), n=50)
    warm = tr.processing_latency_percentiles((99,), n=50, t_from=60.0)
    assert cold[99] > 1.0
    assert warm[99] < 1.0
    tr.stalls = [(50.0, 70.0)]          # straddles the cutoff: 10 s inside
    strad = tr.processing_latency_percentiles((99,), n=50, t_from=60.0)
    assert 1.0 < strad[99] <= 10.0


def test_sustainable_without_p99_in_pcts():
    """Bugfix 3: pcts omitting 99 must still compute p99 (pre-fix: the
    verdict fell back to +inf => 'unsustainable')."""
    res = run_two_phase(
        testing_system=lambda: _CannedSystem(_steady_trace()),
        running_system=lambda: _CannedSystem(_steady_trace()),
        testing_duration=100.0, running_duration=100.0, warmup=10.0,
        pcts=(50,))
    assert 99 in res.write_latencies
    assert res.sustainable


# --------------------------------------------------------------------------
# BackgroundDriver pacing
# --------------------------------------------------------------------------
class _SlowPumpEngine:
    """Engine stub whose pump costs real time (compute/lock contention):
    under the pre-fix fixed-quantum loop this halves-or-worse the
    delivered budget; the deficit-paced driver repays it with larger
    quanta."""

    def __init__(self, pump_cost_s: float):
        self._lock = threading.RLock()
        self.cost = pump_cost_s
        self.offered = 0

    def lock(self):
        return self._lock

    def pump(self, budget_entries: int) -> int:
        self.offered += budget_entries
        time.sleep(self.cost)
        return budget_entries


def test_driver_delivers_configured_bandwidth_under_contention():
    """Bugfix 2: delivered budget must track elapsed * rate even when
    each pump call eats ~2 quanta of wall time (pre-fix: ~1/3 of the
    configured bandwidth)."""
    rate_entries = 2000.0
    eng = _SlowPumpEngine(pump_cost_s=0.02)
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=rate_entries * ENTRY_BYTES,
                           quantum_s=0.01)
    t0 = time.monotonic()
    drv.start()
    time.sleep(0.6)
    drv.stop()
    elapsed = time.monotonic() - t0
    expected = rate_entries * elapsed
    # generous CI bounds; the pre-fix driver lands near 0.33x
    assert eng.offered > 0.55 * expected
    assert eng.offered < 1.5 * expected


# --------------------------------------------------------------------------
# pump flush-debt
# --------------------------------------------------------------------------
def _flush_engine(memtable=64, num_memtables=3) -> LSMEngine:
    return LSMEngine(TieringPolicy(3, memtable, 4096), GreedyScheduler(),
                     NoConstraint(), memtable_entries=memtable,
                     num_memtables=num_memtables, unique_keys=4096)


def test_pump_flush_overshoot_carried_as_debt():
    """Bugfix 4: a flush bigger than the quantum must charge the
    overshoot to later quanta — two sealed 64-entry memtables at
    16-entry quanta cost 8 pumps, not 2 (pre-fix: one free flush per
    pump call regardless of budget)."""
    eng = _flush_engine()
    for i in range(2 * 64 + 1):         # fill + seal two memtables
        assert eng.put(i % 4096, i)
    assert len(eng.sealed) == 2
    flushes = []
    for _ in range(8):
        eng.pump(16)
        flushes.append(eng.stats["flushes"])
    # first flush on pump 1, debt 48 repaid over pumps 2-4 (pump 4's
    # budget is fully consumed by the last repayment), second flush on
    # pump 5, its debt repaid over pumps 6-8
    assert flushes[0] == 1
    assert flushes[2] == 1              # pre-fix: already 2 by pump 2
    assert flushes[-1] == 2
    assert eng._flush_debt == 0         # 128 entries == 8 * 16 quanta
