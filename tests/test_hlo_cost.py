"""Unit tests for the loop-aware HLO cost model — the §Roofline inputs."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze, parse_module


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_flat_scan_flops():
    w = jnp.ones((64, 64), jnp.float32)

    def f(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=7)[0]

    t = analyze(_compiled_text(f, jnp.ones((32, 64))))
    expect = 7 * 2 * 32 * 64 * 64
    assert abs(t.flops / expect - 1) < 0.05


def test_nested_scan_flops():
    w = jnp.ones((64, 64), jnp.float32)

    def g(x):
        def outer(c, _):
            c, _ = jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                length=3)
            return c, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    t = analyze(_compiled_text(g, jnp.ones((32, 64))))
    expect = 15 * 2 * 32 * 64 * 64
    assert abs(t.flops / expect - 1) < 0.05


def test_cost_analysis_undercounts_loops():
    """The reason this module exists: XLA's flat counter misses trips."""
    w = jnp.ones((64, 64), jnp.float32)

    def f(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=50)[0]

    comp = jax.jit(f).lower(jnp.ones((32, 64))).compile()
    ca = comp.cost_analysis()           # dict, or list of dicts on new jax
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    flat = float((ca or {}).get("flops", 0))
    ours = analyze(comp.as_text()).flops
    assert ours > 5 * max(flat, 1.0)


def test_entry_detection():
    comps, entry = parse_module(_compiled_text(
        lambda x: x * 2 + 1, jnp.ones((8,))))
    assert entry is not None and entry in comps


def test_traffic_counts_fusion_boundaries_once():
    """Fused elementwise chains contribute call-site traffic only."""
    def f(x):
        y = x * 2
        y = y + 1
        y = jnp.tanh(y)
        y = y * x
        return y

    n = 1 << 16
    t = analyze(_compiled_text(f, jnp.ones((n,), jnp.float32)))
    # in + out (+ maybe one temp): far less than 8 arrays the unfused
    # chain would touch
    assert t.traffic_bytes <= 5 * n * 4
