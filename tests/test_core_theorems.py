"""Property tests for the paper's three theorems (Appendix A).

These exercise the *scheduling laws* directly, with hypothesis-generated
workloads where the theorem quantifies over arbitrary inputs.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BurstyArrival, Component, ConstantArrival,
                        GlobalConstraint, GreedyScheduler, LSMSimulator,
                        MergeOp, OpenClient, SimConfig, TieringPolicy)
from repro.core.metrics import _invert


# ---------------------------------------------------------------------------
# Theorem 1: processing writes as quickly as possible minimizes the latency
# of EACH write, for any arrival process.
# ---------------------------------------------------------------------------
def _completion_times(trace, xs):
    return _invert(np.asarray(trace.service_t), np.asarray(trace.service_v), xs)


def _run(rate_cap, arrival, duration=1800.0):
    cfg = SimConfig()
    pol = TieringPolicy(3, cfg.memtable_entries, cfg.unique_keys)
    controller = None if rate_cap is None else (lambda t, tree: rate_cap)
    sim = LSMSimulator(pol, GreedyScheduler(),
                       GlobalConstraint(2 * pol.expected_components()), cfg,
                       write_controller=controller)
    return sim.run(OpenClient(arrivals=arrival), duration)


@settings(deadline=None, max_examples=12)
@given(normal=st.floats(1000, 12000), burst=st.floats(12000, 40000),
       cap=st.floats(4000, 20000))
def test_theorem1_asap_dominates_delayed(normal, burst, cap):
    arrival = BurstyArrival(normal, burst, 300.0, 120.0)
    asap = _run(None, arrival)
    delayed = _run(cap, arrival)
    n_done = min(asap.service_v[-1], delayed.service_v[-1])
    if n_done < 1:
        return
    xs = np.linspace(0.0, n_done * 0.999, 512)
    t_asap = _completion_times(asap, xs)
    t_delayed = _completion_times(delayed, xs)
    # same arrivals => identical arrival times; ASAP completes every write
    # no later (small fluid-integration tolerance)
    assert np.all(t_asap <= t_delayed + 1e-3)


# ---------------------------------------------------------------------------
# Theorem 2: for a STATIC set of same-arity merges, greedy minimizes the
# number of components at every instant, vs any other allocation.
# ---------------------------------------------------------------------------
def _static_schedule(remaining, order_or_alloc, bandwidth=1.0):
    """Execute static jobs; returns sorted completion times.

    ``order_or_alloc`` is 'greedy' (SJF), or a permutation (sequential
    execution order), or 'fair'.
    """
    rem = list(map(float, remaining))
    n = len(rem)
    t = 0.0
    completions = []
    if order_or_alloc == "fair":
        live = list(range(n))
        while live:
            share = bandwidth / len(live)
            k = min(live, key=lambda i: rem[i])
            dt = rem[k] / share
            for i in live:
                rem[i] -= share * dt
            t += dt
            done = [i for i in live if rem[i] <= 1e-9]
            for i in done:
                completions.append(t)
                live.remove(i)
    else:
        order = (np.argsort(remaining, kind="stable")
                 if order_or_alloc == "greedy" else order_or_alloc)
        for i in order:
            t += rem[i] / bandwidth
            completions.append(t)
    return np.asarray(sorted(completions))


@settings(deadline=None, max_examples=50)
@given(sizes=st.lists(st.floats(0.1, 100.0), min_size=1, max_size=8),
       data=st.data())
def test_theorem2_greedy_minimizes_components(sizes, data):
    greedy = _static_schedule(sizes, "greedy")
    perm = data.draw(st.permutations(range(len(sizes))))
    other = _static_schedule(sizes, list(perm))
    fair = _static_schedule(sizes, "fair")
    # greedy's i-th completion is no later than any other schedule's i-th
    # completion  =>  #components(t) is pointwise minimal.
    assert np.all(greedy <= other + 1e-9)
    assert np.all(greedy <= fair + 1e-9)


def test_theorem2_on_simulator_allocations():
    """Greedy vs fair through the actual scheduler classes on a static set."""
    from repro.core import FairScheduler
    comps = [Component(size=s, level=0) for s in (5.0, 1.0, 3.0)]
    def fresh_ops():
        return [MergeOp(inputs=[Component(size=c.size, level=0)],
                        output_level=1, output_size=c.size) for c in comps]

    def run(sched):
        ops = fresh_ops()
        t, completions = 0.0, []
        while ops:
            alloc = sched.allocate(ops)
            rates = {o.op_id: alloc.get(o.op_id, 0.0) for o in ops}
            dt = min(o.remaining_output / rates[o.op_id]
                     for o in ops if rates[o.op_id] > 0)
            for o in ops:
                o.written += rates[o.op_id] * dt
            t += dt
            done = [o for o in ops if o.done]
            for o in done:
                completions.append(t)
                ops.remove(o)
        return completions

    greedy = run(GreedyScheduler())
    from repro.core import FairScheduler as FS
    fair = run(FS())
    assert all(g <= f + 1e-9 for g, f in zip(greedy, fair))
    assert greedy[0] == pytest.approx(1.0)  # smallest (1.0) first


# ---------------------------------------------------------------------------
# Theorem 3: no scheduler minimizes #components at every instant once the
# policy creates merges dynamically — the appendix counterexample.
# ---------------------------------------------------------------------------
def test_theorem3_counterexample():
    B = 1.0
    m12, m45, m13 = 10.0, 6.0, 2.0  # |M13| < |M45| < |M12|
    # S1: M45 then M12 (then M13)
    s1_first, s1_second = m45 / B, (m45 + m12) / B
    # S2: M12 first, which unlocks M13
    s2_first, s2_second = m12 / B, (m12 + m13) / B
    assert s1_first < s2_first      # S1 wins the first completion
    assert s2_second < s1_second    # S2 wins the second completion
    # any scheduler matching S1's first completion must run M45 first and
    # then cannot beat S2's second completion:
    best_second_after_m45 = m45 / B + m12 / B  # M13 not yet creatable
    assert best_second_after_m45 > s2_second
