"""Foreground/background concurrency hammers (ISSUE 6 satellite).

The engine's foreground entry points now lock INTERNALLY, so router
worker threads racing a live ``BackgroundDriver`` can never observe a
half-updated ``_order`` list / filter-stack journal or a donated device
buffer.  Pre-fix, unlocked readers against a pumping driver raced the
insertion-maintained read view (list mutation during the snapshot,
donated Bloom-stack buffers, memtable seal vs append) and crashed or
returned phantom results; these hammers regression-pin the fix by
hammering get/scan/put from several threads WITHOUT any external
locking, under live background I/O, and checking invariants that only
hold if every operation saw a consistent engine state.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core.engine import BackgroundDriver, LSMEngine
from repro.core.fleet import FleetBackgroundDriver, LSMFleet
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler

UNIQUE = 1 << 15


def _mk_engine(_shard: int = 0) -> LSMEngine:
    return LSMEngine(TieringPolicy(3, 512, UNIQUE), FairScheduler(), None,
                     memtable_entries=512, num_memtables=4,
                     unique_keys=UNIQUE, use_kernels=False)


def _hammer(store, writer_keys, duration_s: float = 2.0,
            n_readers: int = 3):
    """Writers insert value == key; readers get/scan concurrently with NO
    external locking.  Any found value must equal its key — a torn read
    view or half-applied filter journal surfaces as a wrong value, a
    crash, or an inverted scan order."""
    errors: list[BaseException] = []
    stop = threading.Event()

    def writer():
        rng = np.random.default_rng(1)
        try:
            while not stop.is_set():
                ks = rng.choice(writer_keys, 256, replace=False)
                store.put_batch(ks, ks.astype(np.int32))
        except BaseException as e:  # noqa: BLE001 - collect for report
            errors.append(e)

    def reader(seed: int):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                qs = rng.integers(0, UNIQUE, 128, dtype=np.uint32)
                found, vals = store.get_batch(qs)
                bad = found & (vals != qs.astype(np.int32))
                assert not bad.any(), \
                    f"phantom values {vals[bad][:4]} for keys {qs[bad][:4]}"
                lo = int(rng.integers(0, UNIQUE - 2048))
                sk, sv = store.scan_range(lo, lo + 2048)
                assert (np.diff(sk.astype(np.int64)) > 0).all(), \
                    "scan returned unsorted/duplicate keys"
                assert (sv == sk.astype(np.int32)).all(), \
                    "scan returned torn values"
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer)] + \
        [threading.Thread(target=reader, args=(10 + i,))
         for i in range(n_readers)]
    for t in threads:
        t.start()
    stop.wait(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    if errors:
        raise errors[0]


def test_engine_reads_safe_against_live_driver():
    """get_batch/scan_range/put_batch from 4 unlocked threads against a
    live BackgroundDriver: every found value equals its key and every
    scan is sorted-unique.  (Pre-fix, the unlocked read path raced the
    pump thread's _order/filter-stack mutations.)"""
    eng = _mk_engine()
    writer_keys = np.arange(UNIQUE, dtype=np.uint32)
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=64e6,
                           quantum_s=0.002)
    drv.start()
    try:
        _hammer(eng, writer_keys, duration_s=2.0)
    finally:
        drv.stop()
    assert eng.stats["flushes"] > 0, "hammer never exercised background"


def test_fleet_router_safe_against_live_driver():
    """The same hammer through the fleet router: worker threads fan each
    batch across shard locks while the FleetBackgroundDriver splits the
    global budget — no torn reads across any shard."""
    fleet = LSMFleet(4, _mk_engine, arbiter="fair")
    writer_keys = np.arange(UNIQUE, dtype=np.uint32)
    drv = FleetBackgroundDriver(fleet, bandwidth_bytes_per_s=64e6,
                                quantum_s=0.002)
    drv.start()
    try:
        with fleet:
            _hammer(fleet, writer_keys, duration_s=2.0)
    finally:
        drv.stop()
    assert fleet.stats["flushes"] > 0


def test_scan_merge_runs_outside_lock():
    """The scan plane snapshots its run windows under the lock but merges
    outside it: a scan started while the lock is HELD by another thread
    must block only for the snapshot, and the returned arrays stay valid
    even if a merge retires their source tables mid-merge (immutable
    snapshots)."""
    eng = _mk_engine()
    rng = np.random.default_rng(3)

    def write_all(ks):
        done = 0
        while done < len(ks):
            done += eng.put_batch(ks[done:], ks[done:].astype(np.int32))
            eng.pump(1024)
        eng.drain()

    keys = rng.choice(UNIQUE, 4096, replace=False).astype(np.uint32)
    write_all(keys)
    before_k, before_v = eng.scan_range(0, UNIQUE)
    # retire every table through a fresh workload + drain, then verify
    # the previously returned arrays are untouched snapshots
    write_all(rng.choice(UNIQUE, 4096, replace=False).astype(np.uint32))
    assert (before_v == before_k.astype(np.int32)).all()
    assert len(before_k) == len(keys)


@pytest.mark.parametrize("n_threads", [2, 4])
def test_concurrent_put_batches_no_lost_writes(n_threads):
    """N writer threads each own a disjoint key range and write value ==
    key; after drain, every key reads back exactly once with its own
    value (internal locking makes put_batch linearizable per engine)."""
    eng = _mk_engine()
    span = UNIQUE // n_threads
    errs: list[BaseException] = []

    def writer(i: int):
        try:
            ks = np.arange(i * span, (i + 1) * span, dtype=np.uint32)
            done = 0
            while done < len(ks):
                done += eng.put_batch(ks[done:done + 512],
                                      ks[done:done + 512].astype(np.int32))
                eng.pump(512)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=writer, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs, errs
    eng.drain()
    all_keys = np.arange(n_threads * span, dtype=np.uint32)
    found, vals = eng.get_batch(all_keys)
    assert found.all(), f"lost {int((~found).sum())} writes"
    np.testing.assert_array_equal(vals, all_keys.astype(np.int32))
