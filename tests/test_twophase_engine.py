"""Engine-backed two-phase harness tests: the real ``LSMEngine`` driven
through ``run_two_phase`` must produce well-formed traces, agree with the
fluid simulator's verdicts on matched configurations, and keep the read
view's Bloom stack cached on device.

Fast lane: virtual-clock smokes, the sim/engine differential, and the
device-cache check.  Slow lane: the full benchmark-grid replay
(``benchmarks.twophase_engine``).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (BurstyArrival, ClosedClient, ConstantArrival,
                        EngineSystem, GlobalConstraint, LSMEngine,
                        LSMSimulator, OpenClient, SimConfig, TieringPolicy,
                        TwoPhaseSystem, make_scheduler, run_two_phase)

MEMTABLE = 128
UNIQUE = 4096
BANDWIDTH_E = 2048.0           # background budget, entries/s
MEM_RATE = 6000.0              # in-memory insert capacity, entries/s


def _engine_factory(sched="greedy", bandwidth_frac=1.0):
    def factory():
        pol = TieringPolicy(3, MEMTABLE, UNIQUE)
        return LSMEngine(pol, make_scheduler(sched),
                         GlobalConstraint(2 * pol.expected_components()),
                         memtable_entries=MEMTABLE, unique_keys=UNIQUE,
                         merge_block=64)
    return factory


def _engine_system(sched="greedy", bandwidth_frac=1.0, **kw) -> EngineSystem:
    return EngineSystem(_engine_factory(sched),
                        bandwidth_bytes_per_s=BANDWIDTH_E * 1024
                        * bandwidth_frac,
                        mem_write_rate=MEM_RATE, tick_s=0.02, **kw)


def _sim_system(sched="fair", bandwidth_frac=1.0) -> LSMSimulator:
    pol = TieringPolicy(3, MEMTABLE, UNIQUE)
    cfg = SimConfig(bandwidth=BANDWIDTH_E * bandwidth_frac,
                    memtable_entries=MEMTABLE, unique_keys=UNIQUE,
                    mem_write_rate=MEM_RATE)
    return LSMSimulator(pol, make_scheduler(sched),
                        GlobalConstraint(2 * pol.expected_components()), cfg)


def test_systems_satisfy_protocol():
    assert isinstance(_engine_system(), TwoPhaseSystem)
    assert isinstance(_sim_system(), TwoPhaseSystem)
    assert _engine_system().write_capacity == MEM_RATE
    assert _sim_system().write_capacity == MEM_RATE


def test_closed_run_trace_well_formed():
    """Closed client on the virtual clock: monotone curves, arrival ==
    service, and the trace's written total == the engine's own count."""
    sys = _engine_system()
    tr = sys.run(ClosedClient(n_threads=1, per_thread_rate=MEM_RATE), 6.0)
    assert np.all(np.diff(tr.service_t) >= 0)
    assert np.all(np.diff(tr.service_v) >= 0)
    assert tr.arrival_v[-1] == pytest.approx(tr.service_v[-1])
    assert int(tr.total_written) == sys.last_engine.stats["puts"]
    assert tr.total_written > 0
    # the closed client must have been throttled by background I/O at
    # some point (memtables outrun a 2048 e/s budget at 6000 e/s inserts)
    assert tr.stalls or tr.throughput() < MEM_RATE


def test_open_run_respects_arrivals():
    """Open client: service never exceeds arrivals, and a modest rate is
    absorbed without stalls."""
    sys = _engine_system()
    tr = sys.run(OpenClient(arrivals=ConstantArrival(400.0)), 6.0)
    assert tr.service_v[-1] <= tr.arrival_v[-1] + 1e-6
    assert tr.arrival_v[-1] == pytest.approx(400.0 * 6.0, rel=0.05)
    assert not tr.stalls
    assert tr.write_latency_percentiles((99,))[99] < 1.0


def test_open_run_starved_stalls():
    """An arrival rate far above the background budget must produce
    writer-observed stall intervals and large write latencies."""
    sys = _engine_system(bandwidth_frac=0.125)   # 256 e/s budget
    tr = sys.run(OpenClient(arrivals=ConstantArrival(2000.0)), 20.0)
    assert len(tr.stalls) > 0
    assert tr.stall_time() > 0.0
    assert tr.write_latency_percentiles((99,), t_from=2.0)[99] > 1.0


def test_engine_two_phase_differential_with_simulator():
    """The headline differential: the engine-backed and simulator-backed
    harnesses agree on the stall/sustainability verdicts for a matched
    configuration — generous background bandwidth is sustainable at 95%
    utilization on both backends, and a running system with 1/8 the
    bandwidth is unsustainable (with stalls) on both."""
    durs = dict(testing_duration=8.0, running_duration=8.0, warmup=1.5)

    healthy = {}
    for name, mk in (("engine", lambda s: _engine_system(s)),
                     ("sim", lambda s: _sim_system(s))):
        res = run_two_phase(testing_system=lambda: mk("fair"),
                            running_system=lambda: mk("greedy"), **durs)
        healthy[name] = res
    assert healthy["engine"].sustainable and healthy["sim"].sustainable
    assert healthy["engine"].running.stall_time() == 0.0
    assert healthy["sim"].running.stall_time() == 0.0
    # both backends measure a testing max bounded by the I/O budget
    for res in healthy.values():
        assert 0.0 < res.max_throughput <= BANDWIDTH_E

    starved = {}
    for name, mk in (("engine", _engine_system), ("sim", _sim_system)):
        res = run_two_phase(
            testing_system=lambda: mk(),
            running_system=lambda: mk(bandwidth_frac=0.125),
            testing_duration=8.0, running_duration=30.0, warmup=1.5)
        starved[name] = res
    for name, res in starved.items():
        assert not res.sustainable, name
        assert len(res.running.stalls) > 0, name
    # verdict agreement is the differential claim
    assert starved["engine"].sustainable == starved["sim"].sustainable
    assert healthy["engine"].sustainable == healthy["sim"].sustainable


def test_realtime_driver_smoke():
    """Wall-clock pacing through the BackgroundDriver: a short real-time
    two-phase run completes with finite, well-formed metrics."""
    def mk():
        return EngineSystem(_engine_factory("greedy"),
                            bandwidth_bytes_per_s=2e6,
                            mem_write_rate=20_000.0, tick_s=0.005,
                            realtime=True)
    res = run_two_phase(testing_system=mk, testing_duration=0.6,
                        running_duration=0.8, warmup=0.1)
    assert res.max_throughput > 0
    assert np.isfinite(res.write_latencies[99])
    for s0, s1 in res.running.stalls:
        assert 0.0 <= s0 <= s1 <= res.running.duration


def test_bursty_cum_entries_integral():
    """The shared arrival abstraction the engine harness integrates per
    tick: the piecewise integral must match the closed form."""
    proc = BurstyArrival(normal_rate=100.0, burst_rate=400.0,
                         normal_s=10.0, burst_s=5.0)
    # one full period: 10 s * 100 + 5 s * 400 = 3000
    assert proc.cum_entries(0.0, 15.0) == pytest.approx(3000.0)
    # straddling segments: [8, 12) = 2 s normal + 2 s burst
    assert proc.cum_entries(8.0, 12.0) == pytest.approx(2 * 100 + 2 * 400)
    assert ConstantArrival(50.0).cum_entries(1.0, 3.0) == pytest.approx(100.0)


def test_read_view_bloom_stack_cached_on_device():
    """The read view's filter stack is a device array synced lazily on
    the first point lookup (PR 5: scan-only workloads never build it)
    and reused by every ``get_batch`` until the next flush/merge — no
    per-probe host re-staging, no per-view restack."""
    import jax

    eng = _engine_factory()()
    rng = np.random.default_rng(3)
    keys = rng.integers(0, UNIQUE, 2000).astype(np.uint32)
    done = 0
    while done < len(keys):
        done += eng.put_batch(keys[done:done + 256],
                              np.arange(min(256, len(keys) - done),
                                        dtype=np.int32))
        eng.pump(256)
    eng.drain()
    view = eng._read_view()
    assert len(view.tables) >= 1
    assert view.filts is None, "filter stack must be lazy (scans-only)"
    eng.get_batch(keys[:64])                  # first point read: sync
    view = eng._read_view()
    assert isinstance(view.filts, jax.Array)
    filts_before = view.filts
    eng.get_batch(keys[64:128])
    assert eng._read_view().filts is filts_before


@pytest.mark.slow
def test_twophase_engine_benchmark_claims():
    """Full engine-grid replay: every claim in the engine-backed
    two-phase benchmark must hold (fair/greedy/single x three policies
    on the real data plane)."""
    from benchmarks.twophase_engine import run

    out = run(quick=True)
    assert all(out["claims"].values()), out["claims"]
