"""Per-architecture smoke tests: reduced config of the same family runs
one forward/train step + prefill + decode on CPU, asserts output shapes
and finiteness (assignment deliverable f)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import (decode_step, init_params, param_count, prefill,
                          train_loss)
from repro.models.config import SHAPES, cell_applicable


def _batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: train_loss(cfg, p, b))(params,
                                                                batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    grads = jax.grad(lambda p: train_loss(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    cache, logits = jax.jit(lambda p, b: prefill(cfg, p, b, 32))(params,
                                                                 batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        cache, logits = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["len"]) == S + 3 + (cfg.n_patches
                                         if cfg.family == "vlm" else 0)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab=50_280,
                            ssm_state=128),
        "gemma-7b": dict(n_layers=28, d_model=3072, n_heads=16,
                         n_kv_heads=16, d_ff=24_576, vocab=256_000),
        "nemotron-4-340b": dict(n_layers=96, d_model=18_432, n_heads=96,
                                n_kv_heads=8, d_ff=73_728, vocab=256_000),
        "llama3-405b": dict(n_layers=126, d_model=16_384, n_heads=128,
                            n_kv_heads=8, d_ff=53_248, vocab=128_256),
        "smollm-135m": dict(n_layers=30, d_model=576, n_heads=9,
                            n_kv_heads=3, d_ff=1_536, vocab=49_152),
        "whisper-base": dict(n_layers=6, n_enc_layers=6, d_model=512,
                             n_heads=8, d_ff=2_048, vocab=51_865),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4_096,
                                     n_heads=32, n_kv_heads=8, d_ff=6_400,
                                     vocab=32_064, n_experts=16, top_k=2),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7_168, n_heads=64,
                                n_kv_heads=8, d_ff=2_048, vocab=163_840,
                                n_experts=384, top_k=8),
        "zamba2-2.7b": dict(n_layers=54, d_model=2_560, n_heads=32,
                            n_kv_heads=32, d_ff=10_240, vocab=32_000,
                            ssm_state=64),
        "paligemma-3b": dict(n_layers=18, d_model=2_048, n_heads=8,
                             n_kv_heads=1, d_ff=16_384, vocab=257_216),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_range():
    """Total params land near the architectures' nameplate sizes."""
    approx = {
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "gemma-7b": (7.0e9, 10.0e9),       # gemma counts exclude embeddings
        "nemotron-4-340b": (300e9, 380e9),
        "llama3-405b": (390e9, 430e9),
        "smollm-135m": (0.12e9, 0.15e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "zamba2-2.7b": (2.2e9, 3.2e9),
        "paligemma-3b": (2.2e9, 3.5e9),    # backbone only (SigLIP stubbed)
    }
    for arch, (lo, hi) in approx.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, (arch, f"{n:.3e}")


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in ARCHS
            if cell_applicable(get_config(a), long)[0]]
    assert sorted(runs) == ["mamba2-1.3b", "zamba2-2.7b"]


def test_input_specs_cover_all_cells():
    """Every applicable (arch x shape) cell has well-formed abstract
    inputs — the dry-run's contract."""
    from repro.train.steps import input_specs
    from repro.models.config import SHAPES
    import jax
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            if not ok:
                assert why, (arch, shape.name)
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            if shape.kind == "decode":
                assert "cache" in specs
                assert specs["tokens"].shape == (shape.global_batch,)
            else:
                assert specs["tokens"].shape[0] == shape.global_batch


def test_effective_microbatches_divisibility():
    from repro.train.steps import effective_microbatches
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ARCHS:
        cfg = get_config(arch)
        for gb in (256, 32, 8):
            mb = effective_microbatches(cfg, mesh, gb)
            assert gb % mb == 0 and mb >= 1
