"""Sharded fleet plane (ISSUE 6): router correctness, arbiter invariants,
and the fleet-vs-single-engine differential.

* An N-shard ``LSMFleet`` replaying any put/get/scan trace returns
  BIT-IDENTICAL results to a single ``LSMEngine`` fed the same trace —
  across the three merge policies (shards hold disjoint key sets, so the
  scan gather is a pure merge-sort and point lookups resolve on exactly
  one shard).
* ``GlobalBudgetArbiter``: ``sum(shard grants) <= global budget`` every
  epoch, no grant beyond a shard's debt, fair proportionality, greedy's
  fewest-remaining-first order, single's FIFO stickiness.
* ``apportion_largest_remainder`` (the helper extracted from
  ``LSMEngine.pump``): full-budget spend, ceiling-share bound, sub-1
  shares topped up.
* ``FleetSystem`` runs the two-phase harness unchanged; fleet-wide stats
  roll up per-shard counters.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.engine import LSMEngine
from repro.core.fleet import (FleetSystem, GlobalBudgetArbiter, LSMFleet)
from repro.core.metrics import rollup_stats
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import (FairScheduler,
                                  apportion_largest_remainder)
from repro.core.twophase import run_two_phase

UNIQUE = 1 << 14


def _factory(policy: str):
    def mk(_shard: int = 0) -> LSMEngine:
        pol = {
            "tiering": lambda: TieringPolicy(3, 256, UNIQUE),
            "leveling": lambda: LevelingPolicy(3, 256, UNIQUE),
            "partitioned": lambda: PartitionedLevelingPolicy(
                4, 256, UNIQUE, file_entries=128, l1_capacity=512),
        }[policy]()
        return LSMEngine(pol, FairScheduler(), None, memtable_entries=256,
                         num_memtables=4, unique_keys=UNIQUE,
                         use_kernels=False)
    return mk


# ------------------------------------------------------- differential
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_fleet_matches_single_engine(policy, n_shards):
    """Replay one random put/get/scan trace against a single engine and
    an N-shard fleet: every get_batch mask/value and every scan_range
    array must be bit-identical, mid-trace (merges in flight on both
    sides) and after drain."""
    seed = {"tiering": 1, "leveling": 2, "partitioned": 3}[policy]
    rng = np.random.default_rng(seed * 10 + n_shards)
    mk = _factory(policy)
    eng = mk()
    fleet = LSMFleet(n_shards, mk, arbiter="fair")

    def check_reads(ctx):
        qs = rng.integers(0, UNIQUE, 512, dtype=np.uint32)
        f1, v1 = eng.get_batch(qs)
        f2, v2 = fleet.get_batch(qs)
        np.testing.assert_array_equal(f1, f2, err_msg=f"found @ {ctx}")
        np.testing.assert_array_equal(v1[f1], v2[f2],
                                      err_msg=f"values @ {ctx}")
        lo = int(rng.integers(0, UNIQUE - 1024))
        span = int(rng.integers(64, 4096))
        k1, x1 = eng.scan_range(lo, lo + span)
        k2, x2 = fleet.scan_range(lo, lo + span)
        np.testing.assert_array_equal(k1, k2, err_msg=f"scan keys @ {ctx}")
        np.testing.assert_array_equal(x1, x2, err_msg=f"scan vals @ {ctx}")

    with fleet:
        for step in range(8):
            keys = rng.integers(0, UNIQUE, 1500, dtype=np.uint32)
            vals = rng.integers(0, 1 << 30, 1500, dtype=np.int32)
            done = 0
            while done < len(keys):
                chunk = len(keys[done:done + 256])
                n = eng.put_batch(keys[done:done + 256],
                                  vals[done:done + 256])
                m = fleet.put_batch(keys[done:done + 256],
                                    vals[done:done + 256])
                # no constraints + per-iteration pump >= chunk: neither
                # side stalls, so the traces stay aligned entry-for-entry
                assert n == chunk and m == chunk, \
                    "fleet admitted differently than the engine"
                done += n
                eng.pump(512)
                fleet.pump(512)     # same GLOBAL budget, arbiter-split
            check_reads(f"mid step {step}")
        eng.drain()
        fleet.drain()
        check_reads("after drain")
        # full-space scan: the complete stores are identical
        k1, x1 = eng.scan_range(0, UNIQUE)
        k2, x2 = fleet.scan_range(0, UNIQUE)
        np.testing.assert_array_equal(k1, k2)
        np.testing.assert_array_equal(x1, x2)


def test_router_scatter_is_stable_and_total():
    """Bucketing covers every key exactly once and preserves issue order
    within a shard (per-key ordering: duplicate keys land on one shard in
    batch order — last write wins)."""
    fleet = LSMFleet(4, _factory("tiering"), parallel=False)
    keys = np.array([7, 9, 7, 7, 12345, 9], np.uint32)
    order, bounds = fleet._scatter(keys)
    assert sorted(order.tolist()) == list(range(len(keys)))
    assert bounds[0] == 0 and bounds[-1] == len(keys)
    sid = fleet.shard_ids(keys)
    for s in range(4):
        idx = order[bounds[s]:bounds[s + 1]]
        assert (sid[idx] == s).all()
        # stability: original positions ascend within the shard bucket
        assert (np.diff(idx) > 0).all() or len(idx) <= 1
    # duplicate keys share a shard
    assert sid[0] == sid[2] == sid[3] and sid[1] == sid[5]


def test_fleet_put_batch_sentinel_atomic():
    fleet = LSMFleet(2, _factory("tiering"), parallel=False)
    keys = np.array([1, 0xFFFFFFFF, 2], np.uint32)
    vals = np.zeros(3, np.int32)
    with pytest.raises(ValueError):
        fleet.put_batch(keys, vals)
    assert fleet.stats["puts"] == 0, "sentinel batch admitted entries"


def test_put_batch_admitted_mask_under_partial_admission():
    """When a shard stalls, the fleet's admitted set is per-shard
    scattered PREFIXES, not a prefix of the caller's batch — the mask
    identifies exactly which keys landed (count-based ``keys[n:]`` retry
    would drop rejected keys and re-send admitted ones)."""
    def tiny(_s: int = 0) -> LSMEngine:
        return LSMEngine(TieringPolicy(3, 256, UNIQUE), FairScheduler(),
                         None, memtable_entries=256, num_memtables=2,
                         unique_keys=UNIQUE, use_kernels=False)

    fleet = LSMFleet(4, tiny, parallel=False)
    rng = np.random.default_rng(5)
    keys = rng.choice(UNIQUE, 4096, replace=False).astype(np.uint32)
    vals = keys.astype(np.int32)
    mask = fleet.put_batch_admitted(keys, vals)   # no pump: shards stall
    assert 0 < mask.sum() < len(keys), "expected a partial admission"
    # per shard, admitted positions form a prefix of that shard's
    # sub-batch in issue order
    sid = fleet.shard_ids(keys)
    for s in range(4):
        m = mask[sid == s]
        assert m[: m.sum()].all() and not m[m.sum():].any(), \
            f"shard {s} admitted a non-prefix"
    fleet.drain()
    found, got = fleet.get_batch(keys)
    np.testing.assert_array_equal(found, mask)
    assert (got[mask] == vals[mask]).all()
    # mask-based retry lands every rejected key, none lost
    rest = ~mask
    while rest.any():
        sel = np.flatnonzero(rest)
        m2 = fleet.put_batch_admitted(keys[sel], vals[sel])
        rest[sel[m2]] = False
        fleet.pump(1024)
    fleet.drain()
    found, got = fleet.get_batch(keys)
    assert found.all()
    np.testing.assert_array_equal(got, vals)


# ------------------------------------------------------- apportionment
@pytest.mark.parametrize("n,budget", [(3, 2), (3, 10), (4, 1), (7, 5),
                                      (2, 101)])
def test_apportion_largest_remainder_exact(n, budget):
    shares = [(i, 1.0 / n) for i in range(n)]
    quanta = apportion_largest_remainder(shares, budget)
    assert sum(quanta) == budget            # nothing silently vanishes
    assert max(quanta) <= -(-budget // n)   # ceiling share
    assert min(quanta) >= budget // n


def test_apportion_partial_shares_capped_by_budget():
    # fractions summing below 1 spend only their rounded total
    quanta = apportion_largest_remainder([(0, 0.25), (1, 0.25)], 10)
    assert sum(quanta) == 5
    assert apportion_largest_remainder([], 10) == []
    assert apportion_largest_remainder([(0, 1.0)], 0) == [0]


# ------------------------------------------------------- arbiter
@pytest.mark.parametrize("policy", GlobalBudgetArbiter.POLICIES)
def test_arbiter_budget_and_debt_invariants(policy):
    """Pinned invariant: every epoch, ``sum(shard budgets) <= global
    budget`` and no shard is granted beyond its pending debt — across
    policies, budgets, and debt shapes (including zero debt)."""
    rng = np.random.default_rng(17)
    arb = GlobalBudgetArbiter(policy)
    for _ in range(200):
        n = int(rng.integers(1, 9))
        debts = rng.integers(0, 5000, n).tolist()
        budget = int(rng.integers(0, 8000))
        grants = arb.allocate(debts, budget)
        assert sum(grants) <= budget
        assert all(g <= d for g, d in zip(grants, debts))
        assert all(g >= 0 for g in grants)
        # when debt can absorb the budget, nothing is stranded (except
        # under "single", which strands leftover past the sticky shard)
        if policy in ("fair", "greedy") and sum(debts) >= budget:
            assert sum(grants) == budget


def test_arbiter_fair_is_proportional():
    grants = GlobalBudgetArbiter("fair").allocate([100, 300, 600], 100)
    assert grants == [10, 30, 60]
    # sub-1 shares still make progress (largest remainder, not floor)
    grants = GlobalBudgetArbiter("fair").allocate([1, 1, 1000], 3)
    assert sum(grants) == 3 and grants[2] >= 1


def test_arbiter_greedy_finishes_smallest_first():
    grants = GlobalBudgetArbiter("greedy").allocate([500, 20, 80], 100)
    assert grants == [0, 20, 80]
    grants = GlobalBudgetArbiter("greedy").allocate([500, 20, 80], 60)
    assert grants == [0, 20, 40]


def test_arbiter_single_is_sticky_fifo():
    arb = GlobalBudgetArbiter("single")
    assert arb.allocate([50, 500], 30) == [30, 0]
    # shard 0 still in debt: stays active even though shard 1 is larger
    assert arb.allocate([20, 500], 30) == [20, 0]
    # shard 0 drained: move to the next shard; leftover strands
    assert arb.allocate([0, 500], 30) == [0, 30]


def test_fleet_pump_respects_global_budget():
    """An engine-level pin of the arbiter invariant: one fleet pump epoch
    never spends more than the global budget, whatever the per-shard
    debt imbalance."""
    fleet = LSMFleet(3, _factory("tiering"), arbiter="fair",
                     parallel=False)
    rng = np.random.default_rng(5)
    with fleet:
        for _ in range(6):
            keys = rng.integers(0, UNIQUE, 1024, dtype=np.uint32)
            vals = rng.integers(0, 1 << 30, 1024, dtype=np.int32)
            fleet.put_batch(keys, vals)
            spent = fleet.pump(100)
            assert spent <= 100, "fleet epoch overspent the global budget"
        # drains to completion under epoch-limited budget
        for _ in range(3000):
            if sum(fleet.pending_debts()) == 0:
                break
            fleet.pump(64)
        assert sum(fleet.pending_debts()) == 0


# ------------------------------------------------------- stats rollup
def test_rollup_stats_sums_counters():
    assert rollup_stats([{"a": 1, "b": 2}, {"a": 3, "c": 4}]) == \
        {"a": 4, "b": 2, "c": 4}
    assert rollup_stats([]) == {}


def test_fleet_stats_rollup_matches_shards():
    fleet = LSMFleet(4, _factory("tiering"), parallel=False)
    rng = np.random.default_rng(11)
    with fleet:
        keys = rng.integers(0, UNIQUE, 4096, dtype=np.uint32)
        vals = rng.integers(0, 1 << 30, 4096, dtype=np.int32)
        done = 0
        while done < len(keys):     # retry across per-shard stalls
            done += fleet.put_batch(keys[done:], vals[done:])
            fleet.pump(1024)
        fleet.drain()
        fleet.get_batch(keys[:256])
        shard = fleet.per_shard_stats()
        total = fleet.stats
        for key in ("puts", "stall_events", "merge_touched", "flushes",
                    "merges", "lookups"):
            assert total[key] == sum(s[key] for s in shard), key
        assert total["puts"] == 4096
        assert total["lookups"] == 256


def test_fleet_write_recorder_fleet_wide_and_per_shard():
    """The fleet recorder sees ONE aggregated (admitted, offered) event
    per batch; per-shard recorders attached to the engines see their
    shard's sub-batch — and the shard counters roll up to the fleet's."""
    from repro.core.metrics import Trace, WriteTraceRecorder
    fleet = LSMFleet(2, _factory("tiering"), parallel=False)
    clock = lambda: 0.5  # noqa: E731
    fleet_rec = WriteTraceRecorder(Trace(duration=1.0), clock, 1000.0)
    shard_recs = [WriteTraceRecorder(Trace(duration=1.0), clock, 1000.0)
                  for _ in fleet.engines]
    fleet.attach_write_recorder(fleet_rec)
    for e, r in zip(fleet.engines, shard_recs):
        e.attach_write_recorder(r)
    with fleet:
        keys = np.arange(512, dtype=np.uint32)
        vals = np.ones(512, np.int32)
        n = fleet.put_batch(keys, vals)
    assert n == 512
    assert fleet_rec.admitted == 512 and fleet_rec.offered == 512
    roll = rollup_stats([r.counters() for r in shard_recs])
    assert roll["admitted"] == 512 and roll["offered"] == 512
    assert all(r.admitted > 0 for r in shard_recs), \
        "a shard saw no traffic — routing is degenerate"


# ------------------------------------------------------- two-phase harness
def test_fleet_system_runs_two_phase():
    """The fleet conforms to TwoPhaseSystem: the paper's two-phase
    harness runs unchanged (deterministic virtual clock, tiny sizes) and
    produces a finite verdict."""
    def fleet_factory():
        return LSMFleet(2, _factory("tiering"), arbiter="fair",
                        parallel=False)

    sys_factory = lambda: FleetSystem(  # noqa: E731
        fleet_factory=fleet_factory, bandwidth_bytes_per_s=400 * 1024,
        mem_write_rate=2000.0, tick_s=0.05, key_space=UNIQUE)
    res = run_two_phase(sys_factory, testing_duration=8.0,
                        running_duration=8.0, warmup=2.0)
    assert res.max_throughput > 0
    assert np.isfinite(res.write_latencies[99])
    assert res.testing.total_written > 0
    assert res.running.total_written > 0
