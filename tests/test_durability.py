"""Durability-plane tests: WAL framing + group commit, snapshot +
budgeted replay, tombstoned deletes through every read path, and the
crash/recover differential (the PR-7 acceptance grid).

The differential contract (see ``core/faults.py``): the WAL logs in
admission order, so after a crash at ANY named point plus a torn tail,
recovery restores a PREFIX of the admitted-write history, and a
reference store fed exactly that prefix must answer every
get/get_batch/scan_range bit-identically.  The fast lane runs one
crash point end to end; the slow lane sweeps every point x every merge
policy x {single engine, 2-shard fleet} x torn-tail fractions.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.checkpoint import EngineSnapshotStore
from repro.core import (CRASH_POINTS, BackgroundDriver, FaultInjector,
                        FleetBackgroundDriver, GlobalBudgetArbiter,
                        LSMEngine, LSMFleet, RecoverySession, SimulatedCrash,
                        TOMBSTONE, WorkloadLog, WriteAheadLog,
                        amplification_stats, apply_entries, apply_torn_tail,
                        assert_reads_equal, recover_engine)
from repro.core import IndexSpec
from repro.core.constraints import GlobalConstraint
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import GreedyScheduler

KEY_SPACE = 2048

# the index-maintenance crash point never fires on a plain single-tree
# engine (it sits between primary admit and index maintenance) — the
# single-tree grids sweep the others; the multi-tree scenario below
# covers it
SINGLE_TREE_CRASH_POINTS = tuple(p for p in CRASH_POINTS
                                 if p != "post-primary-pre-index")


def _mk(policy="tiering", wal=None, faults=None, use_kernels=False,
        memtable=128, **kw):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, KEY_SPACE),
        "leveling": lambda: LevelingPolicy(3, memtable, KEY_SPACE),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, KEY_SPACE, file_entries=64, l1_capacity=256),
    }[policy]()
    kw.setdefault("scan_use_kernels", use_kernels)
    return LSMEngine(pol, GreedyScheduler(), GlobalConstraint(200),
                     memtable_entries=memtable, unique_keys=KEY_SPACE,
                     use_kernels=use_kernels, merge_block=64,
                     wal=wal, faults=faults, **kw)


def _feed(store, log, keys, vals=None, pump=1 << 12):
    """Admit a batch (vals=None -> deletes) through stalls, recording
    the admitted history.  On a SimulatedCrash the unacknowledged
    remainder is appended to the log — the WAL holds at most a prefix
    of it, so ``log.prefix(recovered_lsn)`` stays the exact durable
    history."""
    done = 0
    try:
        while done < len(keys):
            if vals is None:
                n = store.delete_batch(keys[done:])
                log.record_deletes(keys[done:done + n])
            else:
                n = store.put_batch(keys[done:], vals[done:])
                log.record(keys[done:done + n], vals[done:done + n])
            done += n
            if done < len(keys):
                store.pump(pump)
    except SimulatedCrash:
        if vals is None:
            log.record_deletes(keys[done:])
        else:
            log.record(keys[done:], vals[done:])
        raise


# ---------------------------------------------------------------------------
# WAL unit behavior
# ---------------------------------------------------------------------------
class TestWAL:
    def test_append_sync_reopen(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        k = np.arange(10, dtype=np.uint32)
        v = np.arange(10, dtype=np.int32)
        assert wal.append(k, v) == 0
        assert wal.append(k + 10, v + 10) == 10
        assert wal.unsynced_entries == 20
        assert wal.sync() > 0
        assert wal.unsynced_entries == 0 and wal.synced_lsn == 20
        wal.close()
        re = WriteAheadLog(tmp_path / "wal")
        assert re.start_lsn == 0 and re.end_lsn == 20
        ks, vs = re.entries_since(5)
        assert np.array_equal(ks, np.arange(5, 20, dtype=np.uint32))
        assert np.array_equal(vs, np.arange(5, 20, dtype=np.int32))

    def test_torn_tail_truncated_on_open(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        wal.append(np.arange(8, dtype=np.uint32), np.zeros(8, np.int32))
        wal.sync()
        wal.append(np.arange(8, dtype=np.uint32), np.ones(8, np.int32))
        kept = apply_torn_tail(wal, 0.5)      # cuts the unsynced frame
        assert kept > wal.synced_bytes or kept == wal.synced_bytes
        re = WriteAheadLog(tmp_path / "wal")
        assert re.end_lsn == 8                # torn frame dropped whole
        # file was truncated back to the valid prefix on open
        assert (tmp_path / "wal").stat().st_size <= kept

    def test_torn_tail_full_fraction_survives(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        wal.append(np.arange(8, dtype=np.uint32), np.zeros(8, np.int32))
        apply_torn_tail(wal, 1.0)             # whole page cache survived
        assert WriteAheadLog(tmp_path / "wal").end_lsn == 8

    def test_truncate_upto_is_segment_granular(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=5)
        for i in range(4):
            wal.append(np.arange(5, dtype=np.uint32),
                       np.full(5, i, np.int32))   # each fills one segment
        wal.sync()
        segs_before = wal.segments
        assert segs_before >= 4               # rotation actually happened
        wal.truncate_upto(7)                  # LSN 7 straddles segment 1
        # segment 0 (LSNs 0..4) unlinked whole; segment 1 kept whole
        assert wal.start_lsn == 5
        assert wal.segments < segs_before
        ks, vs = wal.entries_since(7)
        assert len(ks) == 13
        re = WriteAheadLog(tmp_path / "wal")
        assert re.start_lsn == 5 and re.end_lsn == 20

    def test_segment_rotation_reopen_and_tail_only_tear(self, tmp_path):
        """Rotated segments chain across reopen; a torn tail only ever
        damages the LAST segment (sealed ones were fsynced at
        rotation)."""
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=8)
        wal.append(np.arange(8, dtype=np.uint32), np.zeros(8, np.int32))
        wal.append(np.arange(8, dtype=np.uint32), np.ones(8, np.int32))
        wal.append(np.arange(6, dtype=np.uint32),
                   np.full(6, 2, np.int32))   # unsynced tail frame
        assert wal.segments == 3
        kept = apply_torn_tail(wal, 0.0)      # page cache lost the tail
        assert kept >= 0
        re = WriteAheadLog(tmp_path / "wal", segment_entries=8)
        assert re.start_lsn == 0 and re.end_lsn == 16   # sealed survive
        ks, vs = re.entries_since(0)
        assert np.array_equal(vs[:8], np.zeros(8, np.int32))
        assert np.array_equal(vs[8:], np.ones(8, np.int32))

    def test_corrupt_frame_ends_valid_prefix(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal")
        wal.append(np.arange(8, dtype=np.uint32), np.zeros(8, np.int32))
        wal.append(np.arange(8, dtype=np.uint32), np.ones(8, np.int32))
        wal.close()
        data = bytearray((tmp_path / "wal").read_bytes())
        data[-3] ^= 0xFF                      # flip a payload byte
        (tmp_path / "wal").write_bytes(bytes(data))
        assert WriteAheadLog(tmp_path / "wal").end_lsn == 8


# ---------------------------------------------------------------------------
# Group commit + budget accounting
# ---------------------------------------------------------------------------
class TestGroupCommit:
    def test_threshold_triggers_sync(self, tmp_path):
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal"),
                  group_commit_entries=64)
        ks = np.arange(63, dtype=np.uint32)
        eng.put_batch(ks, np.ones(63, np.int32))
        assert eng.stats["wal_syncs"] == 0    # below the group threshold
        eng.put_batch(np.array([100], np.uint32), np.array([1], np.int32))
        assert eng.stats["wal_syncs"] == 1
        assert eng.wal.unsynced_entries == 0

    def test_pump_is_an_fsync_epoch(self, tmp_path):
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal"),
                  group_commit_entries=1 << 20)
        eng.put_batch(np.arange(10, dtype=np.uint32), np.ones(10, np.int32))
        assert eng.wal.unsynced_entries == 10
        eng.pump(1 << 12)
        assert eng.wal.unsynced_entries == 0
        assert eng.stats["wal_syncs"] == 1

    def test_wal_traffic_charged_to_budget(self, tmp_path):
        """The synced entries + fixed sync cost land in _flush_debt and
        are repaid from pump budget before any flush/merge work."""
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal"),
                  group_commit_entries=1 << 20, wal_sync_cost=32)
        eng.put_batch(np.arange(50, dtype=np.uint32), np.ones(50, np.int32))
        spent = eng.pump(10)                  # sync charges 50 + 32
        assert spent == 10                    # fully consumed by WAL debt
        assert eng._flush_debt == 50 + 32 - 10
        ref = _mk()                           # no WAL: nothing to repay
        ref.put_batch(np.arange(50, dtype=np.uint32), np.ones(50, np.int32))
        assert ref.pump(10) == 0

    def test_group_commit_reduces_syncs(self, tmp_path):
        def syncs(group):
            eng = _mk(wal=WriteAheadLog(tmp_path / f"wal-{group}"),
                      group_commit_entries=group)
            for i in range(32):
                eng.put_batch(np.full(8, i, np.uint32),
                              np.full(8, i, np.int32))
            return eng.stats["wal_syncs"]
        assert syncs(8) > syncs(128)


# ---------------------------------------------------------------------------
# Tombstoned deletes through every read path (satellite 3)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("kernels", [False, True])
class TestDeletes:
    def _loaded(self, policy, kernels):
        eng = _mk(policy, use_kernels=kernels)
        keys = np.arange(512, dtype=np.uint32)
        _feed(eng, WorkloadLog(), keys, keys.astype(np.int32) + 1)
        _feed(eng, WorkloadLog(), keys[::3])          # delete every 3rd
        return eng, keys

    def test_gets_hide_deleted(self, policy, kernels):
        eng, keys = self._loaded(policy, kernels)
        eng.drain()
        found, vals = eng.get_batch(keys)
        dead = np.zeros(512, bool)
        dead[::3] = True
        assert not found[dead].any()
        assert found[~dead].all()
        assert np.array_equal(vals[~dead], keys[~dead].astype(np.int32) + 1)
        assert eng.get(0) is None and eng.get(1) == 2

    def test_scans_hide_deleted(self, policy, kernels):
        eng, keys = self._loaded(policy, kernels)
        eng.drain()
        sk, sv = eng.scan_range(0, KEY_SPACE)
        assert not np.isin(keys[::3], sk).any()
        live = keys[np.arange(512) % 3 != 0]
        assert np.array_equal(sk, live)
        assert np.array_equal(sv, live.astype(np.int32) + 1)
        # single-run shortcut (post-compaction) filters too
        eng.compact_all()
        sk2, sv2 = eng.scan_range(0, KEY_SPACE)
        assert np.array_equal(sk2, live)
        assert (sv2 != TOMBSTONE).all()

    def test_reinsert_after_delete_visible(self, policy, kernels):
        eng, keys = self._loaded(policy, kernels)
        _feed(eng, WorkloadLog(), keys[::3],
              np.full(len(keys[::3]), 7, np.int32))
        eng.drain()
        found, vals = eng.get_batch(keys[::3])
        assert found.all() and (vals == 7).all()
        sk, sv = eng.scan_range(0, 512)
        assert np.array_equal(sk, keys)       # everything live again


def test_put_rejects_tombstone_value():
    eng = _mk()
    with pytest.raises(ValueError):
        eng.put(1, int(TOMBSTONE))
    with pytest.raises(ValueError):
        eng.put_batch(np.array([1], np.uint32),
                      np.array([TOMBSTONE], np.int32))


def test_tombstones_dropped_at_bottom_space_amp():
    """Acceptance pin: delete everything, compact fully -> live bytes ~0
    (physical entries reclaimed, not just hidden)."""
    eng = _mk("leveling")
    keys = np.arange(1024, dtype=np.uint32)
    _feed(eng, WorkloadLog(), keys, np.ones(1024, np.int32))
    _feed(eng, WorkloadLog(), keys)           # delete all
    eng.drain()
    eng.compact_all()
    amp = eng.amplification()
    assert amp["physical_entries"] == 0       # space released, not hidden
    assert amp["live_entries"] == 0
    assert eng.stats["tombstones_dropped"] >= 1024
    assert amp["write_amp"] > 1.0             # flushes+merges happened


def test_amplification_stats_shape():
    s = {"logical_bytes": 1000, "flush_bytes": 1000, "merge_bytes": 2000,
         "wal_bytes": 1000}
    out = amplification_stats(s, physical_entries=30, live_entries=10)
    assert out["write_amp"] == 4.0
    assert out["space_amp"] == 3.0
    assert "space_amp" not in amplification_stats(s)


# ---------------------------------------------------------------------------
# Snapshot + budgeted replay
# ---------------------------------------------------------------------------
class TestRecovery:
    def _workload(self, tmp_path, policy="tiering", rounds=10, seed=0,
                  faults=None, snapshot_at=5):
        rng = np.random.default_rng(seed)
        eng = _mk(policy,
                  wal=WriteAheadLog(tmp_path / "wal", segment_entries=256),
                  faults=faults, group_commit_entries=96)
        store = EngineSnapshotStore(tmp_path / "snap")
        log = WorkloadLog()
        for r in range(rounds):
            _feed(eng, log, rng.integers(0, KEY_SPACE, 200, dtype=np.uint32),
                  rng.integers(0, 1 << 30, 200, dtype=np.int32))
            _feed(eng, log, rng.integers(0, KEY_SPACE, 40, dtype=np.uint32))
            eng.pump(256)
            if r == snapshot_at:
                eng.snapshot(store)
        return eng, store, log

    def test_snapshot_truncates_wal(self, tmp_path):
        eng, store, log = self._workload(tmp_path)
        before = eng.wal.entries
        segs_before = eng.wal.segments
        eng.drain()
        eng.snapshot(store)
        # whole sealed segments below flushed_lsn dropped; the partially
        # covered segment is kept, so start_lsn trails flushed_lsn by at
        # most one segment
        assert eng.wal.entries < before
        assert eng.wal.segments <= segs_before
        assert eng.wal.start_lsn <= eng.flushed_lsn
        assert eng.flushed_lsn - eng.wal.start_lsn < 256

    def test_recover_clean_shutdown(self, tmp_path):
        eng, store, log = self._workload(tmp_path)
        eng.close()                           # fsync: nothing may be lost
        eng2 = _mk(wal=WriteAheadLog(tmp_path / "wal"))
        recover_engine(eng2, store)
        assert eng2._lsn == log.n
        ref = _mk()
        apply_entries(ref, *log.prefix(log.n))
        assert_reads_equal(eng2, ref, KEY_SPACE)

    def test_recovery_budget_charges_replay(self, tmp_path):
        """Starved bandwidth slows recovery: epochs scale up as the
        per-epoch budget shrinks (WAL replay + induced flushes charge
        the same budget)."""
        eng, store, log = self._workload(tmp_path)
        eng.close()
        def epochs(budget):
            e = _mk(wal=WriteAheadLog(tmp_path / "wal"))
            n = RecoverySession(e, store).run(budget)
            assert e._lsn == log.n
            return n
        fast, slow = epochs(1 << 14), epochs(128)
        assert slow > fast
        assert fast <= 2

    def test_recovery_without_snapshot(self, tmp_path):
        eng, _, log = self._workload(tmp_path, snapshot_at=-1)
        eng.close()
        eng2 = _mk(wal=WriteAheadLog(tmp_path / "wal"))
        recover_engine(eng2)                  # WAL-only recovery
        ref = _mk()
        apply_entries(ref, *log.prefix(log.n))
        assert_reads_equal(eng2, ref, KEY_SPACE)

    def test_mid_snapshot_crash_keeps_previous_manifest(self, tmp_path):
        faults = FaultInjector()
        eng, store, log = self._workload(tmp_path, faults=faults,
                                         snapshot_at=3)
        manifest_before = store.load()
        faults.arm("mid-snapshot")
        eng.drain()
        with pytest.raises(SimulatedCrash):
            eng.snapshot(store)
        assert store.load() == manifest_before   # old view intact
        # and it still recovers consistently from the old snapshot
        apply_torn_tail(eng.wal, 0.0)
        eng2 = _mk(wal=WriteAheadLog(tmp_path / "wal"))
        rec = RecoverySession(eng2, store)
        rec.run(1 << 14)
        ref = _mk()
        apply_entries(ref, *log.prefix(eng2._lsn))
        assert_reads_equal(eng2, ref, KEY_SPACE)


# ---------------------------------------------------------------------------
# Crash differential harness
# ---------------------------------------------------------------------------
def _run_crash_differential(tmp_path, point, policy, torn_frac=0.5,
                            use_kernels=False, seed=0):
    """Run a workload, crash at ``point``, tear the WAL tail, recover,
    and assert the recovered engine reads identically to an uncrashed
    reference fed exactly the recovered durable prefix."""
    rng = np.random.default_rng(seed)
    faults = FaultInjector()
    eng = _mk(policy, wal=WriteAheadLog(tmp_path / "wal"), faults=faults,
              use_kernels=use_kernels, group_commit_entries=96)
    store = EngineSnapshotStore(tmp_path / "snap")
    log = WorkloadLog()

    def round_(r):
        _feed(eng, log, rng.integers(0, KEY_SPACE, 200, dtype=np.uint32),
              rng.integers(0, 1 << 30, 200, dtype=np.int32))
        _feed(eng, log, rng.integers(0, KEY_SPACE, 40, dtype=np.uint32))
        eng.pump(256)
        if r == 3:
            eng.snapshot(store)

    for r in range(5):                         # warm up: tables + snapshot
        round_(r)
    faults.arm(point, after=2)
    crashed = False
    try:
        for r in range(5, 12):
            round_(r)
        if point == "mid-snapshot":
            eng.snapshot(store)
    except SimulatedCrash as e:
        assert e.point == point
        crashed = True
    assert crashed, f"workload never hit crash point {point!r}"

    apply_torn_tail(eng.wal, torn_frac)
    wal2 = WriteAheadLog(tmp_path / "wal")
    eng2 = _mk(policy, wal=wal2, use_kernels=use_kernels)
    RecoverySession(eng2, store).run(1 << 12)
    rec_lsn = eng2._lsn
    assert wal2.synced_lsn <= rec_lsn <= log.n
    ref = _mk(policy, use_kernels=use_kernels)
    apply_entries(ref, *log.prefix(rec_lsn))
    ref.drain()
    assert_reads_equal(eng2, ref, KEY_SPACE,
                       rng=np.random.default_rng(seed))
    return rec_lsn


def test_crash_differential_smoke(tmp_path):
    """Fast-lane single-point crash differential (the full grid is in
    the slow lane below)."""
    _run_crash_differential(tmp_path, "post-wal-pre-memtable", "tiering")


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("point", SINGLE_TREE_CRASH_POINTS)
def test_crash_differential_grid(tmp_path, point, policy):
    for frac in (0.0, 0.5, 1.0):
        d = tmp_path / f"f{int(frac * 10)}"
        d.mkdir()
        _run_crash_differential(d, point, policy, torn_frac=frac,
                                seed=int(frac * 10))


@pytest.mark.slow
def test_crash_differential_kernel_path(tmp_path):
    """One kernel-backed scenario: the Pallas merge path (with fused
    tombstone drop) recovers identically too."""
    _run_crash_differential(tmp_path, "mid-merge-quantum", "leveling",
                            use_kernels=True)


# ---------------------------------------------------------------------------
# Fleet: per-shard WALs, recovery under the global arbiter
# ---------------------------------------------------------------------------
def _mk_fleet(tmp_path, policy="tiering", n_shards=2, faults=None,
              arbiter="fair", tag=""):
    def factory(i):
        return _mk(policy, wal=WriteAheadLog(tmp_path / f"wal{tag}-{i}"),
                   faults=faults, group_commit_entries=96)
    fleet = LSMFleet(n_shards, factory, arbiter=arbiter, parallel=False)
    stores = [EngineSnapshotStore(tmp_path / f"snap{tag}-{i}")
              for i in range(n_shards)]
    return fleet, stores


def _fleet_crash_differential(tmp_path, point, policy, torn_frac=0.5,
                              seed=0):
    """2-shard fleet version: per-shard WALs and WorkloadLogs (the fleet
    scatter is deterministic, so the harness feeds shards directly and
    reads through the fleet router), crash anywhere, recover under the
    GlobalBudgetArbiter, compare against a reference fleet fed each
    shard's durable prefix."""
    rng = np.random.default_rng(seed)
    faults = FaultInjector()
    fleet, stores = _mk_fleet(tmp_path, policy, faults=faults)
    logs = [WorkloadLog() for _ in fleet.engines]

    def scatter_feed(keys, vals=None):
        sid = fleet.shard_ids(keys)
        for s, eng in enumerate(fleet.engines):
            m = sid == s
            if m.any():
                _feed(eng, logs[s], keys[m],
                      None if vals is None else vals[m])

    def round_(r):
        scatter_feed(rng.integers(0, KEY_SPACE, 240, dtype=np.uint32),
                     rng.integers(0, 1 << 30, 240, dtype=np.int32))
        scatter_feed(rng.integers(0, KEY_SPACE, 48, dtype=np.uint32))
        fleet.pump(512)
        if r == 3:
            fleet.snapshot(stores)

    for r in range(5):
        round_(r)
    faults.arm(point, after=2)
    crashed = False
    try:
        for r in range(5, 12):
            round_(r)
        if point == "mid-snapshot":
            fleet.snapshot(stores)
    except SimulatedCrash as e:
        assert e.point == point
        crashed = True
    assert crashed, f"fleet workload never hit {point!r}"

    for eng in fleet.engines:
        apply_torn_tail(eng.wal, torn_frac)
    fleet2, _ = _mk_fleet(tmp_path, policy, tag="")   # reopen same WALs
    epochs = fleet2.recover(stores, budget_per_epoch=1 << 12)
    assert epochs >= 1
    ref, _ = _mk_fleet(tmp_path, policy, tag="-ref")
    for s, eng in enumerate(fleet2.engines):
        assert eng.wal.synced_lsn <= eng._lsn <= logs[s].n
        apply_entries(ref.engines[s], *logs[s].prefix(eng._lsn))
    ref.drain()
    assert_reads_equal(fleet2, ref, KEY_SPACE,
                       rng=np.random.default_rng(seed))
    fleet2.close()
    ref.close()


def test_fleet_crash_differential_smoke(tmp_path):
    _fleet_crash_differential(tmp_path, "pre-flush", "tiering")


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("point", SINGLE_TREE_CRASH_POINTS)
def test_fleet_crash_differential_grid(tmp_path, point, policy):
    _fleet_crash_differential(tmp_path, point, policy,
                              torn_frac=0.5, seed=3)


# ---------------------------------------------------------------------------
# Multi-tree crash: between primary admit and eager index maintenance
# ---------------------------------------------------------------------------
def test_multi_tree_crash_between_primary_and_index(tmp_path):
    """Crash AFTER a chunk's primary admit but BEFORE its eager index
    maintenance: the WAL holds the primary frame without its index
    frames.  Recovery must restore, per tree, exactly the durable frame
    prefix — the primary reads as a consistent history prefix and the
    index tree equals the newest-wins replay of its own logged frames
    (stale by at most the un-maintained chunk, never corrupt)."""
    faults = FaultInjector()
    rng = np.random.default_rng(7)
    eng = _mk(wal=WriteAheadLog(tmp_path / "wal", segment_entries=512),
              faults=faults, group_commit_entries=96,
              indexes=(IndexSpec("by_attr", mode="eager"),))
    log = WorkloadLog()

    def round_():
        _feed(eng, log, rng.integers(0, KEY_SPACE, 200, dtype=np.uint32),
              rng.integers(0, 1 << 20, 200, dtype=np.int32))
        eng.pump(256)

    for _ in range(3):
        round_()
    faults.arm("post-primary-pre-index", after=2)
    with pytest.raises(SimulatedCrash):
        for _ in range(8):
            round_()

    apply_torn_tail(eng.wal, 0.5)
    wal2 = WriteAheadLog(tmp_path / "wal", segment_entries=512)
    eng2 = _mk(wal=wal2, indexes=(IndexSpec("by_attr", mode="eager"),))
    RecoverySession(eng2).run(1 << 12)
    assert wal2.synced_lsn <= eng2._lsn == wal2.end_lsn

    # per-tree newest-wins replay of the durable tree-tagged frames
    state: list[dict[int, int]] = [{}, {}]
    for tree, base, ks, vs in wal2.frames_since(0):
        for k, v in zip(ks.tolist(), vs.tolist()):
            if v == TOMBSTONE:
                state[tree].pop(k, None)
            else:
                state[tree][k] = v

    # primary: bit-identical to the replayed primary frames
    qs = np.arange(KEY_SPACE, dtype=np.uint32)
    found, vals = eng2.get_batch(qs)
    want = np.array([state[0].get(int(k), 0) for k in qs], np.int32)
    assert np.array_equal(found, np.array([int(k) in state[0] for k in qs]))
    assert np.array_equal(vals[found], want[found])

    # eager index tree: exactly its own logged frames (covering scan)
    attrs, pks = eng2.index_scan("by_attr", 0, 1 << 20)
    want_idx = dict(sorted(state[1].items()))
    assert attrs.tolist() == list(want_idx.keys())
    assert pks.tolist() == [v & 0xFFFFFFFF for v in want_idx.values()]


def test_fleet_deletes_and_amplification(tmp_path):
    fleet, _ = _mk_fleet(tmp_path)
    keys = np.arange(1024, dtype=np.uint32)
    # fleet-wide admission is not prefix-shaped: retry by mask, not count
    todo = np.ones(1024, bool)
    while todo.any():
        m = fleet.put_batch_admitted(keys[todo],
                                     np.ones(int(todo.sum()), np.int32))
        todo[np.flatnonzero(todo)[m]] = False
        fleet.pump(1 << 12)
    dead = keys[:512]
    while len(dead):                          # blind deletes are idempotent
        fleet.delete_batch(dead)
        fleet.pump(1 << 12)
        f, _ = fleet.get_batch(dead)
        dead = dead[f]
    fleet.drain()
    found, _ = fleet.get_batch(keys)
    assert not found[:512].any() and found[512:].all()
    sk, sv = fleet.scan_range(0, KEY_SPACE)
    assert np.array_equal(sk, keys[512:])
    assert (sv != TOMBSTONE).all()
    amp = fleet.amplification()
    assert amp["live_entries"] == 512
    assert amp["write_amp"] > 0
    assert fleet.stats["deletes"] >= 512
    fleet.close()


# ---------------------------------------------------------------------------
# Graceful shutdown (satellite 1)
# ---------------------------------------------------------------------------
class TestGracefulShutdown:
    def test_background_driver_close_joins_and_fsyncs(self, tmp_path):
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal"),
                  group_commit_entries=1 << 20)
        with BackgroundDriver(eng, bandwidth_bytes_per_s=64e6) as drv:
            eng.put_batch(np.arange(100, dtype=np.uint32),
                          np.ones(100, np.int32))
            assert drv._thread is not None and drv._thread.is_alive()
        assert drv._thread is None            # joined
        assert eng.wal.unsynced_entries == 0  # close() fsynced
        drv.close()                           # idempotent

    def test_fleet_driver_close(self, tmp_path):
        fleet, _ = _mk_fleet(tmp_path)
        with FleetBackgroundDriver(fleet, bandwidth_bytes_per_s=64e6) as drv:
            fleet.put_batch(np.arange(64, dtype=np.uint32),
                            np.ones(64, np.int32))
        assert drv._thread is None
        for e in fleet.engines:
            assert e.wal.unsynced_entries == 0
        drv.close()

    def test_engine_context_manager(self, tmp_path):
        with _mk(wal=WriteAheadLog(tmp_path / "wal")) as eng:
            eng.put_batch(np.arange(10, dtype=np.uint32),
                          np.ones(10, np.int32))
        assert eng.wal.unsynced_entries == 0
