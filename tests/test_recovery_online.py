"""Online recovery: serve reads and admit writes WHILE replaying the
WAL, with the engine's published consistency contract (see
``core/engine.py``, "Online recovery and the fault-tolerance plane"):

* reads observe exactly ``durable prefix up to the replay watermark +
  live writes`` — nothing more (no un-replayed suffix), nothing less;
* the watermark only advances, and caps every ``flushed_lsn`` claim
  (snapshot truncation can never drop un-replayed WAL);
* live writes go to a FRESH WAL segment (never interleaved with the
  frames being replayed) and win over the replayed history for their
  keys;
* replay is an ordinary pump-driven debt stream arbitrated against
  flush/merge/WAL debt, so a starved budget slows FULL recovery but
  not time-to-first-read.

The differential harness reuses the durability plane's idioms:
``WorkloadLog`` records the admitted history, ``apply_entries`` feeds a
reference store the exact durable prefix + the recorded live writes,
``assert_reads_equal`` compares read planes bit-for-bit — at MID-REPLAY
checkpoints, not just at the end.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.checkpoint import EngineSnapshotStore
from repro.core import (LSMEngine, LSMFleet, RecoverySession, WorkloadLog,
                        WriteAheadLog, apply_entries, apply_torn_tail,
                        assert_reads_equal)
from repro.core.constraints import GlobalConstraint
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import GreedyScheduler

KEY_SPACE = 2048


def _mk(policy="tiering", wal=None, memtable=128, **kw):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, KEY_SPACE),
        "leveling": lambda: LevelingPolicy(3, memtable, KEY_SPACE),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, KEY_SPACE, file_entries=64, l1_capacity=256),
    }[policy]()
    kw.setdefault("scan_use_kernels", False)
    return LSMEngine(pol, GreedyScheduler(), GlobalConstraint(400),
                     memtable_entries=memtable, unique_keys=KEY_SPACE,
                     use_kernels=False, merge_block=64, wal=wal, **kw)


def _feed(store, log, keys, vals=None, pump=1 << 12):
    done = 0
    while done < len(keys):
        if vals is None:
            n = store.delete_batch(keys[done:])
            log.record_deletes(keys[done:done + n])
        else:
            n = store.put_batch(keys[done:], vals[done:])
            log.record(keys[done:done + n], vals[done:done + n])
        done += n
        if done < len(keys):
            store.pump(pump)


def _crashed_workload(tmp_path, policy, torn_frac, seed=0, tag=""):
    """Run a recorded workload (snapshot mid-way), then crash with a
    torn WAL tail.  Returns the admitted-history log."""
    rng = np.random.default_rng(seed)
    eng = _mk(policy, wal=WriteAheadLog(tmp_path / f"wal{tag}"),
              group_commit_entries=96)
    store = EngineSnapshotStore(tmp_path / f"snap{tag}")
    log = WorkloadLog()
    for r in range(10):
        _feed(eng, log, rng.integers(0, KEY_SPACE, 200, dtype=np.uint32),
              rng.integers(0, 1 << 30, 200, dtype=np.int32))
        _feed(eng, log, rng.integers(0, KEY_SPACE, 40, dtype=np.uint32))
        eng.pump(256)
        if r == 4:
            eng.snapshot(store)
    apply_torn_tail(eng.wal, torn_frac)
    return log, store


def _reopen_online(tmp_path, policy, store, tag=""):
    wal = WriteAheadLog(tmp_path / f"wal{tag}")
    eng = _mk(policy, wal=wal, group_commit_entries=96)
    return eng, RecoverySession(eng, store, online=True)


def _reference(policy, log, upto, live=None):
    ref = _mk(policy)
    apply_entries(ref, *log.prefix(upto))
    if live is not None and live.n:
        apply_entries(ref, *live.prefix(live.n))
    ref.drain()
    return ref


# ---------------------------------------------------------------------------
# Contract unit tests
# ---------------------------------------------------------------------------
class TestOnlineContract:
    def test_serves_first_read_before_any_replay(self, tmp_path):
        """Time-to-first-read is the session OPEN, not full recovery:
        with zero replay budget spent, reads equal the snapshot view
        (the durable prefix up to the opening watermark)."""
        log, store = _crashed_workload(tmp_path, "tiering", 0.5)
        eng, sess = _reopen_online(tmp_path, "tiering", store)
        assert not sess.done and sess.remaining > 0
        ref = _reference("tiering", log, sess.watermark)
        assert_reads_equal(eng, ref, KEY_SPACE,
                           rng=np.random.default_rng(0))

    def test_watermark_monotone_and_caps_flushed_lsn(self, tmp_path):
        _, store = _crashed_workload(tmp_path, "tiering", 0.5)
        eng, sess = _reopen_online(tmp_path, "tiering", store)
        assert eng.health()["recovering"] == 1
        assert eng.pending_background_entries() >= sess.remaining
        last = sess.watermark
        while not sess.done:
            eng.pump(128)
            assert sess.watermark >= last, "watermark went backwards"
            last = sess.watermark
            if not sess.done:
                assert eng.flushed_lsn <= sess.watermark, \
                    "flushed_lsn claimed un-replayed WAL"
        assert sess.watermark == sess.replay_end
        assert eng.health()["recovering"] == 0

    def test_live_writes_go_to_a_fresh_segment(self, tmp_path):
        """The fresh-segment rule: live frames never interleave with
        the frames being replayed — the group LSN jumps to the live
        frontier before the first live write."""
        log, store = _crashed_workload(tmp_path, "tiering", 0.5)
        eng, sess = _reopen_online(tmp_path, "tiering", store)
        frontier = max(sess.replay_end, eng.wal.end_lsn)
        assert eng._lsn == frontier
        base = eng.wal.end_lsn
        eng.put_batch(np.array([1, 2], np.uint32),
                      np.array([10, 20], np.int32))
        assert eng.wal.end_lsn == base + 2      # appended past the tail
        assert sess.watermark <= frontier

    def test_live_writes_win_over_replayed_history(self, tmp_path):
        log, store = _crashed_workload(tmp_path, "tiering", 1.0)
        eng, sess = _reopen_online(tmp_path, "tiering", store)
        # overwrite keys that exist in the un-replayed suffix
        ks, vs = log.prefix(log.n)
        suffix_keys = np.unique(ks[sess.watermark:])[:8].astype(np.uint32)
        assert len(suffix_keys), "workload must cover the suffix"
        live_vals = np.arange(len(suffix_keys), dtype=np.int32) + 7_000_000
        assert eng.put_batch(suffix_keys, live_vals) == len(suffix_keys)
        while not sess.done:
            eng.pump(256)
        eng.pump(1 << 16)
        f, v = eng.get_batch(suffix_keys)
        assert f.all()
        assert np.array_equal(v, live_vals), \
            "replayed history clobbered a live write"

    def test_starved_budget_still_serves_reads(self, tmp_path):
        """Replay debt is arbitrated, not prioritized absolutely: a
        tiny budget makes FULL recovery slow (many epochs) while reads
        keep working from epoch zero."""
        log, store = _crashed_workload(tmp_path, "tiering", 0.5)
        eng, sess = _reopen_online(tmp_path, "tiering", store)
        epochs = 0
        probe = np.arange(0, KEY_SPACE, 64, dtype=np.uint32)
        while not sess.done and epochs < 5000:
            eng.pump(48)                        # starved epoch
            eng.get_batch(probe)                # reads never blocked
            epochs += 1
        assert sess.done
        assert epochs > 5, "starved recovery should take many epochs"


# ---------------------------------------------------------------------------
# The serve-during-recovery differential
# ---------------------------------------------------------------------------
def _online_differential(tmp_path, policy, torn_frac, seed=0, tag=""):
    """Crash, reopen ONLINE, interleave live writes with budgeted
    replay, and at mid-replay checkpoints compare every read against a
    reference fed ``log.prefix(watermark) + live writes``."""
    rng = np.random.default_rng(seed)
    log, store = _crashed_workload(tmp_path, policy, torn_frac,
                                   seed=seed, tag=tag)
    eng, sess = _reopen_online(tmp_path, policy, store, tag=tag)
    live = WorkloadLog()
    checks = 0
    epochs = 0
    while not sess.done and epochs < 5000:
        eng.pump(192)
        epochs += 1
        k = rng.integers(0, KEY_SPACE, 12, dtype=np.uint32)
        v = rng.integers(0, 1 << 30, 12, dtype=np.int32)
        n = eng.put_batch(k, v)                 # stalls are fine: record
        live.record(k[:n], v[:n])               # only what was admitted
        if not sess.done and epochs % 3 == 0 and checks < 3:
            ref = _reference(policy, log, sess.watermark, live)
            assert_reads_equal(eng, ref, KEY_SPACE,
                               rng=np.random.default_rng(seed))
            checks += 1
    assert sess.done, "replay never finished"
    assert checks >= 1, "no mid-replay checkpoint was exercised"
    eng.pump(1 << 16)
    ref = _reference(policy, log, sess.replay_end, live)
    assert_reads_equal(eng, ref, KEY_SPACE, rng=np.random.default_rng(seed))


def test_online_differential_smoke(tmp_path):
    """Fast-lane single-combo differential (full grid in the slow
    lane)."""
    _online_differential(tmp_path, "tiering", 0.5)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
def test_online_differential_grid(tmp_path, policy):
    for frac in (0.0, 0.5, 1.0):
        d = tmp_path / f"f{int(frac * 10)}"
        d.mkdir()
        _online_differential(d, policy, frac, seed=int(frac * 10))


# ---------------------------------------------------------------------------
# Fleet: serve during recovery under the global arbiter
# ---------------------------------------------------------------------------
def _fleet_online_differential(tmp_path, policy, torn_frac, seed=0):
    rng = np.random.default_rng(seed)

    def factory(tag):
        def make(i):
            return _mk(policy,
                       wal=WriteAheadLog(tmp_path / f"wal{tag}-{i}"),
                       group_commit_entries=96)
        return make

    fleet = LSMFleet(2, factory(""), parallel=False)
    stores = [EngineSnapshotStore(tmp_path / f"snap-{i}")
              for i in range(2)]
    logs = [WorkloadLog() for _ in fleet.engines]

    def scatter_feed(keys, vals=None):
        sid = fleet.shard_ids(keys)
        for s, eng in enumerate(fleet.engines):
            m = sid == s
            if m.any():
                _feed(eng, logs[s], keys[m],
                      None if vals is None else vals[m])

    for r in range(10):
        scatter_feed(rng.integers(0, KEY_SPACE, 240, dtype=np.uint32),
                     rng.integers(0, 1 << 30, 240, dtype=np.int32))
        scatter_feed(rng.integers(0, KEY_SPACE, 48, dtype=np.uint32))
        fleet.pump(512)
        if r == 4:
            fleet.snapshot(stores)
    for eng in fleet.engines:
        apply_torn_tail(eng.wal, torn_frac)

    fleet2 = LSMFleet(2, factory(""), parallel=False)
    sessions = fleet2.recover(stores, serve_during_recovery=True)
    assert len(sessions) == 2
    assert fleet2.health()["recovering"] >= 1
    lives = [WorkloadLog() for _ in fleet2.engines]

    def reference():
        ref = LSMFleet(2, lambda i: _mk(policy), parallel=False)
        for s, eng in enumerate(ref.engines):
            apply_entries(eng, *logs[s].prefix(sessions[s].watermark))
            if lives[s].n:
                apply_entries(eng, *lives[s].prefix(lives[s].n))
            eng.drain()
        return ref

    checks = 0
    epochs = 0
    while not all(s.done for s in sessions) and epochs < 5000:
        fleet2.pump(384)                        # global budget, arbitrated
        epochs += 1
        k = rng.integers(0, KEY_SPACE, 16, dtype=np.uint32)
        v = rng.integers(0, 1 << 30, 16, dtype=np.int32)
        sid = fleet2.shard_ids(k)
        for s, eng in enumerate(fleet2.engines):
            m = sid == s
            if m.any():
                n = eng.put_batch(k[m], v[m])
                lives[s].record(k[m][:n], v[m][:n])
        if epochs % 4 == 0 and checks < 2 and \
                not all(s.done for s in sessions):
            assert_reads_equal(fleet2, reference(), KEY_SPACE,
                               rng=np.random.default_rng(seed))
            checks += 1
    assert all(s.done for s in sessions), "fleet replay never finished"
    assert fleet2.health()["recovering"] == 0
    fleet2.pump(1 << 16)
    assert_reads_equal(fleet2, reference(), KEY_SPACE,
                       rng=np.random.default_rng(seed))
    assert checks >= 1


def test_fleet_online_differential_smoke(tmp_path):
    _fleet_online_differential(tmp_path, "tiering", 0.5)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
def test_fleet_online_differential_grid(tmp_path, policy):
    for frac in (0.0, 1.0):
        d = tmp_path / f"f{int(frac * 10)}"
        d.mkdir()
        _fleet_online_differential(d, policy, frac, seed=int(frac * 10))
