"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(assignment deliverable c): every Pallas kernel is validated in
interpret mode over a grid of shapes and dtypes."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.attention.ops import attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
from repro.kernels.bloom.ref import bloom_build_ref, bloom_probe_ref
from repro.kernels.merge.ops import (merge_dedup, merge_dedup_kway,
                                     merge_sorted)
from repro.kernels.merge.ref import (merge_dedup_kway_ref, merge_dedup_ref,
                                     merge_sorted_ref)
from repro.kernels.ssd.ops import ssd, ssd_decode_step
from repro.kernels.ssd.ref import ssd_scan_ref


# ---------------------------------------------------------------- merge
@pytest.mark.parametrize("na,nb,block", [
    (100, 100, 64), (1000, 37, 128), (0, 64, 64), (513, 511, 256),
    (2048, 2048, 256),
])
@pytest.mark.parametrize("dtype", [np.uint32, np.int32])
def test_merge_sorted_sweep(na, nb, block, dtype):
    rng = np.random.default_rng(na * 7919 + nb)
    hi = np.iinfo(dtype).max - 1
    ka = np.sort(rng.integers(0, hi, na)).astype(dtype)
    kb = np.sort(rng.integers(0, hi, nb)).astype(dtype)
    va = rng.integers(0, 1 << 30, na).astype(np.int32)
    vb = rng.integers(0, 1 << 30, nb).astype(np.int32)
    mk, mv, ms, valid = merge_sorted(jnp.asarray(ka), jnp.asarray(va),
                                     jnp.asarray(kb), jnp.asarray(vb),
                                     block=block)
    rk, rv, rs = merge_sorted_ref(jnp.asarray(ka), jnp.asarray(va),
                                  jnp.asarray(kb), jnp.asarray(vb))
    assert valid == na + nb
    np.testing.assert_array_equal(np.asarray(mk)[:valid], np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(mv)[:valid], np.asarray(rv))


@pytest.mark.parametrize("na,nb", [(128, 128), (1000, 333), (47, 2000)])
def test_merge_dedup_matches_dict_oracle(na, nb):
    rng = np.random.default_rng(na + nb)
    # force heavy key overlap so dedup matters
    ka = np.sort(rng.choice(max(na, nb) * 2, na, replace=False)).astype(
        np.uint32)
    kb = np.sort(rng.choice(max(na, nb) * 2, nb, replace=False)).astype(
        np.uint32)
    va = rng.integers(0, 1 << 30, na).astype(np.int32)
    vb = rng.integers(0, 1 << 30, nb).astype(np.int32)
    mk, mv, keep, valid = merge_dedup(jnp.asarray(ka), jnp.asarray(va),
                                      jnp.asarray(kb), jnp.asarray(vb),
                                      block=128)
    keep = np.array(keep)
    keep[valid:] = False
    rk, rv = merge_dedup_ref(ka, va, kb, vb)
    np.testing.assert_array_equal(np.asarray(mk)[keep], rk)
    np.testing.assert_array_equal(np.asarray(mv)[keep], rv)


def _mk_runs(rng, sizes, key_space):
    runs = []
    for n in sizes:
        ks = np.sort(rng.choice(key_space, n, replace=False)).astype(
            np.uint32)
        vs = rng.integers(0, 1 << 30, n).astype(np.int32)
        runs.append((ks, vs))
    return runs


@pytest.mark.parametrize("sizes,block", [
    ((100, 80), 64),                 # k=2: degenerates to the pairwise path
    ((64, 0, 200), 64),              # empty run dropped
    ((33, 128, 7, 255, 64), 128),    # odd k: carry-over leg
    ((100,) * 8, 64),                # balanced 3-round tournament
    ((50,), 64),                     # k=1 passthrough
])
def test_merge_dedup_kway_matches_dict_oracle(sizes, block):
    rng = np.random.default_rng(sum(sizes))
    runs = _mk_runs(rng, sizes, max(sizes) * 2 + 1)   # heavy key overlap
    mk, mv = merge_dedup_kway(runs, block=block)
    rk, rv = merge_dedup_kway_ref(runs)
    np.testing.assert_array_equal(np.asarray(mk), rk)
    np.testing.assert_array_equal(np.asarray(mv), rv)


def test_merge_dedup_kway_equals_pairwise_fold():
    """The balanced tournament must equal the sequential pairwise fold
    (oldest -> newest, newer run as A) it replaces in the engine."""
    rng = np.random.default_rng(9)
    runs = _mk_runs(rng, (120, 90, 255, 33, 64, 128), 400)
    mk, mv = merge_dedup_kway(runs, block=64)

    fk, fv = (jnp.asarray(runs[-1][0]), jnp.asarray(runs[-1][1]))
    for ks, vs in reversed(runs[:-1]):     # fold oldest->newest, newer = A
        k2, v2, keep, valid = merge_dedup(jnp.asarray(ks), jnp.asarray(vs),
                                          fk, fv, block=64)
        keep = np.array(keep)
        keep[valid:] = False
        fk, fv = jnp.asarray(np.asarray(k2)[keep]), \
            jnp.asarray(np.asarray(v2)[keep])
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(fk))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(fv))


def test_merge_dedup_kway_duplicate_heavy():
    """Every run holds the SAME key set: output is run 0 verbatim (the
    newest version of every key), the hardest dedup case for the
    age-carrying tournament."""
    rng = np.random.default_rng(4)
    ks = np.sort(rng.choice(2048, 300, replace=False)).astype(np.uint32)
    runs = [(ks, rng.integers(0, 1 << 30, 300).astype(np.int32))
            for _ in range(5)]
    mk, mv = merge_dedup_kway(runs, block=64)
    np.testing.assert_array_equal(np.asarray(mk), ks)
    np.testing.assert_array_equal(np.asarray(mv), runs[0][1])


# ---------------------------------------------------------------- bloom
@pytest.mark.parametrize("n,fpr", [(64, 0.01), (1000, 0.01), (5000, 0.05)])
def test_bloom_sweep(n, fpr):
    rng = np.random.default_rng(n)
    keys = rng.choice(1 << 24, n, replace=False).astype(np.uint32)
    n_bits, k = filter_params(n, fpr)
    filt = bloom_build(jnp.asarray(keys), n_bits, k)
    # kernel probe == numpy oracle on both present and absent keys
    absent = np.setdiff1d(
        rng.choice(1 << 24, 3 * n, replace=False).astype(np.uint32), keys)
    for qs in (keys, absent[:n]):
        got = np.asarray(bloom_probe(filt, jnp.asarray(qs), n_bits, k))
        bits = bloom_build_ref(keys, n_bits, k)
        want = bloom_probe_ref(bits, qs, n_bits, k)
        np.testing.assert_array_equal(got, want)
    # no false negatives; fp rate near target
    present = np.asarray(bloom_probe(filt, jnp.asarray(keys), n_bits, k))
    assert present.all()
    fp = np.mean(np.asarray(bloom_probe(filt, jnp.asarray(absent[:2000]),
                                        n_bits, k)))
    assert fp <= max(3 * fpr, 0.02)


def test_bloom_probe_multi_equals_per_table():
    """The fused stacked probe (heterogeneous filter geometry, zero-padded
    to a common word count) returns exactly the per-table probe rows, with
    no false negatives on each table's own keys."""
    from repro.kernels.bloom.ops import bloom_probe_multi, stack_filters
    rng = np.random.default_rng(0)
    tables = []
    for n, fpr in ((17, 0.01), (260, 0.05), (2048, 0.01), (900, 0.02)):
        keys = rng.choice(1 << 22, n, replace=False).astype(np.uint32)
        n_bits, k = filter_params(n, fpr)
        filt = bloom_build(jnp.asarray(keys), n_bits, k)
        tables.append((keys, filt, n_bits, k))
    filts, meta = stack_filters([t[1] for t in tables],
                                [t[2] for t in tables],
                                [t[3] for t in tables])
    assert filts.shape[1] == max(t[1].shape[0] for t in tables)
    qs = rng.integers(0, 1 << 22, 513, dtype=np.uint32)   # non-block-aligned
    multi = bloom_probe_multi(filts, meta, qs)
    assert multi.shape == (len(tables), len(qs))
    for i, (keys, filt, n_bits, k) in enumerate(tables):
        single = np.asarray(bloom_probe(filt, jnp.asarray(qs), n_bits, k))
        np.testing.assert_array_equal(multi[i], single)
        own = bloom_probe_multi(filts, meta, keys)
        assert own[i].all(), f"false negative in table {i}"
    # degenerate shapes
    assert bloom_probe_multi(filts[:0], meta[:0], qs).shape == (0, len(qs))
    empty_q = np.empty(0, np.uint32)
    assert bloom_probe_multi(filts, meta, empty_q).shape == (len(tables), 0)


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk", [
    (1, 2, 1, 64, 16, 32, 32),
    (2, 4, 2, 128, 32, 64, 64),
    (1, 8, 8, 96, 16, 64, 32),      # MHA, non-multiple seq
    (2, 4, 1, 128, 64, 128, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_sweep(B, H, Hkv, S, D, bq, bk, dtype):
    key = jax.random.PRNGKey(B * 100 + S)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = attention(q, k, v, causal=True, bq=bq, bk=bk)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 ref.astype(jnp.float32)))) < tol


# ------------------------------------------------------------------- ssd
@pytest.mark.parametrize("BH,L,P,N,chunk", [
    (1, 64, 8, 4, 16), (2, 100, 16, 8, 32), (3, 256, 32, 16, 64),
])
def test_ssd_sweep(BH, L, P, N, chunk):
    rng = np.random.default_rng(L)
    x = jnp.asarray(rng.standard_normal((BH, L, P)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    alog = jnp.asarray(-np.abs(rng.standard_normal((BH, L))) * 0.2,
                       jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((BH, L))) * 0.2,
                     jnp.float32)
    y = ssd(x, b, c, alog, dt, chunk=chunk)
    ref = ssd_scan_ref(x, b, c, alog, dt)
    assert float(jnp.max(jnp.abs(y - ref))) < 2e-3


def test_ssd_decode_matches_scan():
    """Sequential decode steps reproduce the chunked scan exactly."""
    rng = np.random.default_rng(0)
    BH, L, P, N = 2, 24, 8, 4
    x = jnp.asarray(rng.standard_normal((BH, L, P)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((BH, L, N)), jnp.float32)
    alog = jnp.asarray(-np.abs(rng.standard_normal((BH, L))) * 0.2,
                       jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((BH, L))) * 0.2,
                     jnp.float32)
    y_scan = ssd(x, b, c, alog, dt, chunk=8)
    state = jnp.zeros((BH, N, P), jnp.float32)
    outs = []
    for t in range(L):
        state, y_t = ssd_decode_step(state, x[:, t], b[:, t], c[:, t],
                                     alog[:, t], dt[:, t])
        outs.append(y_t)
    y_seq = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(y_scan - y_seq))) < 2e-3


# --------------------------------------------------------- paged attention
@pytest.mark.parametrize("B,Hkv,G,D,page,n_pages,max_pages", [
    (2, 1, 1, 16, 4, 16, 4),
    (3, 2, 4, 16, 8, 32, 6),
    (1, 4, 2, 32, 16, 24, 8),
])
def test_paged_attention_sweep(B, Hkv, G, D, page, n_pages, max_pages):
    from repro.kernels.paged_attention.paged_attention import \
        paged_attention_kernel
    from repro.kernels.paged_attention.ref import paged_attention_ref
    rng = np.random.default_rng(B * 7 + page)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    tables = jnp.asarray(np.stack([
        rng.choice(n_pages, max_pages, replace=False) for _ in range(B)]),
        jnp.int32)
    lens = jnp.asarray(rng.integers(1, max_pages * page, B), jnp.int32)
    out = paged_attention_kernel(q, kp, vp, tables, lens)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_paged_attention_matches_contiguous():
    """Paged result == dense decode attention over the gathered cache."""
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.models.layers import decode_attention_jnp
    rng = np.random.default_rng(3)
    B, Hkv, G, D, page, mp = 2, 2, 2, 16, 8, 4
    n_pages = B * mp
    q = jnp.asarray(rng.standard_normal((B, G * Hkv, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, Hkv, page, D)),
                     jnp.float32)
    tables = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
    lens = jnp.asarray([13, 29], jnp.int32)
    out = paged_decode_attention(q, kp, vp, tables, lens)
    # contiguous cache: (B, Hkv, S, D)
    kc = kp[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, mp * page, D)
    vc = vp[tables].transpose(0, 2, 1, 3, 4).reshape(B, Hkv, mp * page, D)
    for b in range(B):
        ref = decode_attention_jnp(q[b:b + 1, :, None], kc[b:b + 1],
                                   vc[b:b + 1], lens[b])[:, :, 0]
        assert float(jnp.max(jnp.abs(out[b:b + 1] - ref))) < 2e-5
