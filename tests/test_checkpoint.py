"""Checkpoint store: atomic manifests, newest-wins restore, compaction
equivalence, elastic reshard, exact train-resume."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import LSMCheckpointStore, flatten_state
from repro.checkpoint.restore import restore_state
from repro.core.constraints import GlobalConstraint
from repro.core.policies import TieringPolicy
from repro.core.scheduler import GreedyScheduler


def _store(tmp_path, max_comps=8):
    return LSMCheckpointStore(
        tmp_path, policy=TieringPolicy(3, 1, 1e9),
        scheduler=GreedyScheduler(),
        constraint=GlobalConstraint(max_comps))


def test_put_restore_roundtrip(tmp_path):
    store = _store(tmp_path / "s")
    rng = np.random.default_rng(0)
    want = {}
    for step in range(6):
        delta = {f"layer{i}/w": rng.standard_normal(32).astype(np.float32)
                 for i in range(3)}
        want.update(delta)
        assert store.put_delta(step, delta)
    state, last = restore_state(store)
    assert last == 5
    for i in range(3):
        np.testing.assert_array_equal(state[f"layer{i}"]["w"],
                                      want[f"layer{i}/w"])


def test_compaction_preserves_newest_wins(tmp_path):
    store = _store(tmp_path / "s")
    rng = np.random.default_rng(1)
    latest = {}
    for step in range(12):
        delta = {"w": rng.standard_normal(64).astype(np.float32)}
        latest = delta
        store.put_delta(step, delta)
        store.pump(1e12)
    assert store.stats["compactions"] > 0
    state, last = restore_state(store)
    np.testing.assert_array_equal(state["w"], latest["w"])
    assert last == 11


def test_constraint_stalls_checkpoints(tmp_path):
    store = _store(tmp_path / "s", max_comps=3)
    ok = [store.put_delta(s, {"w": np.ones(8, np.float32)})
          for s in range(10)]                       # never pumped
    assert not all(ok), "component constraint should stall delta puts"
    store.drain()
    assert store.num_components() <= 3


def test_manifest_survives_restart(tmp_path):
    root = tmp_path / "s"
    store = _store(root)
    for step in range(5):
        store.put_delta(step, {"w": np.full(16, step, np.float32)})
    del store
    store2 = _store(root)                            # fresh process view
    state, last = restore_state(store2)
    assert last == 4
    np.testing.assert_array_equal(state["w"], np.full(16, 4, np.float32))
    store2.pump(1e12)                                # compaction still works
    state3, _ = restore_state(store2)
    np.testing.assert_array_equal(state3["w"], state["w"])


def test_bf16_roundtrip(tmp_path):
    import ml_dtypes
    store = _store(tmp_path / "s")
    arr = np.arange(16, dtype=np.float32).astype(ml_dtypes.bfloat16)
    store.put_delta(0, {"w": arr})
    state, _ = restore_state(store)
    assert state["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(state["w"], arr)


def test_train_resume_exact(tmp_path):
    """Save at step k, restore, and verify params match bit-exactly."""
    from repro.configs import get_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.train.steps import init_train_state, train_state_axes
    from repro.checkpoint.restore import reshard_restore

    cfg = get_smoke("smollm-135m")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    store = _store(tmp_path / "s")
    host = jax.tree.map(np.asarray, state)
    store.put_delta(7, flatten_state(host))
    mesh = make_host_mesh()
    restored, last = reshard_restore(store, mesh, train_state_axes(cfg))
    assert last == 7
    for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
