"""Real-engine integration + hypothesis property tests: the LSM engine
(Pallas data plane + paper scheduling plane) is always equivalent to a
plain dict under newest-wins semantics."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.constraints import GlobalConstraint
from repro.core.engine import LSMEngine
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 SizeTieredPolicy, TieringPolicy)
from repro.core.scheduler import (FairScheduler, GreedyScheduler,
                                  SingleThreadedScheduler)


def _mk(policy: str, sched: str, memtable=128, unique=2048):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, unique),
        "leveling": lambda: LevelingPolicy(3, memtable, unique),
        "size_tiered": lambda: SizeTieredPolicy(1.2, memtable, unique),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, unique, file_entries=64, l1_capacity=256),
    }[policy]()
    sch = {"single": SingleThreadedScheduler, "fair": FairScheduler,
           "greedy": GreedyScheduler}[sched]()
    return LSMEngine(pol, sch, GlobalConstraint(200),
                     memtable_entries=memtable, unique_keys=unique,
                     use_kernels=True, merge_block=64)


@pytest.mark.parametrize("policy", ["tiering", "leveling", "size_tiered",
                                    "partitioned"])
@pytest.mark.parametrize("sched", ["single", "fair", "greedy"])
def test_engine_matches_dict(policy, sched):
    rng = np.random.default_rng(42)
    eng = _mk(policy, sched)
    ref = {}
    for i in range(2500):
        k = int(rng.integers(0, 2048))
        v = int(rng.integers(0, 1 << 30))
        while not eng.put(k, v):
            eng.pump(256)
        ref[k] = v
        if i % 50 == 0:
            eng.pump(128)
    eng.drain()
    for k in rng.choice(2048, 200, replace=False):
        assert eng.get(int(k)) == ref.get(int(k)), (policy, sched, k)
    lo, hi = 300, 500
    want = {k: v for k, v in ref.items() if lo <= k < hi}
    sk, sv = eng.scan_range(lo, hi)           # sorted-array contract
    assert (np.diff(sk.astype(np.int64)) > 0).all()
    assert dict(zip(sk.tolist(), sv.tolist())) == want
    assert eng.scan_range_dict(lo, hi) == want


@settings(max_examples=20, deadline=None)
@given(
    ops=st.lists(st.tuples(st.integers(0, 255), st.integers(0, 1 << 20)),
                 min_size=1, max_size=400),
    pump_every=st.integers(5, 60),
    policy=st.sampled_from(["tiering", "leveling", "size_tiered"]),
)
def test_engine_newest_wins_property(ops, pump_every, policy):
    """Invariant: after any write sequence + any pump schedule, the engine
    equals a dict (newest write per key wins, nothing lost)."""
    eng = _mk(policy, "greedy", memtable=32, unique=256)
    ref = {}
    for i, (k, v) in enumerate(ops):
        while not eng.put(k, v):
            eng.pump(64)
        ref[k] = v
        if i % pump_every == 0:
            eng.pump(48)
    eng.drain()
    for k in ref:
        assert eng.get(k) == ref[k]
    assert eng.scan_range_dict(0, 256) == ref


@settings(max_examples=15, deadline=None)
@given(budgets=st.lists(st.integers(1, 400), min_size=1, max_size=30))
def test_engine_pump_budget_invariant(budgets):
    """Background I/O spent per pump never exceeds the handed budget
    (+1 flush granule) — the bandwidth-throttling contract."""
    eng = _mk("tiering", "fair", memtable=64, unique=512)
    rng = np.random.default_rng(0)
    for k in rng.integers(0, 512, 900):
        while not eng.put(int(k), 1):
            eng.pump(64)
    for b in budgets:
        spent = eng.pump(b)
        assert spent <= b + eng.memtable_entries


def test_component_constraint_stalls_writes():
    eng = _mk("tiering", "fair", memtable=32, unique=512)
    eng.constraint = GlobalConstraint(2)
    rng = np.random.default_rng(1)
    stalled = False
    for k in rng.integers(0, 512, 2000):
        if not eng.put(int(k), 1):
            stalled = True
            if eng.stalled:
                break
            eng.pump(32)
    assert stalled, "constraint never produced a write stall"


def test_background_driver_thread():
    """The wall-clock driver pumps the engine concurrently with writes."""
    import time
    from repro.core.engine import BackgroundDriver
    eng = _mk("tiering", "greedy", memtable=64, unique=1024)
    drv = BackgroundDriver(eng, bandwidth_bytes_per_s=4e6, quantum_s=0.002)
    drv.start()
    rng = np.random.default_rng(0)
    ref = {}
    try:
        for i in range(1500):
            k = int(rng.integers(0, 1024))
            v = int(rng.integers(0, 1 << 30))
            deadline = time.time() + 10
            while True:
                with eng.lock():          # exclude the pump thread
                    ok = eng.put(k, v)
                if ok:
                    break
                time.sleep(0.002)
                assert time.time() < deadline, "driver failed to drain"
            ref[k] = v
    finally:
        drv.stop()
    eng.drain()
    for k in list(ref)[:100]:
        assert eng.get(k) == ref[k]
