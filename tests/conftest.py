"""Shared test fixtures/shims.

If ``hypothesis`` is unavailable (the minimal CI/container image), install
a stub module that turns every ``@given`` test into a clean skip instead
of erroring the whole collection — the non-property tests still run.
"""
from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        return lambda fn: _skip(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    def assume(*_a, **_k):  # noqa: ARG001 - signature compatibility
        return True

    class _Strategy:
        """Chainable stand-in: any strategy call returns another stub."""

        def __call__(self, *_a, **_k):
            return _Strategy()

        def __getattr__(self, _name):
            return _Strategy()

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.__getattr__ = lambda _name: _Strategy()

    class _AnyAttr:
        def __getattr__(self, _name):
            return None

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st_mod
    hyp.HealthCheck = _AnyAttr()

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
