"""Differential tests for the vectorized batch read/write plane: the
batch paths (``get_batch``, bulk ``put_batch``, fused multi-table Bloom
probe) must be semantically identical to the scalar paths they replace —
newest-wins resolution, stall/accept counts, and bloom no-false-negatives.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.constraints import GlobalConstraint, NoConstraint
from repro.core.engine import LSMEngine
from repro.core.memtable import MemTable
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 SizeTieredPolicy, TieringPolicy)
from repro.core.scheduler import FairScheduler, GreedyScheduler


def _mk(policy: str, memtable=128, unique=2048, constraint=200):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, unique),
        "leveling": lambda: LevelingPolicy(3, memtable, unique),
        "size_tiered": lambda: SizeTieredPolicy(1.2, memtable, unique),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, unique, file_entries=64, l1_capacity=256),
    }[policy]()
    return LSMEngine(pol, GreedyScheduler(), GlobalConstraint(constraint),
                     memtable_entries=memtable, unique_keys=unique,
                     use_kernels=True, merge_block=64)


def _seed_scalar_put_batch(eng: LSMEngine, keys, values) -> int:
    """The seed's per-entry admission loop — the semantic oracle for the
    vectorized ``put_batch``."""
    keys = np.asarray(keys)
    values = np.asarray(values)
    n_ok = 0
    for i in range(len(keys)):
        if not eng.put(int(keys[i]), int(values[i])):
            break
        n_ok += 1
    return n_ok


# --------------------------------------------------------------- reads
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
def test_get_batch_equals_scalar_get(policy):
    """Random workload with duplicate keys across memtables and
    merged/unmerged tables: get_batch == per-key get == dict oracle, both
    mid-stream (memtables populated) and after drain."""
    rng = np.random.default_rng(11)
    eng = _mk(policy)
    ref = {}
    for i in range(2500):
        k = int(rng.integers(0, 1024))       # heavy key reuse
        v = int(rng.integers(0, 1 << 30))
        while not eng.put(k, v):
            eng.pump(256)
        ref[k] = v
        if i % 40 == 0:
            eng.pump(96)
    for phase in ("mid", "drained"):
        qs = rng.integers(0, 2048, 400, dtype=np.uint32)  # hits + misses
        found, vals = eng.get_batch(qs)
        for i, k in enumerate(qs):
            want = ref.get(int(k))
            got = int(vals[i]) if found[i] else None
            assert got == want, (phase, int(k), got, want)
            assert eng.get(int(k)) == want, (phase, int(k))
        eng.drain()


def test_get_batch_sees_fresh_tables_after_flush_and_merge():
    """Read-view invalidation: lookups reflect every flush/merge
    completion, never a stale snapshot."""
    eng = _mk("tiering", memtable=32, unique=256)
    for v, pump in ((1, 0), (2, 64), (3, 512)):
        n = eng.put_batch(np.arange(32, dtype=np.uint32),
                          np.full(32, v, np.int32))
        assert n == 32
        eng._seal_active()
        if pump:
            eng.pump(pump)
        found, vals = eng.get_batch(np.arange(32, dtype=np.uint32))
        assert found.all() and (vals == v).all(), v
    eng.drain()
    found, vals = eng.get_batch(np.arange(32, dtype=np.uint32))
    assert found.all() and (vals == 3).all()


def test_scan_and_get_agree_on_ordering():
    """The unified read-view ordering: a full-range scan must equal the
    per-key point lookups for every live key, including under merges."""
    rng = np.random.default_rng(5)
    eng = _mk("size_tiered", memtable=64, unique=512)
    ref = {}
    for i in range(1500):
        k, v = int(rng.integers(0, 512)), int(rng.integers(0, 1 << 30))
        while not eng.put(k, v):
            eng.pump(128)
        ref[k] = v
        if i % 30 == 0:
            eng.pump(64)
    scan = eng.scan_range_dict(0, 512)
    assert scan == ref
    keys = np.fromiter(ref, dtype=np.uint32)
    found, vals = eng.get_batch(keys)
    assert found.all()
    assert {int(k): int(v) for k, v in zip(keys, vals)} == ref


# --------------------------------------------------------------- writes
@pytest.mark.parametrize("constraint", [2, 6, 200])
def test_put_batch_accept_count_equals_scalar(constraint):
    """Bulk admission accepts exactly as many entries as the seed scalar
    loop under identical stall constraints, across pump interleavings."""
    rng = np.random.default_rng(7)
    batches = [rng.integers(0, 512, int(n)) for n in
               rng.integers(1, 300, 12)]
    vals = [np.arange(len(b), dtype=np.int32) for b in batches]
    pumps = rng.integers(0, 128, len(batches))

    def run(bulk: bool) -> tuple[list[int], int, int]:
        eng = _mk("tiering", memtable=32, unique=512,
                  constraint=constraint)
        accepted = []
        for b, v, p in zip(batches, vals, pumps):
            if bulk:
                accepted.append(eng.put_batch(b, v))
            else:
                accepted.append(_seed_scalar_put_batch(eng, b, v))
            if p:
                eng.pump(int(p))
        return accepted, eng.stats["puts"], eng.total_entries()

    acc_bulk, puts_bulk, tot_bulk = run(bulk=True)
    acc_scalar, puts_scalar, tot_scalar = run(bulk=False)
    assert acc_bulk == acc_scalar
    assert puts_bulk == puts_scalar
    assert tot_bulk == tot_scalar


def test_put_batch_resumes_after_pump():
    """A stalled bulk admission accepts 0, then proceeds once background
    I/O frees a memtable — same contract as the scalar path."""
    eng = _mk("tiering", memtable=32, unique=512)
    keys = np.arange(100, dtype=np.uint32)
    vals = np.arange(100, dtype=np.int32)
    n1 = eng.put_batch(keys, vals)
    assert n1 == 64                       # 2 memtables x 32
    assert eng.put_batch(keys[n1:], vals[n1:]) == 0
    eng.pump(64)                          # flush a sealed memtable
    n2 = eng.put_batch(keys[n1:], vals[n1:])
    assert n2 > 0
    eng.drain()
    found, got = eng.get_batch(keys[:n1 + n2])
    assert found.all() and (got == vals[:n1 + n2]).all()


def test_memtable_put_batch_reports_fit():
    """MemTable.put_batch admits the prefix that fits and reports the
    count instead of raising on overflow."""
    mt = MemTable(10)
    assert mt.put_batch(np.arange(6), np.arange(6)) == 6
    assert mt.put_batch(np.arange(100, 108), np.arange(8)) == 4
    assert len(mt) == 10 and mt.full
    assert mt.put_batch(np.arange(3), np.arange(3)) == 0
    with pytest.raises(ValueError):
        mt.put_batch(np.array([0xFFFFFFFF], np.uint32), np.array([0]))
    f, v = mt.get_batch(np.array([0, 100, 103, 99], np.uint32))
    assert f.tolist() == [True, True, True, False]
    assert v[0] == 0 and v[1] == 0 and v[2] == 3


def test_memtable_get_batch_newest_wins():
    mt = MemTable(8)
    mt.put(5, 1)
    mt.put(5, 2)
    mt.put_batch(np.array([5, 7]), np.array([3, 9]))
    f, v = mt.get_batch(np.array([5, 7, 6], np.uint32))
    assert f.tolist() == [True, True, False]
    assert v[0] == 3 and v[1] == 9


def test_leveling_concurrent_merges_stay_age_adjacent():
    """Regression: the bLSM swap semantics could pair a frozen run with an
    age-NON-adjacent resident (skipping a fresher sibling elsewhere in the
    tree), making stamp-ordered reads return stale values.  This workload
    produced ~100 stale keys before the age-adjacency guard in
    ``LevelingPolicy.collect_merges``."""
    rng = np.random.default_rng(0)
    eng = LSMEngine(LevelingPolicy(3, 64, 1024), GreedyScheduler(),
                    GlobalConstraint(200), memtable_entries=64,
                    unique_keys=1024, use_kernels=False)
    ref = {}
    for i in range(2000):
        k, v = int(rng.integers(0, 1024)), int(rng.integers(0, 1 << 30))
        while not eng.put(k, v):
            eng.pump(128)
        ref[k] = v
        if i % 40 == 0:
            eng.pump(96)
    eng.drain()
    keys = np.fromiter(ref, dtype=np.uint32)
    found, vals = eng.get_batch(keys)
    assert found.all()
    assert dict(zip(keys.tolist(), vals.tolist())) == ref


# --------------------------------------------------- interpret plumbing
def test_interpret_flag_plumbed_to_tables():
    eng = _mk("tiering", memtable=32, unique=256)
    assert eng.interpret is True
    eng.put_batch(np.arange(32, dtype=np.uint32), np.zeros(32, np.int32))
    eng._seal_active()
    eng.pump(64)
    assert all(t.interpret for t in eng.tables.values())
