"""Secondary-index differential tests (the PR-9 acceptance grid).

A ``StorageGroup`` maintains secondary indexes as sibling LSM trees
sharing the primary's WAL, budget and backend.  The contract under test,
per maintenance mode (see ``core/engine.py``):

* eager — the index tree is EXACT: every primary put/delete
  synchronously deletes the stale index entry (read-old-value through
  the fused probe) and inserts the new one.  Index reads never touch
  the primary; covering scans are one k-way merge over the index tree.
* lazy — maintenance appends blindly (no read-before-write); index
  READS validate each candidate against the primary, so a stale entry
  is filtered at query time.  Because the index tree is newest-wins per
  attribute, a stale newest entry HIDES older valid ones — the
  reference reader models exactly that.

The grid compares both modes against a dict-of-dicts reference reader —
bit-identical found masks, primary keys and covering scans — across
{tiering, leveling, partitioned} x {host, kernel} under update-heavy
and delete-heavy workloads, plus stale-entry reclamation through
``compact_all``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import IndexSpec, LSMEngine, StorageGroup
from repro.core.constraints import GlobalConstraint
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import GreedyScheduler

PKS = 512            # primary-key universe
ATTRS = 96           # attribute universe (dense -> heavy collisions)


def _mk(policy="tiering", use_kernels=False, memtable=128, indexes=(),
        **kw):
    pol = {
        "tiering": lambda: TieringPolicy(3, memtable, PKS),
        "leveling": lambda: LevelingPolicy(3, memtable, PKS),
        "partitioned": lambda: PartitionedLevelingPolicy(
            4, memtable, PKS, file_entries=64, l1_capacity=256),
    }[policy]()
    kw.setdefault("scan_use_kernels", use_kernels)
    return LSMEngine(pol, GreedyScheduler(), GlobalConstraint(200),
                     memtable_entries=memtable, unique_keys=PKS,
                     use_kernels=use_kernels, merge_block=64,
                     indexes=indexes, **kw)


def _feed(eng, keys, vals=None, pump=1 << 12):
    done = 0
    while done < len(keys):
        if vals is None:
            n = eng.delete_batch(keys[done:])
        else:
            n = eng.put_batch(keys[done:], vals[done:])
        done += n
        if done < len(keys):
            eng.pump(pump)


class RefIndexed:
    """Dict-of-dicts reference: a primary map plus one attr -> pk index
    map replayed per entry with the mode's exact semantics."""

    def __init__(self, mode):
        self.mode = mode
        self.primary: dict[int, int] = {}
        self.idx: dict[int, int] = {}

    @staticmethod
    def extract(v: int) -> int:
        return v & 0xFFFFFFFF

    def put(self, pk: int, v: int) -> None:
        a_new = self.extract(v)
        if self.mode == "eager" and pk in self.primary:
            a_old = self.extract(self.primary[pk])
            if a_old != a_new:
                # the engine logs the stale delete unconditionally; if
                # another pk had since claimed a_old IN AN EARLIER
                # batch, its entry is newer than the tombstone and
                # survives newest-wins — dict semantics: only pop when
                # this pk still owns it is NOT what the engine does
                # per-chunk, but per-ENTRY replay (this class) is the
                # pinned contract and the engine matches it
                self.idx.pop(a_old, None)
        self.idx[a_new] = pk
        self.primary[pk] = v

    def delete(self, pk: int) -> None:
        if pk in self.primary:
            if self.mode == "eager":
                self.idx.pop(self.extract(self.primary[pk]), None)
            del self.primary[pk]

    def lookup(self, a: int):
        pk = self.idx.get(a)
        if pk is None:
            return None
        if self.mode == "lazy":
            v = self.primary.get(pk)
            if v is None or self.extract(v) != a:
                return None
        return pk

    def scan(self, lo: int, hi: int) -> list[tuple[int, int]]:
        return sorted((a, pk) for a in self.idx
                      if lo <= a < hi and self.lookup(a) is not None
                      for pk in [self.idx[a]])


def _assert_index_equal(eng, ref, name="ix"):
    """Bit-identical comparison across every index read path."""
    qs = np.arange(ATTRS, dtype=np.uint32)
    found, pks = eng.index_lookup(name, qs)
    want = [ref.lookup(int(a)) for a in qs]
    assert found.tolist() == [w is not None for w in want]
    got_pairs = [(int(a), int(p)) for a, p, f in zip(qs, pks, found) if f]
    assert got_pairs == [(int(a), w) for a, w in zip(qs, want)
                         if w is not None]
    # index-to-primary reads return the primary VALUES
    vfound, vals = eng.get_by_index(name, qs)
    assert vfound.tolist() == found.tolist()
    for a, v, f in zip(qs, vals, vfound):
        if f:
            assert int(v) == ref.primary[ref.lookup(int(a))]
    # covering / validated range scans
    attrs, spks = eng.index_scan(name, 0, ATTRS)
    assert list(zip(attrs.tolist(), spks.tolist())) == ref.scan(0, ATTRS)
    lo, hi = ATTRS // 4, 3 * ATTRS // 4
    attrs, spks = eng.index_scan(name, lo, hi)
    assert list(zip(attrs.tolist(), spks.tolist())) == ref.scan(lo, hi)
    # and the primary plane itself
    pq = np.arange(PKS, dtype=np.uint32)
    pf, pv = eng.get_batch(pq)
    assert pf.tolist() == [int(k) in ref.primary for k in pq]
    for k, v, f in zip(pq, pv, pf):
        if f:
            assert int(v) == ref.primary[int(k)]


def _run_differential(policy, use_kernels, mode, seed=0, rounds=8):
    rng = np.random.default_rng(seed)
    eng = _mk(policy, use_kernels,
              indexes=(IndexSpec("ix", mode=mode),))
    ref = RefIndexed(mode)
    for r in range(rounds):
        # update-heavy: a narrow pk range re-put every round, so most
        # writes move an existing pk to a new attribute (stale entries)
        n = 150
        pks = rng.integers(0, PKS, n, dtype=np.uint32)
        vals = rng.integers(0, ATTRS, n, dtype=np.int32)
        _feed(eng, pks, vals)
        for pk, v in zip(pks.tolist(), vals.tolist()):
            ref.put(pk, v)
        if r % 2 == 1:                       # delete propagation
            dels = rng.integers(0, PKS, 30, dtype=np.uint32)
            _feed(eng, dels)
            for pk in dels.tolist():
                ref.delete(pk)
        eng.pump(256)
        if r == rounds // 2:
            _assert_index_equal(eng, ref)    # mid-workload, merges live
    eng.drain()
    _assert_index_equal(eng, ref)
    eng.compact_all()                        # stale-entry reclamation
    _assert_index_equal(eng, ref)
    return eng, ref


def test_secondary_differential_smoke():
    """Fast lane: one policy, host backend, both modes."""
    _run_differential("tiering", False, "eager")
    _run_differential("tiering", False, "lazy")


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["tiering", "leveling", "partitioned"])
@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["host", "kernel"])
@pytest.mark.parametrize("mode", ["eager", "lazy"])
def test_secondary_differential_grid(policy, use_kernels, mode):
    seed = {"tiering": 11, "leveling": 22, "partitioned": 33}[policy]
    _run_differential(policy, use_kernels, mode, seed=seed)


def test_eager_reclaims_stale_entries():
    """Update-heavy eager maintenance: after full compaction the index
    tree's PHYSICAL entries equal its live attribute count — stale
    entries and their tombstones are reclaimed, not hidden."""
    eng, ref = _run_differential("leveling", False, "eager", seed=3)
    ix = eng.trees[1]
    live = len(ref.scan(0, ATTRS))
    assert ix.total_entries() == live
    assert eng.stats["tombstones_dropped"] > 0


def test_lazy_skips_read_before_write():
    """Lazy maintenance never probes the primary on the write path:
    same workload, strictly fewer primary lookups than eager."""
    def lookups(mode):
        eng = _mk(indexes=(IndexSpec("ix", mode=mode),))
        rng = np.random.default_rng(1)
        for _ in range(4):
            _feed(eng, rng.integers(0, PKS, 200, dtype=np.uint32),
                  rng.integers(0, ATTRS, 200, dtype=np.int32))
        return eng.trees[0].stats["lookups"]
    assert lookups("lazy") == 0
    assert lookups("eager") > 0


def test_custom_extract_and_multiple_indexes():
    """Two indexes over different attributes of the same value, one
    eager one lazy, maintained from the same write batch."""
    lo4 = lambda vals: (vals.astype(np.int64) & 0xF).astype(np.uint32)
    hi4 = lambda vals: ((vals.astype(np.int64) >> 4) & 0xF).astype(
        np.uint32)
    eng = _mk(indexes=(IndexSpec("lo", mode="eager", extract=lo4),
                       IndexSpec("hi", mode="lazy", extract=hi4)))
    assert eng.index_names == ("lo", "hi")
    assert len(eng.trees) == 3
    eng.put(5, 0x73)
    eng.put(9, 0x21)
    f, pks = eng.index_lookup("lo", np.array([3, 1], np.uint32))
    assert f.all() and pks.tolist() == [5, 9]
    f, pks = eng.index_lookup("hi", np.array([7, 2], np.uint32))
    assert f.all() and pks.tolist() == [5, 9]
    eng.put(5, 0x41)                         # moves 5: lo 3->1, hi 7->4
    f, pks = eng.index_lookup("lo", np.array([3, 1], np.uint32))
    assert f.tolist() == [False, True] and int(pks[1]) == 5
    f, _ = eng.index_lookup("hi", np.array([7], np.uint32))
    assert not f[0]                          # lazy validation filters


def test_index_spec_validation():
    eng = _mk(indexes=("ix",))               # bare name -> eager spec
    assert eng.index_names == ("ix",)
    with pytest.raises(ValueError):
        eng.add_index("ix")                  # duplicate name
    with pytest.raises(ValueError):
        eng.add_index(IndexSpec("m", mode="bogus"))
    eng.put(1, 2)
    with pytest.raises(ValueError):
        eng.add_index("late")                # after writes
    with pytest.raises(ValueError):          # pk must bit-cast to int32
        eng.put_batch(np.array([1 << 31], np.uint32),
                      np.array([1], np.int32))


def test_plain_engine_unchanged():
    """A bare LSMEngine is the 1-tree group: no index trees, no index
    overhead on the write path, legacy surface intact."""
    eng = _mk()
    assert isinstance(eng, StorageGroup) and len(eng.trees) == 1
    assert eng.index_names == ()
    eng.put_batch(np.arange(64, dtype=np.uint32), np.ones(64, np.int32))
    assert eng.stats["puts"] == 64
    assert eng.tree is eng.trees[0].meta
    with pytest.raises(KeyError):
        eng.index_lookup("nope", np.array([1], np.uint32))
