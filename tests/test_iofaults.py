"""Storage fault-tolerance plane: injector schedules, the retrying
I/O stack, ENOSPC stall-and-drain, WAL segment archival, snapshot
checksums, and the scrub pass's detect/quarantine/repair lifecycle.

The plane's invariant (``ISSUE`` acceptance): a transient fault NEVER
causes data loss or a wrong answer — only retries, stalls, or typed
errors.  Every scenario here ends by reading the store back and
comparing against what an un-faulted store would say.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.checkpoint import EngineSnapshotStore
from repro.core import (FaultInjector, IOFaultError, IOStack, LSMEngine,
                        LSMFleet, RecoverySession, RetryPolicy,
                        StorageFull, UnrepairableCorruptionError,
                        WriteAheadLog, flip_bit)
from repro.core.constraints import GlobalConstraint
from repro.core.iostack import CorruptionError, data_crc32
from repro.core.policies import TieringPolicy
from repro.core.scheduler import GreedyScheduler

KEY_SPACE = 2048


def _policy(memtable=128):
    return TieringPolicy(3, memtable, KEY_SPACE)


def _io(faults, retries=6):
    """An IOStack whose backoff schedule runs without real sleeping."""
    return IOStack(faults,
                   RetryPolicy(max_retries=retries, backoff_s=0.001,
                               backoff_cap_s=0.01, deadline_s=60.0),
                   sleep=lambda s: None)


def _mk(wal=None, faults=None, memtable=128, **kw):
    return LSMEngine(_policy(memtable), GreedyScheduler(),
                     GlobalConstraint(400), memtable_entries=memtable,
                     unique_keys=KEY_SPACE, use_kernels=False,
                     merge_block=64, scan_use_kernels=False,
                     wal=wal, faults=faults, **kw)


def _fill(eng, n=1000, seed=0):
    """Admit n random writes through stalls; returns the key->val map."""
    rng = np.random.default_rng(seed)
    hist: dict[int, int] = {}
    k = rng.integers(0, KEY_SPACE, n).astype(np.uint32)
    v = rng.integers(0, 1 << 30, n).astype(np.int32)
    done = 0
    while done < n:
        took = eng.put_batch(k[done:], v[done:])
        for kk, vv in zip(k[done:done + took].tolist(),
                          v[done:done + took].tolist()):
            hist[kk] = vv
        done += took
        if done < n:
            eng.pump(1 << 12)
    return hist


def _assert_state(eng, hist):
    ks = np.array(sorted(hist), np.uint32)
    f, v = eng.get_batch(ks)
    assert f.all(), "recovered/repaired store lost keys"
    exp = np.array([hist[int(k)] for k in ks], np.int32)
    assert np.array_equal(v, exp), "repaired store answers wrong"


# ---------------------------------------------------------------------------
# Injector schedules (satellite: fix one-shot semantics)
# ---------------------------------------------------------------------------
class TestInjectorSchedules:
    def test_legacy_one_shot_disarms_after_firing(self):
        fi = FaultInjector()
        fi.arm_io("io-fsync", error="EIO", after=2)
        assert fi.check_io("io-fsync") is None          # hit 1: countdown
        assert fi.check_io("io-fsync")["error"] == "EIO"  # hit 2: fires
        assert fi.check_io("io-fsync") is None          # disarmed
        assert fi.check_io("io-fsync") is None

    def test_every_kth_is_persistent(self):
        fi = FaultInjector()
        fi.arm_io("io-fsync", error="EIO", every=3)
        fired = [fi.check_io("io-fsync") is not None for _ in range(9)]
        # fires on hits 1, 4, 7 (after=1, then every 3rd) — persistent:
        # no re-arming between firings
        assert fired == [True, False, False] * 3

    def test_probabilistic_is_seeded_deterministic(self):
        def run():
            fi = FaultInjector()
            fi.arm_io("io-write", error="EIO", p=0.5, seed=7)
            return [fi.check_io("io-write") is not None
                    for _ in range(32)]
        a, b = run(), run()
        assert a == b, "seeded schedule must be reproducible"
        assert any(a) and not all(a), "p=0.5 should mix over 32 hits"

    def test_count_bounds_a_persistent_schedule(self):
        fi = FaultInjector()
        fi.arm_io("io-write", error="EIO", every=1, count=2)
        fired = [fi.check_io("io-write") is not None for _ in range(5)]
        assert fired == [True, True, False, False, False]

    def test_crash_points_share_the_schedules(self):
        fi = FaultInjector()
        fi.arm("pre-flush", every=2, count=2)
        hits = []
        for _ in range(6):
            try:
                fi.hit("pre-flush")
                hits.append(False)
            except Exception:
                hits.append(True)
        assert hits == [True, False, True, False, False, False]


# ---------------------------------------------------------------------------
# IOStack retry / backoff / typed errors
# ---------------------------------------------------------------------------
class TestIOStack:
    def test_transient_eio_retries_then_succeeds(self):
        fi = FaultInjector()
        slept: list[float] = []
        io = IOStack(fi, RetryPolicy(max_retries=6, backoff_s=0.001,
                                     backoff_cap_s=0.004, deadline_s=60.0),
                     sleep=slept.append)
        fi.arm_io("io-read", error="EIO", every=1, count=3)
        calls = []
        out = io.call("io-read", lambda: calls.append(1) or 42)
        assert out == 42 and len(calls) == 1
        assert io.stats["io_retries"] == 3
        assert io.stats["io_faults"] == 3
        # capped exponential: 1ms, 2ms, then clamped at 4ms
        assert slept == pytest.approx([0.001, 0.002, 0.004])
        assert io.stats["io_backoff_s"] == pytest.approx(sum(slept))

    def test_persistent_eio_becomes_typed_error(self):
        fi = FaultInjector()
        io = _io(fi, retries=4)
        fi.arm_io("io-read", error="EIO", every=1, count=None)
        with pytest.raises(IOFaultError) as ei:
            io.call("io-read", lambda: 1)
        assert ei.value.point == "io-read"
        assert ei.value.attempts == 5           # 1 + max_retries

    def test_enospc_is_not_retried(self):
        fi = FaultInjector()
        io = _io(fi)
        fi.arm_io("io-write", error="ENOSPC", every=1)
        with pytest.raises(StorageFull):
            io.call("io-write", lambda: 1)
        assert io.stats["io_retries"] == 0      # backoff can't free space
        assert io.stats["io_enospc"] == 1

    def test_latency_spike_is_served_and_counted(self):
        fi = FaultInjector()
        slept: list[float] = []
        io = IOStack(fi, RetryPolicy(), sleep=slept.append)
        fi.arm_io("io-fsync", error=None, latency=0.25, every=1, count=2)
        assert io.call("io-fsync", lambda: "ok") == "ok"
        assert io.call("io-fsync", lambda: "ok") == "ok"
        assert io.stats["io_latency_injected_s"] == pytest.approx(0.5)
        assert 0.25 in slept
        assert io.stats["io_faults"] == 0       # a spike is not an error


# ---------------------------------------------------------------------------
# Engine under I/O faults: retries, stalls, drains — never loss
# ---------------------------------------------------------------------------
class TestEngineUnderFaults:
    def test_transient_fsync_faults_are_absorbed(self, tmp_path):
        fi = FaultInjector()
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal", io=_io(fi)),
                  faults=fi)
        fi.arm_io("io-fsync", error="EIO", every=2, count=4)
        hist = _fill(eng, 800)
        eng.pump(1 << 16)
        h = eng.health()
        assert h["io_retries"] >= 4
        assert h["io_backoff_s"] > 0
        _assert_state(eng, hist)

    def test_enospc_stalls_writes_and_drains(self, tmp_path):
        fi = FaultInjector()
        eng = _mk(wal=WriteAheadLog(tmp_path / "wal", io=_io(fi)),
                  faults=fi)
        hist = _fill(eng, 300, seed=1)
        fi.arm_io("io-write", error="ENOSPC", every=1, count=None)
        k = np.arange(100, dtype=np.uint32)
        v = np.full(100, 7, np.int32)
        assert eng.put_batch(k, v) == 0         # disk full: stall, no loss
        assert eng.health()["enospc_stalls"] >= 1
        assert eng.stats["stall_events"] >= 1
        eng.pump(1 << 12)                       # pump survives ENOSPC too
        fi.disarm("io-write")                   # space returns
        done = 0
        while done < len(k):
            done += eng.put_batch(k[done:], v[done:])
            if done < len(k):
                eng.pump(1 << 12)
        for kk in k.tolist():
            hist[kk] = 7
        eng.pump(1 << 16)
        _assert_state(eng, hist)

    def test_health_rolls_up_fleet_wide(self, tmp_path):
        fi = FaultInjector()
        fleet = LSMFleet(2, lambda i: _mk(
            wal=WriteAheadLog(tmp_path / f"wal-{i}", io=_io(fi)),
            faults=fi), parallel=False)
        fi.arm_io("io-fsync", error="EIO", every=1, count=4)
        rng = np.random.default_rng(2)
        k = rng.integers(0, KEY_SPACE, 600).astype(np.uint32)
        v = rng.integers(0, 1 << 30, 600).astype(np.int32)
        done = 0
        while done < len(k):
            done += fleet.put_batch(k[done:], v[done:])
            if done < len(k):
                fleet.pump(1 << 12)
        fleet.pump(1 << 16)
        h = fleet.health()
        assert h["io_retries"] >= 4
        assert h["recovering"] == 0
        per_shard = [e.health()["io_retries"] for e in fleet.engines]
        assert h["io_retries"] == sum(per_shard)


# ---------------------------------------------------------------------------
# WAL segment archival (satellite)
# ---------------------------------------------------------------------------
class TestWALArchival:
    def test_truncate_moves_segments_to_archive(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=5,
                            archive_dir=tmp_path / "cold")
        for i in range(4):
            wal.append(np.arange(5, dtype=np.uint32),
                       np.full(5, i, np.int32))
        wal.sync()
        moved = wal.truncate_upto(12)           # seals segments 0 and 1
        assert moved == 10                      # archived entries returned
        assert wal.start_lsn == 10
        assert wal.oldest_lsn == 0              # archive still covers 0
        assert wal.archived_segments == 2
        assert wal.archived_entries == 10
        assert sorted(p.name for p in (tmp_path / "cold").iterdir()) == \
            ["wal.000000", "wal.000001"]
        # replay reads THROUGH the archive: the full history survives
        ks, vs = wal.entries_since(0)
        assert len(ks) == 20
        assert np.array_equal(vs, np.repeat(np.arange(4, dtype=np.int32), 5))

    def test_unlink_mode_is_unchanged(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=5)
        for i in range(3):
            wal.append(np.arange(5, dtype=np.uint32),
                       np.full(5, i, np.int32))
        wal.sync()
        assert wal.truncate_upto(7) == 0        # nothing archived
        assert wal.oldest_lsn == wal.start_lsn == 5

    def test_reopen_chains_archive_before_live_log(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=5,
                            archive_dir=tmp_path / "cold")
        for i in range(4):
            wal.append(np.arange(5, dtype=np.uint32),
                       np.full(5, i, np.int32))
        wal.sync()
        wal.truncate_upto(12)
        wal.close()
        re = WriteAheadLog(tmp_path / "wal", segment_entries=5,
                           archive_dir=tmp_path / "cold")
        assert re.oldest_lsn == 0 and re.end_lsn == 20
        ks, _ = re.entries_since(3)
        assert len(ks) == 17

    def test_recovery_replays_through_archive(self, tmp_path):
        """A snapshot archives sealed segments; a later crash recovers
        from an OLDER surviving snapshot by replaying archived frames."""
        fi = FaultInjector()
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=64,
                            archive_dir=tmp_path / "cold", io=_io(fi))
        eng = _mk(wal=wal, faults=fi)
        store = EngineSnapshotStore(tmp_path / "snap")
        hist = _fill(eng, 400, seed=3)
        eng.snapshot(store)                     # archives sealed segments
        hist.update(_fill(eng, 400, seed=4))
        debt_before = eng._wal_debt
        eng.snapshot(store)
        assert eng.wal.archived_segments >= 1
        # archival traffic is charged to the background budget
        assert eng._wal_debt >= debt_before
        hist.update(_fill(eng, 200, seed=5))
        eng.wal.sync()
        eng.wal.close()
        wal2 = WriteAheadLog(tmp_path / "wal", segment_entries=64,
                             archive_dir=tmp_path / "cold")
        eng2 = _mk(wal=wal2)
        RecoverySession(eng2, store).run(1 << 12)
        eng2.pump(1 << 16)
        _assert_state(eng2, hist)

    def test_archival_bytes_accounted(self, tmp_path):
        wal = WriteAheadLog(tmp_path / "wal", segment_entries=5,
                            archive_dir=tmp_path / "cold")
        for i in range(4):
            wal.append(np.arange(5, dtype=np.uint32),
                       np.full(5, i, np.int32))
        wal.sync()
        wal.truncate_upto(10)
        assert wal.archived_bytes > 0


# ---------------------------------------------------------------------------
# Snapshot checksums + scrub detect/quarantine/repair
# ---------------------------------------------------------------------------
class TestCorruption:
    def _flushed_engine(self, tmp_path, wal=True, n=900, seed=6):
        fi = FaultInjector()
        w = WriteAheadLog(tmp_path / "wal", io=_io(fi)) if wal else None
        eng = _mk(wal=w, faults=fi)
        hist = _fill(eng, n, seed=seed)
        eng.pump(1 << 18)
        assert eng.trees[0]._order, "need at least one on-disk table"
        return eng, hist

    def test_snapshot_restore_verifies_crc(self, tmp_path):
        eng, _ = self._flushed_engine(tmp_path)
        store = EngineSnapshotStore(tmp_path / "snap")
        eng.snapshot(store)
        snap = store.load()
        sections = snap.get("trees") or [snap]
        sec = sections[0]
        target = tmp_path / "snap" / sec["tables"][0]["file"]
        data = bytearray(target.read_bytes())
        data[len(data) // 2] ^= 0xFF            # bit-rot on disk
        target.write_bytes(bytes(data))
        with pytest.raises(CorruptionError):
            list(store.load_tree_tables(sec))

    def test_manifest_records_the_live_checksum(self, tmp_path):
        eng, _ = self._flushed_engine(tmp_path)
        store = EngineSnapshotStore(tmp_path / "snap")
        eng.snapshot(store)
        snap = store.load()
        sections = snap.get("trees") or [snap]
        by_crc = {int(t["crc"]) for s in sections for t in s["tables"]}
        live = {int(t.crc32) for t in eng.trees[0]._order}
        assert live <= by_crc

    def test_scrub_repairs_bit_rot_from_snapshot(self, tmp_path):
        eng, hist = self._flushed_engine(tmp_path)
        store = EngineSnapshotStore(tmp_path / "snap",
                                    io=eng.wal.io)
        eng.snapshot(store)
        sc = eng.enable_scrub(store=store)
        victim = eng.trees[0]._order[0]
        flip_bit(victim, entry=1, bit=3)
        for _ in range(600):
            eng.pump(512)
            if sc.stats["tables_repaired"]:
                break
        assert sc.stats["tables_quarantined"] == 1
        assert sc.stats["tables_repaired"] == 1
        assert sc.stats["tables_unrepairable"] == 0
        assert eng.health()["tables_repaired"] == 1
        _assert_state(eng, hist)                # bit-identical again

    def test_scrub_rebuilds_whole_tree_from_wal(self, tmp_path):
        eng, hist = self._flushed_engine(tmp_path)
        sc = eng.enable_scrub(store=None)       # no snapshot copy exists
        victim = eng.trees[0]._order[-1]
        flip_bit(victim, entry=0, bit=17)
        for _ in range(600):
            eng.pump(512)
            if sc.stats["tables_repaired"]:
                break
        assert sc.stats["tables_quarantined"] == 1
        assert sc.stats["tables_repaired"] == 1
        _assert_state(eng, hist)

    def test_unrepairable_is_a_typed_error_not_a_wrong_answer(
            self, tmp_path):
        eng, _ = self._flushed_engine(tmp_path, wal=False)
        sc = eng.enable_scrub(store=None)       # no WAL, no snapshot
        flip_bit(eng.trees[0]._order[0], entry=2, bit=9)
        for _ in range(600):
            eng.pump(512)
            if sc.stats["tables_unrepairable"]:
                break
        assert sc.stats["tables_unrepairable"] == 1
        assert eng.trees[0].corrupt
        with pytest.raises(UnrepairableCorruptionError):
            eng.get_batch(np.arange(16, dtype=np.uint32))
        with pytest.raises(UnrepairableCorruptionError):
            eng.scan_range(0, KEY_SPACE)

    def test_scrub_budget_is_charged(self, tmp_path):
        eng, _ = self._flushed_engine(tmp_path)
        eng.pump(1 << 18)                       # clear background debt
        eng.enable_scrub(store=None, entries_per_epoch=64)
        spent = eng.pump(256)
        assert 0 < spent <= 256
        assert eng.health()["scrub_entries"] == spent

    def test_data_crc32_matches_seal(self):
        k = np.arange(100, dtype=np.uint32)
        v = (np.arange(100) * 3).astype(np.int32)
        from repro.core import SSTable
        t = SSTable.build(k, v)
        assert t.verify_checksum()              # unsealed: vacuous
        t.seal_checksum()
        assert t.crc32 == data_crc32(k, v)
        assert t.verify_checksum()
        flip_bit(t, entry=5, bit=1)
        assert not t.verify_checksum()
