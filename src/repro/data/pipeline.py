"""Deterministic sharded token pipeline with exact-resume state.

Synthetic tokenized corpus (seeded per (shard, sequence)), packed to
fixed-length sequences.  The iterator is a pure function of
(config, step) — checkpointing the data state is checkpointing one
integer, and restoring on a different dp-shard count replays without
sample loss or duplication (elasticity contract: global sample order is
fixed, shards take strided slices).

An optional open-system ingestion front (``IngestionQueue``) models the
paper's arrival-rate machinery for the ingestion benchmarks: producers
enqueue at a configured rate; the trainer consumes a batch per step;
queue growth == unsustainable arrival rate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class ShardedTokenPipeline:
    """Stateless-resumable pipeline: batch(step, shard) is pure."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.per_shard = cfg.global_batch // n_shards

    def _seq(self, global_index: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, global_index]))
        # zipf-ish marginal over the vocab: realistic token frequencies
        z = rng.zipf(1.3, size=self.cfg.seq_len).astype(np.int64)
        return (z % self.cfg.vocab).astype(np.int32)

    def batch(self, step: int) -> dict:
        base = step * self.cfg.global_batch
        idx = [base + self.shard * self.per_shard + i
               for i in range(self.per_shard)]
        toks = np.stack([self._seq(i) for i in idx])
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_iterator(cfg: DataConfig, start_step: int = 0,
                        shard: int = 0, n_shards: int = 1):
    """Resume-exact iterator: (state, next) where state is the step int."""
    pipe = ShardedTokenPipeline(cfg, shard, n_shards)
    step = start_step

    def next_batch():
        nonlocal step
        b = pipe.batch(step)
        step += 1
        return b, step

    return next_batch


class IngestionQueue:
    """Open-system ingestion front (Figure 5b, applied to data loading).

    Producers enqueue sequences at ``arrival_rate`` per tick; the train
    loop consumes ``global_batch`` per step.  Queue depth over time is
    the sustainability signal the two-phase method evaluates."""

    def __init__(self, arrival_rate: float):
        self.rate = float(arrival_rate)
        self.queue = 0.0
        self.enqueued = 0.0
        self.consumed = 0.0
        self.depth_trace: list[float] = []

    def tick(self, dt: float = 1.0):
        self.queue += self.rate * dt
        self.enqueued += self.rate * dt

    def consume(self, n: int) -> int:
        take = min(self.queue, n)
        self.queue -= take
        self.consumed += take
        self.depth_trace.append(self.queue)
        return int(take)
