from .pipeline import DataConfig, ShardedTokenPipeline, make_batch_iterator

__all__ = ["DataConfig", "ShardedTokenPipeline", "make_batch_iterator"]
