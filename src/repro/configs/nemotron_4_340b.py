"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000; head_dim=192.
Optimizer: adafactor (factored second moment) so optimizer state fits
v5e HBM at 256/512 chips.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab=256_000,
    activation="relu2",
    norm="layernorm",
    optimizer="adafactor",
    microbatches=8,
    scan_group=12,
    attn_causal_skip=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(activation="relu2", norm="layernorm")
