"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full (paper-table) config;
``get_smoke(arch_id)`` the reduced CPU-testable variant of the same
family.  ``ARCHS`` lists the assigned ids in assignment order.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeCell, cell_applicable

ARCHS = [
    "mamba2-1.3b",
    "gemma-7b",
    "nemotron-4-340b",
    "llama3-405b",
    "smollm-135m",
    "whisper-base",
    "phi3.5-moe-42b-a6.6b",
    "kimi-k2-1t-a32b",
    "zamba2-2.7b",
    "paligemma-3b",
]

_MODULES = {
    "mamba2-1.3b": "mamba2_1_3b",
    "gemma-7b": "gemma_7b",
    "nemotron-4-340b": "nemotron_4_340b",
    "llama3-405b": "llama3_405b",
    "smollm-135m": "smollm_135m",
    "whisper-base": "whisper_base",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "kimi-k2-1t-a32b": "kimi_k2",
    "zamba2-2.7b": "zamba2_2_7b",
    "paligemma-3b": "paligemma_3b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


__all__ = ["ARCHS", "SHAPES", "ShapeCell", "cell_applicable",
           "get_config", "get_smoke"]
