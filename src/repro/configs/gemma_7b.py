"""gemma-7b [dense] — GeGLU, head_dim=256 [arXiv:2403.08295].

28L d_model=3072 16H (GQA kv=16 => MHA-like) d_ff=24576 vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab=256_000,
    activation="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    microbatches=4,
    attn_causal_skip=True,
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced()
