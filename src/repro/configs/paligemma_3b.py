"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216; head_dim=256.
The SigLIP vision frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings
(B, 256, d_model); the decoder runs prefix-LM attention (bidirectional
over the image prefix, causal over text).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2_048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab=257_216,
    activation="geglu",
    tie_embeddings=True,
    scale_embeddings=True,
    norm="rmsnorm",
    n_patches=256,
    microbatches=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(n_kv_heads=1, n_patches=8)
