"""whisper-base [audio] — enc-dec transformer backbone [arXiv:2212.04356].

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  The conv audio frontend
is a STUB per the assignment: ``input_specs()`` provides precomputed
frame embeddings (B, 1500, d_model).  Positions use RoPE so the assigned
32k-decode cell is well-defined (adaptation noted in DESIGN.md — the
published model uses sinusoidal/learned positions capped at 448 decoder
positions).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2_048,
    vocab=51_865,
    activation="gelu",
    norm="layernorm",
    enc_frames=1_500,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(activation="gelu", norm="layernorm")
