"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048 (attention-free), vocab=50280, ssm_state=128.
d_inner = 2*2048 = 4096, head_dim 64 -> 64 SSD heads.  Runs the
``long_500k`` cell (O(1) recurrent decode state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    microbatches=4,
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(n_layers=2, ssm_state=16)
