"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2, paper-table].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
plus one always-on shared expert (DeepSeek-style).  head_dim=112.
The largest checkpoint-pressure member of the zoo — the motivating cell
for the LSM delta-checkpoint store.  Optimizer: adafactor.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7_168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2_048,
    vocab=163_840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    activation="swiglu",
    norm="rmsnorm",
    optimizer="adafactor",
    microbatches=4,               # §Perf: mb16->4 + SP + causal-skip
    accum_dtype="bfloat16",
    seq_shard_activations=True,
    attn_causal_skip=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(n_experts=8, top_k=2)
