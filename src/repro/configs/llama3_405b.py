"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256; head_dim=128.
Optimizer: adafactor for HBM fit at 256/512 chips.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    norm="rmsnorm",
    optimizer="adafactor",
    microbatches=8,
    scan_group=14,
    attn_causal_skip=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced()
