"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152; head_dim=64.
This family also backs the end-to-end CPU training example.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1_536,
    vocab=49_152,
    activation="swiglu",
    tie_embeddings=True,
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(n_heads=3, n_kv_heads=3, head_dim=16)
