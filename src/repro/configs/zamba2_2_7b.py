"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

54L d_model=2560 (Mamba2, ssm_state=64) with ONE shared full-attention
block (32H MHA kv=32, d_ff=10240 MLP) applied every 6 layers, re-using
the same weights each time (the Zamba2 weight-sharing trick).  vocab=32000.
Runs the ``long_500k`` cell (recurrent state + one shared-KV attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2_560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    attn_every=6,
    microbatches=2,
    norm="rmsnorm",
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(n_layers=4, attn_every=2, ssm_state=16)
