import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh and record the roofline inputs.

The two lines above MUST run before any jax import (jax locks the device
count on first init), which is why this module sets XLA_FLAGS at the very
top.  Everything else imports lazily below.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --all --resume   # skip existing artifacts

Artifacts: artifacts/dryrun/{arch}__{shape}__{mesh}.json with
memory_analysis, cost_analysis, per-collective bytes and roofline terms —
benchmarks/roofline.py and EXPERIMENTS.md are generated from these.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_BF16_FLOPS,
                               make_production_mesh)
from repro.models import cache_logical_axes, param_logical_axes
from repro.models.config import ModelConfig
from repro.train.steps import (batch_shardings, input_specs, make_decode_step,
                               make_train_step)
from repro.distributed.sharding import default_rules, tree_shardings

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _tree_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(tree))


def lower_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Build + lower the cell's step function.  Returns (lowered, meta)."""
    shape = SHAPES[shape_name]
    rules = default_rules(mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        from repro.train.steps import effective_microbatches
        mb = effective_microbatches(cfg, mesh, shape.global_batch)
        step_fn, state_shardings, abstract_state = make_train_step(
            cfg, mesh, microbatches=mb)
        state = abstract_state()
        b_shard = batch_shardings(cfg, mesh, rules, specs)
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_shardings, b_shard),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            ).lower(state, specs)
        arg_bytes = _tree_bytes(state) + _tree_bytes(specs)
    elif shape.kind == "prefill":
        from repro.train.steps import make_prefill_step
        prefill_fn, p_shard = make_prefill_step(cfg, mesh, shape.seq_len)
        from repro.models import abstract_params, init_cache
        p_abs = abstract_params(cfg)
        b_shard = batch_shardings(cfg, mesh, rules, specs)
        c_abs = jax.eval_shape(lambda: init_cache(
            cfg, shape.global_batch, shape.seq_len))
        c_shard = tree_shardings(mesh, rules, c_abs, cache_logical_axes(cfg))
        with mesh:
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(p_shard, b_shard),
                out_shardings=((c_shard, None)),
            ).lower(p_abs, specs)
        arg_bytes = _tree_bytes(p_abs) + _tree_bytes(specs)
    else:  # decode
        decode_fn, p_shard, cache_sh_fn = make_decode_step(cfg, mesh)
        from repro.models import abstract_params
        p_abs = abstract_params(cfg)
        cache = specs["cache"]
        c_shard = cache_sh_fn(shape.global_batch, shape.seq_len)
        tok = specs["tokens"]
        with mesh:
            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_shard, c_shard, None),
                out_shardings=(c_shard, None),
                donate_argnums=(1,),
            ).lower(p_abs, cache, tok)
        arg_bytes = _tree_bytes(p_abs) + _tree_bytes(cache)
    return lowered, {"global_arg_bytes": arg_bytes}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = ARTIFACTS, verbose: bool = True,
             cfg_overrides: dict | None = None, tag: str = "") -> dict:
    """Lower+compile one cell.  ``cfg_overrides`` (dataclasses.replace
    kwargs) + ``tag`` support the §Perf hillclimb variants."""
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "status": "skipped", "skip_reason": why,
              "variant": tag or "baseline",
              "overrides": cfg_overrides or {}}
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if not ok:
        out_path.write_text(json.dumps(result, indent=2))
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape_name, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        mem = _mem_analysis_dict(compiled)
        # Loop-aware cost model: cost_analysis() counts while bodies once,
        # so scanned layers / grad-accumulation vanish from it.  analyze()
        # multiplies by known_trip_count along the call graph.
        from repro.launch.hlo_cost import analyze
        totals = analyze(compiled.as_text())
        flops_per_dev = totals.flops
        bytes_per_dev = totals.traffic_bytes

        terms = roofline_terms(
            global_flops=flops_per_dev * n_dev,
            global_bytes=bytes_per_dev * n_dev,
            collective_bytes_per_dev=float(totals.collective_bytes),
            n_devices=n_dev, peak_flops=PEAK_BF16_FLOPS, hbm_bw=HBM_BW,
            ici_bw=ICI_BW)

        from repro.models import param_count
        N = param_count(cfg)
        # MODEL_FLOPS = 6*N_active*D (train: fwd+bwd) or 2*N_active*D
        # (inference: fwd only); D = tokens processed by this step.
        D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mult = 6.0 if shape.kind == "train" else 2.0
        model_flops = mult * cfg.n_active_params() * D

        result.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops_per_device": flops_per_dev,
            "bytes_per_device": bytes_per_dev,
            "cost_analysis_flops_flat": float(cost.get("flops", 0.0)),
            "cost_analysis_bytes_flat": float(cost.get("bytes accessed", 0.0)),
            "collectives": {"total_bytes": totals.collective_bytes,
                            "per_op_bytes": dict(totals.per_collective),
                            "per_op_count": dict(totals.per_collective_count)},
            "memory_analysis": mem,
            "global_arg_bytes": meta["global_arg_bytes"],
            "arg_bytes_per_device_est": meta["global_arg_bytes"] / n_dev,
            "roofline": terms,
            "model_flops_6nd": model_flops,
            "useful_flops_ratio": (model_flops / (flops_per_dev * n_dev)
                                   if flops_per_dev else None),
            "n_params": N,
            "n_active_params": cfg.n_active_params(),
        })
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
                  f"dominant={terms['dominant']})", flush=True)
            if mem:
                print(f"  memory_analysis: {mem}", flush=True)
            print(f"  cost: flops/dev={flops_per_dev:.3e} "
                  f"bytes/dev={bytes_per_dev:.3e} "
                  f"coll_bytes/dev={totals.collective_bytes:.3e}", flush=True)
    except Exception as e:
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]})
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
                  f"FAILED: {e!r}", flush=True)
    result["wall_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already exists and is ok")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                p = out_dir / f"{arch}__{shape}__{mk}.json"
                if args.resume and p.exists():
                    prev = json.loads(p.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                r = run_cell(arch, shape, mk, out_dir)
                n_ok += r["status"] == "ok"
                n_err += r["status"] == "error"
                n_skip += r["status"] == "skipped"
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} errors={n_err}",
          flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
