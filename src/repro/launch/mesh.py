"""Production mesh construction (assignment-specified shapes).

TPU v5e constants used by the roofline analysis live here too, so every
consumer (dry-run, benchmarks, EXPERIMENTS.md generators) agrees on them.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip hardware constants (assignment-specified).
PEAK_BF16_FLOPS = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1x1 (data, model) mesh slice —
    used by CPU examples/tests so the same step code paths run anywhere."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
