import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""Profiling aid: print the top-N HBM-traffic contributors of a cell's
optimized HLO (instruction-level, multiplied by loop trip counts) —
the 'profile' the §Perf loop reasons from on a CPU-only dry-run host.

    python -m repro.launch.traffic_debug --arch llama3-405b \
        --shape decode_32k [--top 15] [--set k=v ...]
"""
import argparse
from collections import defaultdict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    import dataclasses
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.hlo_cost import (parse_module, _shape_bytes,
                                       _SKIP_TRAFFIC, _TRIP_RE, COLLECTIVES)
    import re

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except (ValueError, TypeError):
                pass
        overrides[k] = v
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, _ = lower_cell(cfg, args.shape, mesh)
    text = lowered.compile().as_text()
    comps, entry = parse_module(text)

    # compute each computation's execution multiplier by propagating trip
    # counts down the call graph
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        c = comps.get(name)
        if c is None:
            continue
        for ins in c.instrs:
            trips = 1.0
            if ins.opcode == "while":
                m = _TRIP_RE.search(ins.line)
                if m:
                    trips = float(m.group(1))
            for cn in ins.called:
                mult[cn] += mult[name] * trips
                if cn not in seen:
                    seen.add(cn)
                    order.append(cn)

    rows = []
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ins in c.instrs:
            if ins.opcode in _SKIP_TRAFFIC or ins.opcode in (
                    "call", "while", "conditional"):
                continue
            b = _shape_bytes(ins.type_str)
            for on in ins.operands:
                if on in c.shapes:
                    b += _shape_bytes(c.shapes[on])
            if ins.opcode == "fusion":
                pass  # call-site traffic only; ok
            rows.append((b * m, b, m, ins.opcode, name, ins.name,
                         ins.line.strip()[:140]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total traffic/device: {total:.3e} bytes")
    for t, b, m, op, comp, name, line in rows[:args.top]:
        print(f"  {t:.3e}  ({b:.2e} x{m:.0f})  {op:14s} {comp}/{name}")
        print(f"      {line}")


if __name__ == "__main__":
    main()
