"""Training launcher: data pipeline -> pjit train step -> LSM checkpoint
store, with restart/elastic-reshard built in.

On a real cluster each host runs this under ``jax.distributed``; on CPU
it drives the reduced configs end-to-end (the quickstart/examples do
exactly that).  Fault tolerance contract:

  * every ``ckpt_every`` steps the (donated) state is snapshotted to host
    and written as an LSM delta component (atomic manifest commit);
  * ``--resume`` reconstructs (base (+) deltas) newest-wins and reshards
    onto the CURRENT mesh — which may be a different shape than the one
    that wrote the checkpoint (elastic restart after losing/gaining a
    pod);
  * the data pipeline resumes from one integer, so samples are neither
    dropped nor repeated;
  * checkpoint compaction happens in the background under an I/O budget,
    scheduled by the paper's greedy scheduler, and NEVER blocks the step
    loop (put_delta simply reports a stall and the trainer retries next
    cadence — the write-stall control law).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import LSMCheckpointStore, flatten_state
from repro.checkpoint.restore import reshard_restore
from repro.configs import ARCHS, get_config, get_smoke
from repro.data import DataConfig, ShardedTokenPipeline
from repro.distributed.sharding import default_rules
from repro.launch.mesh import make_host_mesh
from repro.train.steps import (batch_shardings, init_train_state,
                               make_train_step, train_state_axes)


def run_training(cfg, mesh, *, steps: int = 50, global_batch: int = 8,
                 seq_len: int = 64, ckpt_dir: str | None = None,
                 ckpt_every: int = 20, resume: bool = False,
                 ckpt_io_budget: float = 50e6, log_every: int = 10,
                 pump_between_steps: bool = True, seed: int = 0,
                 learning_rate: float = 3e-4):
    """Drives cfg on mesh; returns (final metrics, losses, store)."""
    rules = default_rules(mesh)
    step_fn, state_shardings, _ = make_train_step(
        cfg, mesh, learning_rate=learning_rate,
        microbatches=1 if global_batch < cfg.microbatches else None)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    pipe = ShardedTokenPipeline(data_cfg)

    store = None
    state = None
    start_step = 0
    if ckpt_dir is not None:
        store = LSMCheckpointStore(Path(ckpt_dir),
                                   io_budget_bytes_per_s=ckpt_io_budget)
        if resume and store.manifest.last_step >= 0:
            axes = train_state_axes(cfg)
            state, last = reshard_restore(store, mesh, axes, rules)
            start_step = last + 1
            print(f"[train] resumed from step {last} "
                  f"onto mesh {dict(mesh.shape)}", flush=True)
    if state is None:
        state = init_train_state(cfg, jax.random.PRNGKey(seed))

    with mesh:
        jit_step = jax.jit(step_fn,
                           in_shardings=(state_shardings, None),
                           out_shardings=(state_shardings, None),
                           donate_argnums=(0,))
        losses = []
        t0 = time.time()
        for step in range(start_step, start_step + steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
            if cfg.family == "encdec":
                batch["frames"] = jax.random.normal(
                    jax.random.PRNGKey(step),
                    (global_batch, cfg.enc_frames, cfg.d_model), jnp.float32)
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    jax.random.PRNGKey(step),
                    (global_batch, cfg.n_patches, cfg.d_model), jnp.float32)
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            # -- LSM checkpoint cadence (async off the step path on real
            # hardware; synchronous host snapshot here)
            if store is not None and (step + 1) % ckpt_every == 0:
                host = jax.tree.map(np.asarray, state)
                ok = store.put_delta(step, flatten_state(host))
                if not ok:
                    print(f"[train] ckpt stall at step {step} "
                          f"(constraint); compaction lagging", flush=True)
            if store is not None and pump_between_steps:
                store.pump(budget_bytes=ckpt_io_budget * 0.1)
    return metrics, losses, store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    metrics, losses, _ = run_training(
        cfg, mesh, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume,
        learning_rate=args.lr)
    print(f"[train] done: first loss {losses[0]:.4f} -> "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
