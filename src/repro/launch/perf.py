import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb harness: lower+compile variants of a cell and diff
their roofline terms against the baseline artifact.

    python -m repro.launch.perf --arch llama3-405b --shape train_4k \
        --tag remat_dots --set remat=dots

Results land in artifacts/perf/; EXPERIMENTS.md §Perf is written from
the recorded hypothesis->before->after chains.
"""
import argparse
import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "perf"
BASELINES = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        overrides[k] = _parse_val(v)

    res = run_cell(args.arch, args.shape, args.mesh, out_dir=ARTIFACTS,
                   cfg_overrides=overrides, tag=args.tag)
    base_path = BASELINES / f"{args.arch}__{args.shape}__{args.mesh}.json"
    if base_path.exists() and res.get("status") == "ok":
        base = json.loads(base_path.read_text())
        if base.get("status") == "ok":
            br, vr = base["roofline"], res["roofline"]
            print("--- delta vs baseline ---")
            for k in ("compute_s", "memory_s", "collective_s"):
                d = vr[k] / br[k] - 1 if br[k] else float("nan")
                print(f"  {k}: {br[k]:.4g} -> {vr[k]:.4g}  ({d:+.1%})")
            bb = max(br.get(k, 0) for k in
                     ("compute_s", "memory_s", "collective_s"))
            vb = max(vr.get(k, 0) for k in
                     ("compute_s", "memory_s", "collective_s"))
            print(f"  bound: {bb:.4g} -> {vb:.4g}  ({vb/bb-1:+.1%})")
    return 0 if res.get("status") == "ok" else 1


if __name__ == "__main__":
    raise SystemExit(main())
