"""Call-graph-aware cost model over optimized HLO text.

``Compiled.cost_analysis()`` counts each ``while`` body ONCE — a
scanned-129-layer train step with 8 grad-accumulation microbatches is
under-counted by ~3 orders of magnitude, and collectives inside the loop
are likewise invisible to a flat parse.  This module parses the module
text into computations, assigns per-instruction costs, recovers loop trip
counts from each ``while`` condition, and propagates multipliers down the
call graph (fusion/call/while/conditional).

Costs:
  * dot           — 2 * numel(result) * prod(contracting dims)
  * elementwise   — numel(result)
  * reduce/sort/… — numel(largest operand)
  * collectives   — operand bytes (the cross-link traffic), per family
  * traffic       — sum of operand+result bytes per instruction (an HBM
                    touch model; reported separately from cost_analysis's
                    "bytes accessed")

This is an analytic roofline input, not a simulator; it is exact for the
matmul-dominated graphs we lower and approximate for elementwise tails.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast",
               "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}:\d]+))\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                       r"(?:%([\w\.\-]+)|\{([^}]*)\})")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_dims(type_str: str):
    """[(elem_bytes, numel)] for a (possibly tuple) HLO type."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((_DTYPE_BYTES[dt], n, tuple(int(d) for d in dims.split(","))
                    if dims else ()))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(b * n for b, n, _ in _shape_dims(type_str))


def _numel(type_str: str) -> int:
    return sum(n for _, n, _ in _shape_dims(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: list[str] = field(default_factory=list)
    called: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)   # value -> type str


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry: str | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        rest = line[m.end():]
        # operand names: inside the first balanced paren group
        depth, j = 1, 0
        for j, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        arglist = rest[:j]
        operands = re.findall(r"%([\w\.\-]+)", arglist)
        called = []
        for g1, g2 in _CALLS_RE.findall(line):
            if g1:
                called.append(g1)
            else:
                called += re.findall(r"%([\w\.\-]+)", g2)
        ins = Instr(name, type_str, opcode, line, operands, called)
        cur.instrs.append(ins)
        cur.shapes[name] = type_str
    return comps, entry


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_n = _numel(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_n  # degenerate
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs = shapes.get(ins.operands[0], "")
    dims = _shape_dims(lhs)
    k = 1
    if dims and dims[0][2]:
        shape = dims[0][2]
        for d in cdims:
            if d < len(shape):
                k *= shape[d]
    return 2.0 * out_n * k


def _trip_count(cond: Computation) -> int:
    """Trip count of a canonical jax scan/fori while-loop condition."""
    consts = [int(c) for i in cond.instrs for c in _CONST_RE.findall(i.line)]
    return max(consts) if consts else 1


@dataclass
class CostTotals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict[str, float] = field(default_factory=dict)
    per_collective_count: dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0,
            include_traffic: bool = True):
        self.flops += other.flops * mult
        if include_traffic:
            self.traffic_bytes += other.traffic_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0) + v * mult
        for k, v in other.per_collective_count.items():
            self.per_collective_count[k] = \
                self.per_collective_count.get(k, 0) + v * mult


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "copy-start", "copy-done", "after-all"}


def analyze(text: str) -> CostTotals:
    comps, entry = parse_module(text)
    if entry is None:
        # fall back to a computation never called by others
        called_by = set()
        for c in comps.values():
            for i in c.instrs:
                called_by.update(i.called)
        roots = [n for n in comps if n not in called_by]
        entry = roots[0] if roots else (next(iter(comps)) if comps else None)
    if entry is None:
        return CostTotals()
    memo: dict[str, CostTotals] = {}

    def comp_cost(name: str) -> CostTotals:
        if name in memo:
            return memo[name]
        memo[name] = CostTotals()  # break cycles defensively
        c = comps.get(name)
        if c is None:
            return memo[name]
        t = CostTotals()
        for ins in c.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            # flops
            if op == "dot":
                t.flops += _dot_flops(ins, c.shapes)
            elif op == "convolution":
                t.flops += 2.0 * _numel(ins.type_str) * 8  # tiny convs only
            elif op in ("fusion", "call", "while", "conditional", "map",
                        "reduce", "sort", "scatter", "reduce-window"):
                pass  # handled via called computations / below
            elif op not in _SKIP_TRAFFIC:
                t.flops += _numel(ins.type_str)
            # traffic model: operands + result once per execution.  A
            # fusion's internals stay on-chip (registers/VMEM), so fused
            # computations contribute traffic only at their call site —
            # this is what makes the HBM term TPU-shaped rather than an
            # unfused-CPU artifact.
            if op not in _SKIP_TRAFFIC and op not in ("call", "while",
                                                      "conditional"):
                t.traffic_bytes += _shape_bytes(ins.type_str)
                for on in ins.operands:
                    if on in c.shapes:
                        t.traffic_bytes += _shape_bytes(c.shapes[on])
            # collectives
            if base in COLLECTIVES and not op.endswith("-done"):
                nbytes = sum(_shape_bytes(c.shapes[on])
                             for on in ins.operands if on in c.shapes)
                if nbytes == 0:
                    nbytes = _shape_bytes(ins.type_str)
                t.collective_bytes += nbytes
                t.per_collective[base] = t.per_collective.get(base, 0) + nbytes
                t.per_collective_count[base] = \
                    t.per_collective_count.get(base, 0) + 1
            # recurse
            if op == "while":
                body = cond = None
                m = re.search(r"body=%([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%([\w\.\-]+)", ins.line)
                if m:
                    body = m.group(1)
                if mc:
                    cond = mc.group(1)
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    t.add(comp_cost(body), trips)
                if cond in comps:
                    t.add(comp_cost(cond), trips)
            elif ins.called:
                for cn in ins.called:
                    t.add(comp_cost(cn), 1.0,
                          include_traffic=(op != "fusion"))
        memo[name] = t
        return t

    return comp_cost(entry)
