"""Post-compile HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` has no collective numbers, so we parse the optimized
HLO text: build a {value name -> byte size} table from every instruction's
result type, then sum *operand* sizes for each collective op (the bytes
that actually cross links).  Async pairs are counted once via their
``-start`` halves.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[^\s]+)\s+([\w\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    per_op_bytes: dict[str, int] = field(default_factory=dict)
    per_op_count: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.per_op_bytes.values())

    def to_dict(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "per_op_bytes": dict(self.per_op_bytes),
                "per_op_count": dict(self.per_op_count)}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes for every collective in the optimized module."""
    sizes: dict[str, int] = {}
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        sizes[name] = _shape_bytes(type_str)
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base not in COLLECTIVES or opcode.endswith("-done"):
            continue
        # operand list: %names inside the first (...) after the opcode
        rest = line[m.end():]
        paren = rest.find("(")
        operands = 0
        if paren >= 0:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            arglist = rest[paren + 1:j]
            for on in re.findall(r"%([\w\.\-]+)", arglist):
                operands += sizes.get(on, 0)
        if operands == 0:
            # fallback: result size (all-gather result >= operand; fine as
            # a conservative bound when operands were not resolvable)
            operands = sizes[name]
        stats.per_op_bytes[base] = stats.per_op_bytes.get(base, 0) + operands
        stats.per_op_count[base] = stats.per_op_count.get(base, 0) + 1
    return stats


def roofline_terms(*, global_flops: float, global_bytes: float,
                   collective_bytes_per_dev: float, n_devices: int,
                   peak_flops: float, hbm_bw: float, ici_bw: float) -> dict:
    """The three roofline terms, in seconds (assignment formulas)."""
    compute_s = global_flops / (n_devices * peak_flops)
    memory_s = global_bytes / (n_devices * hbm_bw)
    collective_s = collective_bytes_per_dev / ici_bw
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}
