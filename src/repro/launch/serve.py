"""Serving launcher: batched decode behind the paged-KV pool with
two-phase-calibrated admission.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_smoke
from repro.models import init_params
from repro.serving import BatchServer, ServerConfig, two_phase_admission


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--pages", type=int, default=96)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--testing-steps", type=int, default=150)
    ap.add_argument("--running-steps", type=int, default=300)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServerConfig(batch_size=args.batch_size, max_len=args.max_len,
                        n_pages=args.pages, page_tokens=args.page_tokens,
                        max_new_tokens=args.max_new_tokens)
    report = two_phase_admission(
        lambda: BatchServer(cfg, params, scfg),
        testing_steps=args.testing_steps,
        running_steps=args.running_steps)
    print(f"[serve] arch={cfg.name}")
    for k, v in report.items():
        print(f"[serve]   {k}: {v}")


if __name__ == "__main__":
    main()
