"""Restore + elastic reshard from an LSM checkpoint store.

``restore_state`` reconciles (base ⊕ deltas) newest-wins and rebuilds the
pytree; ``reshard_restore`` places it onto an arbitrary mesh via the same
logical-axis tables used for training — restoring onto a *different* mesh
shape (elastic scaling after losing a pod, or growing into one) is the
same code path as a same-shape restart.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.distributed.sharding import default_rules, tree_shardings
from .store import LSMCheckpointStore, unflatten_state


def _reassemble(flat: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Undo the store's optional per-param sharding."""
    out: dict[str, np.ndarray] = {}
    shapes = {k[:-len("::shape")]: v for k, v in flat.items()
              if k.endswith("::shape")}
    groups: dict[str, dict[int, np.ndarray]] = {}
    for k, v in flat.items():
        if k.endswith("::shape"):
            continue
        path, _, tag = k.rpartition("::")
        if tag == "full":
            out[path] = v
        else:
            groups.setdefault(path, {})[int(tag)] = v
    for path, parts in groups.items():
        arr = np.concatenate([parts[i] for i in sorted(parts)])
        out[path] = arr.reshape(shapes[path])
    # undo the store's bf16-as-uint16 encoding
    final: dict[str, np.ndarray] = {}
    for path, v in out.items():
        if path.endswith("@bf16"):
            import ml_dtypes
            final[path[:-len("@bf16")]] = v.view(ml_dtypes.bfloat16)
        else:
            final[path] = v
    return final


def restore_state(store: LSMCheckpointStore) -> tuple[dict, int]:
    """Returns (state pytree of host arrays, last committed step)."""
    flat = _reassemble(store.read_merged())
    return unflatten_state(flat), store.manifest.last_step


def reshard_restore(store: LSMCheckpointStore, mesh, axes_tree,
                    rules=None) -> tuple[dict, int]:
    """Restore and place onto ``mesh`` with the framework sharding rules.

    ``axes_tree`` is the logical-axes pytree matching the stored state
    (e.g. ``train_state_axes(cfg)``); works for any mesh shape, which is
    the elasticity contract."""
    state, step = restore_state(store)
    rules = rules or default_rules(mesh)
    shardings = tree_shardings(mesh, rules, state, axes_tree)
    placed = jax.tree.map(jax.device_put, state, shardings)
    return placed, step
