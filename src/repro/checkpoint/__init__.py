from .store import (CheckpointManifest, LSMCheckpointStore, ShardKey,
                    flatten_state, unflatten_state)
from .restore import reshard_restore, restore_state

__all__ = ["CheckpointManifest", "LSMCheckpointStore", "ShardKey",
           "flatten_state", "unflatten_state", "reshard_restore",
           "restore_state"]
