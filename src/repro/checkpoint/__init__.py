from .store import (CheckpointManifest, EngineSnapshotStore,
                    LSMCheckpointStore, ShardKey, flatten_state,
                    unflatten_state)
from .restore import reshard_restore, restore_state

__all__ = ["CheckpointManifest", "EngineSnapshotStore",
           "LSMCheckpointStore", "ShardKey", "flatten_state",
           "unflatten_state", "reshard_restore", "restore_state"]
