"""LSM-structured checkpoint store: the paper's technique applied to the
framework's largest background-I/O problem.

Training emits *delta* checkpoints — only the shards that changed (for a
full step that is every shard; for fine-grained emitters like per-expert
or embedding-row updates it is a small subset).  Each delta is an
immutable *component* (one ``.npz`` per component + manifest entry), so
the store is literally an LSM-tree keyed by (param path, shard index):

  * put_delta()  == a write batch into the memory component
  * write-out    == a flush (sequential I/O, budget-metered)
  * background   == merges chosen by a pluggable MergePolicy and paced by
    compaction    a MergeScheduler under a byte budget — the exact
                  classes Sections 4-6 of the paper study; restore cost
                  is the "query performance" the component constraint
                  bounds
  * restore      == a newest-wins point-lookup reconciliation per shard

The two-phase methodology decides the sustainable checkpoint cadence: a
testing phase measures max delta-ingest throughput under the budget, the
running phase validates the chosen cadence against p99 step-stall time
(benchmarks/ckpt_twophase.py).

Manifest commits are atomic (write-new + rename), so a crash between
commits restores the previous consistent view — the fault-tolerance
contract restart tests rely on.
"""
from __future__ import annotations

import json
import os
import time
import zipfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import NamedTuple, Optional

import numpy as np

from repro.core.component import Component, LSMTree, MergeOp
from repro.core.constraints import ComponentConstraint, GlobalConstraint
from repro.core.iostack import CorruptionError, IOStack, data_crc32
from repro.core.policies import MergePolicy, TieringPolicy
from repro.core.scheduler import GreedyScheduler, MergeScheduler


class ShardKey(NamedTuple):
    path: str                 # flattened param path "layers/attn/wq"
    index: int                # shard ordinal within the param


def flatten_state(tree, prefix="") -> dict[str, np.ndarray]:
    """Pytree -> {path: ndarray} (host numpy)."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_state(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_state(flat: dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for path, v in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


@dataclass
class CheckpointManifest:
    """Atomic-commit view: which components exist and their key sets."""
    components: list[dict] = field(default_factory=list)   # newest last
    last_step: int = -1

    def to_json(self) -> str:
        return json.dumps({"components": self.components,
                           "last_step": self.last_step}, indent=1)

    @classmethod
    def from_json(cls, s: str) -> "CheckpointManifest":
        d = json.loads(s)
        return cls(components=d["components"], last_step=d["last_step"])


class LSMCheckpointStore:
    """Delta-checkpoint store with scheduler-paced background compaction."""

    def __init__(self, root: str | os.PathLike,
                 policy: Optional[MergePolicy] = None,
                 scheduler: Optional[MergeScheduler] = None,
                 constraint: Optional[ComponentConstraint] = None,
                 io_budget_bytes_per_s: float = 100e6):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.policy = policy or TieringPolicy(
            size_ratio=3, memtable_entries=1, unique_keys=1e9)
        self.scheduler = scheduler or GreedyScheduler()
        self.constraint = constraint or GlobalConstraint(12)
        self.budget = float(io_budget_bytes_per_s)
        self.tree = LSMTree(unique_keys=1e18)
        self.manifest = self._load_manifest()
        self._files: dict[int, Path] = {}
        self.running: dict[int, MergeOp] = {}
        self._io_spent = 0.0               # bytes of background I/O done
        self.stats = {"deltas": 0, "compactions": 0, "bytes_written": 0,
                      "stall_events": 0}
        self._rehydrate()

    # ------------------------------------------------------------ manifest
    def _manifest_path(self) -> Path:
        return self.root / "MANIFEST.json"

    def _load_manifest(self) -> CheckpointManifest:
        p = self._manifest_path()
        if p.exists():
            return CheckpointManifest.from_json(p.read_text())
        return CheckpointManifest()

    def _commit_manifest(self):
        tmp = self._manifest_path().with_suffix(".tmp")
        tmp.write_text(self.manifest.to_json())
        os.replace(tmp, self._manifest_path())   # atomic on POSIX

    def _rehydrate(self):
        """Rebuild the scheduling-plane tree from the manifest (restart)."""
        for entry in self.manifest.components:
            comp = Component(size=entry["bytes"], level=entry["level"],
                             created_at=entry["stamp"])
            comp_file = self.root / entry["file"]
            entry["cid"] = comp.cid
            self.tree.add(comp)
            self._files[comp.cid] = comp_file

    # ------------------------------------------------------------- writes
    def put_delta(self, step: int, delta: dict[str, np.ndarray],
                  shards_per_param: int = 1) -> bool:
        """Persist one delta checkpoint as a new Level-0 component.

        Returns False (stall) when the component constraint is violated —
        the trainer should keep going and retry at the next cadence tick
        (the write-stall control law, applied to checkpoint pressure).
        """
        if self.constraint.violated(self.tree):
            self.stats["stall_events"] += 1
            return False
        fname = f"delta-{step:08d}-{int(time.time_ns() % 1e9)}.npz"
        arrays = {}
        for path, arr in delta.items():
            # numpy cannot serialize ml_dtypes; store bf16 as raw uint16
            if arr.dtype.name == "bfloat16":
                arr = np.asarray(arr).view(np.uint16)
                path = path + "@bf16"
            splits = np.array_split(arr.reshape(-1), shards_per_param) \
                if shards_per_param > 1 else [arr]
            if shards_per_param > 1:
                arrays[f"{path}::shape"] = np.asarray(arr.shape)
                for i, s in enumerate(splits):
                    arrays[f"{path}::{i}"] = s
            else:
                arrays[f"{path}::full"] = arr
        fpath = self.root / fname
        np.savez(fpath, **arrays)
        nbytes = fpath.stat().st_size
        comp = Component(size=float(nbytes), level=0,
                         created_at=float(step))
        self.tree.add(comp)
        self._files[comp.cid] = fpath
        self.manifest.components.append(
            {"file": fname, "bytes": nbytes, "level": 0,
             "stamp": float(step), "cid": comp.cid, "step": step})
        self.manifest.last_step = max(self.manifest.last_step, step)
        self._commit_manifest()
        self.stats["deltas"] += 1
        self.stats["bytes_written"] += nbytes
        return True

    # ------------------------------------------------------- background I/O
    def pump(self, budget_bytes: float) -> float:
        """Advance compaction by a bandwidth quantum (greedy-scheduled)."""
        for op in self.policy.collect_merges(self.tree, 0.0):
            self.running[op.op_id] = op
        if not self.running:
            return 0.0
        alloc = self.scheduler.allocate(list(self.running.values()))
        spent = 0.0
        for op_id, frac in alloc.items():
            if frac <= 0:
                continue
            op = self.running[op_id]
            q = budget_bytes * frac
            op.written += q
            spent += q
            if op.remaining_output <= 0:
                self._complete_compaction(op)
        return spent

    def drain(self, max_pumps: int = 1000):
        for _ in range(max_pumps):
            for op in self.policy.collect_merges(self.tree, 0.0):
                self.running[op.op_id] = op
            if not self.running:
                return
            self.pump(1e15)

    def _complete_compaction(self, op: MergeOp):
        """Merge the input delta files newest-wins into one component."""
        inputs = sorted(op.inputs, key=lambda c: c.created_at)
        merged: dict[str, np.ndarray] = {}
        max_stamp = 0.0
        for comp in inputs:                      # oldest -> newest
            with np.load(self._files[comp.cid]) as z:
                for k in z.files:
                    merged[k] = z[k]
            max_stamp = max(max_stamp, comp.created_at)
        fname = f"merged-L{op.output_level}-{int(time.time_ns() % 1e12)}.npz"
        fpath = self.root / fname
        np.savez(fpath, **merged)
        nbytes = fpath.stat().st_size
        # scheduling plane
        op.output_size = float(nbytes)
        op.written = float(nbytes)
        for c in op.inputs:
            self.tree.remove(c)
        out = Component(size=float(nbytes), level=op.output_level,
                        created_at=max_stamp)
        self.tree.add(out)
        # manifest + files
        kept_cids = {c.cid for c in op.inputs}
        for c in op.inputs:
            p = self._files.pop(c.cid, None)
            if p is not None and p.exists():
                p.unlink()
        self._files[out.cid] = fpath
        self.manifest.components = [e for e in self.manifest.components
                                    if e.get("cid") not in kept_cids]
        self.manifest.components.append(
            {"file": fname, "bytes": nbytes, "level": op.output_level,
             "stamp": max_stamp, "cid": out.cid, "step": int(max_stamp)})
        self._commit_manifest()
        self.running.pop(op.op_id, None)
        self.stats["compactions"] += 1
        self.stats["bytes_written"] += nbytes

    # ------------------------------------------------------------- reads
    def read_merged(self) -> dict[str, np.ndarray]:
        """Newest-wins reconciliation across all live components."""
        entries = sorted(self.manifest.components, key=lambda e: e["stamp"])
        merged: dict[str, np.ndarray] = {}
        for e in entries:
            with np.load(self.root / e["file"]) as z:
                for k in z.files:
                    merged[k] = z[k]
        return merged

    def num_components(self) -> int:
        return self.tree.num_components()


class EngineSnapshotStore:
    """Durable snapshot of a live ``StorageGroup``'s SSTable state — the
    checkpoint half of crash recovery (``core/wal.py`` replays the
    tree-tagged WAL suffix on top).

    Layout: one ``table-t<tree>-<stamp>-<cid>.npz`` per live SSTable of
    every tree (primary AND index trees) and a ``SNAPSHOT.json``
    manifest committed LAST via the same write-new + rename idiom as
    ``LSMCheckpointStore`` — a crash anywhere mid-save (the
    ``mid-snapshot`` fault point fires between table files) leaves the
    PREVIOUS manifest intact, so recovery always sees a consistent
    (manifest, files) pair.  The manifest carries one section per tree
    (``trees``: tables + per-tree ``flushed_lsn`` + stamp) plus the
    group-level ``flushed_lsn`` (the min over trees): the global WAL
    replay origin that makes snapshot + suffix == full history.  Legacy
    single-tree manifests (flat ``tables``) are still readable —
    ``RecoverySession`` maps them to a one-section group.  Stale table
    files from aborted or superseded saves are swept on the next
    successful ``save``.

    Integrity: every table's manifest entry carries a CRC32 of its
    content (``data_crc32`` — the same formula live ``SSTable``s seal
    and the scrub pass verifies), checked on EVERY load: bit-rot in a
    snapshot file surfaces as a typed ``CorruptionError`` at restore,
    never as silently-wrong reads.  All file I/O routes through an
    ``IOStack`` (transient-fault retries, ENOSPC classification), so
    snapshot saves survive injected EIO and stall cleanly on a full
    disk."""

    MANIFEST = "SNAPSHOT.json"

    def __init__(self, root: str | os.PathLike,
                 io: Optional[IOStack] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.io = io if io is not None else IOStack()

    def _manifest_path(self) -> Path:
        return self.root / self.MANIFEST

    def save(self, group) -> dict:
        """Write every tree's live SSTables plus a manifest; atomic at
        the manifest commit.  Call under ``group.lock()``
        (``StorageGroup.snapshot`` does) with no half-open state you
        care about — running merges are NOT captured (their inputs are,
        so recovery simply redoes that compaction work)."""
        sections = []
        keep = {self.MANIFEST}
        for tree in group.trees:
            tables = []
            for t in tree._order:
                keys, vals = t._host()
                if len(keys) == 0:
                    continue
                fname = (f"table-t{tree.tree_id}-{t.data_stamp:08d}"
                         f"-{t.component.cid}.npz")
                self.io.savez(self.root / fname, keys=keys, vals=vals)
                keep.add(fname)
                tables.append({"file": fname,
                               "level": int(t.component.level),
                               "stamp": int(t.data_stamp),
                               "created_at": float(t.component.created_at),
                               "entries": int(len(keys)),
                               "crc": int(data_crc32(keys, vals))})
                if group.faults is not None:
                    group.faults.hit("mid-snapshot")
            sections.append({"tree": tree.tree_id, "name": tree.name,
                             "tables": tables,
                             "flushed_lsn": int(tree.flushed_lsn),
                             "stamp": int(tree._stamp)})
        manifest = {"trees": sections,
                    "flushed_lsn": int(group.flushed_lsn),
                    "now": float(group.now),
                    "stamp": int(group._stamp)}
        self.io.write_atomic_text(self._manifest_path(),
                                  json.dumps(manifest, indent=1))
        for p in self.root.iterdir():            # sweep stale table files
            if p.name not in keep and p.name.startswith("table-"):
                self.io.unlink(p)
        return manifest

    def load(self) -> Optional[dict]:
        """The last committed manifest, or None (no snapshot yet)."""
        p = self._manifest_path()
        if not p.exists():
            return None
        return json.loads(self.io.read_text(p))

    def load_tree_tables(self, section: dict):
        """Yield ``(keys, vals, meta)`` per saved table of ONE tree
        section, newest-last — the iterable ``LSMTree.restore_tables``
        rebinds.  Also accepts a legacy flat manifest (it carries the
        same ``tables`` key).  Each table's content is CRC-verified
        against its manifest entry (when present — legacy manifests
        carry none): a mismatch raises ``CorruptionError`` rather than
        restoring rotten data."""
        for meta in section["tables"]:
            try:
                with self.io.load_npz(self.root / meta["file"]) as z:
                    keys = z["keys"].astype(np.uint32)
                    vals = z["vals"].astype(np.int32)
            except (zipfile.BadZipFile, ValueError, KeyError) as e:
                # the container itself is rotten (zip-level CRC or a
                # torn write): same typed outcome as a content mismatch
                raise CorruptionError(
                    f"snapshot table {meta['file']!r} is unreadable: "
                    f"{e}") from e
            want = meta.get("crc")
            if want is not None and data_crc32(keys, vals) != int(want):
                raise CorruptionError(
                    f"snapshot table {meta['file']!r} fails its "
                    f"manifest checksum (bit-rot or torn write)")
            yield keys, vals, meta

    def find_table(self, tree_id: int, stamp: int, crc: int):
        """Locate a saved table matching (tree, stamp, checksum) — the
        scrub pass's repair source.  Returns verified ``(keys, vals)``
        or None when no matching durable copy exists."""
        snap = self.load()
        if snap is None:
            return None
        sections = snap.get("trees")
        if sections is None:
            sections = [dict(snap, tree=0)]
        for sec in sections:
            if int(sec.get("tree", 0)) != int(tree_id):
                continue
            for meta in sec["tables"]:
                if int(meta.get("stamp", -1)) != int(stamp) or \
                        int(meta.get("crc", -1)) != int(crc):
                    continue
                p = self.root / meta["file"]
                if not p.exists():
                    continue
                try:
                    with self.io.load_npz(p) as z:
                        keys = z["keys"].astype(np.uint32)
                        vals = z["vals"].astype(np.int32)
                except (zipfile.BadZipFile, ValueError, KeyError):
                    continue        # this copy is rotten too: keep looking
                if data_crc32(keys, vals) == int(crc):
                    return keys, vals
        return None

    # legacy name: a flat single-tree manifest IS a tree section
    load_tables = load_tree_tables
