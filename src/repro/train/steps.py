"""Step factories: train_step / prefill_step / decode_step as pjit-ready
functions plus their input/output shardings and abstract input specs.

These are the objects the dry-run lowers and the launcher executes — one
code path for both (ShapeDtypeStructs in, compiled executable out).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (default_rules, make_constrainer,
                                        sharding_for, tree_shardings)
from repro.models import (abstract_params, cache_logical_axes, decode_step,
                          init_cache, param_logical_axes, prefill, train_loss)
from repro.models.config import ModelConfig, ShapeCell
from repro.optim import make_optimizer, opt_state_logical_axes
from repro.optim.schedules import cosine_schedule


def TrainState(**kw) -> dict:
    """{"params": ..., "opt": ...} as a plain dict (a real pytree)."""
    return dict(**kw)


# ---------------------------------------------------------------------------
# Abstract input specs (assignment deliverable: ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.family == "vlm":
            text = max(S - cfg.n_patches, 1)
            batch = {"tokens": jax.ShapeDtypeStruct((B, text), i32),
                     "patches": jax.ShapeDtypeStruct(
                         (B, cfg.n_patches, cfg.d_model), f32)}
        elif cfg.family == "encdec":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "frames": jax.ShapeDtypeStruct(
                         (B, cfg.enc_frames, cfg.d_model), f32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return batch
    # decode: one new token against an S-long cache
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"tokens": jax.ShapeDtypeStruct((B,), i32), "cache": cache}


def batch_shardings(cfg, mesh: Mesh, rules, batch_specs: dict):
    ax = {"tokens": ("batch", None), "patches": ("batch", None, None),
          "frames": ("batch", None, None)}
    out = {}
    for k, v in batch_specs.items():
        out[k] = sharding_for(mesh, rules, tuple(v.shape),
                              ax.get(k, ("batch",) + (None,) * (len(v.shape) - 1)))
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def effective_microbatches(cfg: ModelConfig, mesh: Mesh,
                           global_batch: int) -> int:
    """Clamp the configured grad-accumulation factor so each microbatch
    still divides the data-parallel axes (per-device batch >= 1)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= int(mesh.shape[a])
    mb = min(cfg.microbatches, max(global_batch // dp, 1))
    while global_batch % mb or (global_batch // mb) % dp:
        mb -= 1
        if mb <= 1:
            return 1
    return mb


def make_train_step(cfg: ModelConfig, mesh: Mesh, rules=None, *,
                    microbatches: int | None = None,
                    learning_rate: float = 3e-4,
                    warmup_steps: int = 100, total_steps: int = 10_000):
    """Returns (step_fn, state_shardings, batch_sharding_fn).

    ``step_fn(state, batch) -> (state, metrics)`` — pure, donate-ready.
    ``microbatches`` defaults to the architecture's configured
    grad-accumulation factor; the accumulator dtype is
    ``cfg.accum_dtype`` (bf16 for the 1T-param config, fp32 otherwise).
    """
    rules = rules or default_rules(mesh)
    sh = make_constrainer(mesh, rules)
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    microbatches = cfg.microbatches if microbatches is None else microbatches
    acc_dt = jnp.dtype(cfg.accum_dtype)

    def loss_fn(params, batch):
        return train_loss(cfg, params, batch, sh=sh)

    def step_fn(state, batch):
        params, opt_state = state["params"], state["opt"]
        if microbatches > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + (g / microbatches).astype(acc_dt),
                    g_acc, grads)
                return (g_acc, l_acc + loss / microbatches), None

            mbs = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches) + a.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)),
                                            mbs)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(opt_state["step"], warmup_steps, total_steps,
                             learning_rate)
        new_params, new_opt = opt_update(params, grads, opt_state, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params=new_params, opt=new_opt), metrics

    p_axes = param_logical_axes(cfg)
    p_abs = abstract_params(cfg)
    o_abs = jax.eval_shape(opt_init, p_abs)
    o_axes = opt_state_logical_axes(cfg.optimizer, p_axes, p_abs)
    state_shardings = TrainState(
        params=tree_shardings(mesh, rules, p_abs, p_axes),
        opt=tree_shardings(mesh, rules, o_abs, o_axes))

    def abstract_state():
        return TrainState(params=p_abs, opt=o_abs)

    return step_fn, state_shardings, abstract_state


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    from repro.models import init_params
    opt_init, _ = make_optimizer(cfg.optimizer)
    params = init_params(cfg, key)
    return TrainState(params=params, opt=opt_init(params))


def train_state_axes(cfg: ModelConfig):
    p_axes = param_logical_axes(cfg)
    p_abs = abstract_params(cfg)
    opt_init, _ = make_optimizer(cfg.optimizer)
    o_abs = jax.eval_shape(opt_init, p_abs)
    return TrainState(params=p_axes,
                      opt=opt_state_logical_axes(cfg.optimizer, p_axes, p_abs))


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, mesh: Mesh, max_len: int, rules=None):
    rules = rules or default_rules(mesh)
    sh = make_constrainer(mesh, rules)

    def prefill_fn(params, batch):
        return prefill(cfg, params, batch, max_len, sh=sh)

    p_abs = abstract_params(cfg)
    p_shard = tree_shardings(mesh, rules, p_abs, param_logical_axes(cfg))
    return prefill_fn, p_shard


def make_decode_step(cfg: ModelConfig, mesh: Mesh, rules=None):
    """Returns (decode_fn, param_shardings, cache_shardings_fn)."""
    rules = rules or default_rules(mesh)
    sh = make_constrainer(mesh, rules)

    def decode_fn(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, sh=sh)

    p_abs = abstract_params(cfg)
    p_shard = tree_shardings(mesh, rules, p_abs, param_logical_axes(cfg))

    def cache_shardings(batch: int, max_len: int):
        c_abs = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
        return tree_shardings(mesh, rules, c_abs, cache_logical_axes(cfg))

    return decode_fn, p_shard, cache_shardings
