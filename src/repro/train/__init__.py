from .steps import (TrainState, input_specs, make_decode_step,
                    make_prefill_step, make_train_step, train_state_axes)

__all__ = ["TrainState", "input_specs", "make_decode_step",
           "make_prefill_step", "make_train_step", "train_state_axes"]
