"""Background integrity scrub: detect bit-rot in live SSTables,
quarantine, and repair — budget-charged from ``pump``.

Every table seals a content CRC when it binds into a tree's read view
(flush, merge completion, snapshot restore — ``SSTable.seal_checksum``,
the same ``data_crc32`` formula the snapshot manifest records).  The
``Scrubber`` re-verifies those seals continuously: each pump epoch
reserves a budget slice (``entries_per_epoch``, charged like any other
background I/O) and streams the running CRC over the current table's
key bytes then value bytes, so one quantum costs O(quantum) no matter
how large the table — the verify state (table, phase, offset, running
CRC) carries across epochs, and a full rotation over every live table
of every tree is one *scrub pass*.

On a mismatch the table is QUARANTINED immediately — removed from the
read view, the filter stack, the scheduling plane, and any running
merge that counts it as an input (surviving inputs are released back
to the policy) — so a corrupt run can never serve another read.  Then
repair, in order of cost:

1. **Snapshot copy**: if the snapshot store holds a table with the
   same (tree, stamp, checksum), reload it, verify, and rebind at the
   quarantined table's exact (stamp, level) rank — reads resume
   bit-identically.
2. **WAL rebuild**: otherwise, if the WAL (plus archive) still covers
   the tree's history, the tree's ENTIRE disk state is rebuilt —
   restore the snapshot section, replay the tree's frames up to its
   ``flushed_lsn`` into one fresh newest-stamped run (memtables are
   untouched; they own everything at and above ``flushed_lsn``).
3. **Unrepairable**: no durable copy survives.  The tree is marked
   ``corrupt`` and every subsequent read raises
   ``UnrepairableCorruptionError`` — a typed error, never a wrong
   answer.

All counters are flat numbers (``stats``) rolled up by
``engine.health()`` and summed fleet-wide.
"""
from __future__ import annotations

import bisect
import zlib
from typing import Optional

import numpy as np

from .iostack import CorruptionError
from .sstable import SSTable


class Scrubber:
    """Incremental CRC verifier over a ``StorageGroup``'s live tables.

    Driven from ``StorageGroup._pump_locked`` (group lock ALWAYS held
    in ``step``): each epoch spends at most ``entries_per_epoch`` of
    the pump budget advancing the stream.  ``store`` (an
    ``EngineSnapshotStore`` or None) is the preferred repair source."""

    def __init__(self, group, store=None, entries_per_epoch: int = 256):
        self.group = group
        self.store = store
        self.entries_per_epoch = max(1, int(entries_per_epoch))
        self.stats = {"scrub_passes": 0, "scrub_tables_checked": 0,
                      "scrub_entries": 0, "tables_quarantined": 0,
                      "tables_repaired": 0, "tables_unrepairable": 0}
        self._queue: list[tuple[int, int]] = []   # (tree_id, cid) this pass
        self._cur: Optional[tuple[int, int]] = None
        self._phase = 0        # 0 = keys, 1 = vals
        self._pos = 0          # entries verified in the current phase
        self._crc = 0          # running CRC across both phases
        self._pass_open = False

    # ------------------------------------------------------------ stepping
    def _refill(self) -> None:
        if self._pass_open:
            self.stats["scrub_passes"] += 1
        self._queue = [(t.tree_id, x.component.cid)
                       for t in self.group.trees if not t.corrupt
                       for x in t._order]
        self._pass_open = bool(self._queue)

    def step(self, budget_entries: int) -> int:
        """Advance the scrub stream by up to ``budget_entries`` units
        (one unit = one entry's keys OR values hashed — a full table
        verify costs 2n units, the read I/O of touching its bytes
        twice).  Returns units spent.  Group lock held by the caller."""
        spent = 0
        g = self.group
        last_refill_spent = -1     # guard: never refill twice for free
        while spent < int(budget_entries):
            if self._cur is None:
                if not self._queue:
                    if last_refill_spent == spent:
                        break      # a whole pass cost nothing: all skips
                    self._refill()
                    last_refill_spent = spent
                    if not self._queue:
                        break
                tid, cid = self._queue.pop(0)
                tree = g.trees[tid]
                table = tree.tables.get(cid)
                if table is None or table.crc32 is None or tree.corrupt:
                    continue          # merged away / unsealed: skip free
                self._cur = (tid, cid)
                self._phase = 0
                self._pos = 0
                self._crc = 0
            tid, cid = self._cur
            tree = g.trees[tid]
            table = tree.tables.get(cid)
            if table is None or tree.corrupt:
                self._cur = None      # vanished mid-verify: abandon
                continue
            data = table.keys_np if self._phase == 0 else table.vals_np
            dt = np.uint32 if self._phase == 0 else np.int32
            n = len(data)
            take = min(int(budget_entries) - spent, n - self._pos)
            if take > 0:
                chunk = np.ascontiguousarray(
                    data[self._pos:self._pos + take], dt)
                self._crc = zlib.crc32(chunk.tobytes(), self._crc)
                self._pos += take
                spent += take
                self.stats["scrub_entries"] += take
            if self._pos >= n:
                if self._phase == 0:
                    self._phase = 1
                    self._pos = 0
                    continue
                # both phases done: verdict
                self.stats["scrub_tables_checked"] += 1
                if self._crc != table.crc32:
                    self._handle_corrupt(tree, table)
                self._cur = None
            if take <= 0 and self._cur is not None:
                break                 # budget exhausted mid-table
        return spent

    # ----------------------------------------------------------- repair
    def _handle_corrupt(self, tree, table: SSTable) -> None:
        """Quarantine ``table`` and repair (group lock held)."""
        stamp = int(table.data_stamp)
        level = int(table.component.level)
        created_at = float(table.component.created_at)
        want_crc = int(table.crc32)
        self.stats["tables_quarantined"] += 1
        self._quarantine(tree, table)
        if self._repair_from_store(tree, stamp, level, created_at,
                                   want_crc):
            self.stats["tables_repaired"] += 1
            return
        if self._rebuild_tree_from_wal(tree):
            self.stats["tables_repaired"] += 1
            return
        tree.corrupt = True
        self.stats["tables_unrepairable"] += 1

    def _quarantine(self, tree, table: SSTable) -> None:
        """Remove a corrupt table from every plane it is visible in —
        read view, filter stack, scheduling metadata, running merges
        (surviving merge inputs are released back to the policy)."""
        cid = table.component.cid
        tree.tables.pop(cid, None)
        try:
            tree.meta.remove(table.component)
        except ValueError:
            pass
        tree._order = [t for t in tree._order if t.component.cid != cid]
        tree._fstack.note_remove(cid)
        for op_id, rm in list(tree.running.items()):
            if any(t.component.cid == cid for t in rm.inputs):
                for c in rm.op.inputs:
                    c.merging = False
                del tree.running[op_id]
        tree._invalidate_view()

    def _rebind(self, tree, keys, vals, level: int, stamp: int,
                created_at: float) -> None:
        """Bind repaired content at the quarantined table's exact
        (stamp, level) rank, so newest-wins ordering is unchanged."""
        t = SSTable.build(keys, vals, level=level, created_at=created_at,
                          interpret=self.group.interpret)
        t.data_stamp = int(stamp)
        t.component.stamp = float(stamp)
        t.seal_checksum()
        tree.meta.add(t.component)
        tree.tables[t.component.cid] = t
        pos = bisect.bisect_left(tree._order, tree._order_key(t),
                                 key=tree._order_key)
        tree._order.insert(pos, t)
        tree._fstack.note_add(t)
        tree._invalidate_view()

    def _repair_from_store(self, tree, stamp: int, level: int,
                           created_at: float, want_crc: int) -> bool:
        if self.store is None:
            return False
        try:
            got = self.store.find_table(tree.tree_id, stamp, want_crc)
        except CorruptionError:
            return False
        if got is None:
            return False
        self._rebind(tree, got[0], got[1], level, stamp, created_at)
        return True

    def _rebuild_tree_from_wal(self, tree) -> bool:
        """Rebuild the tree's ENTIRE disk state from snapshot + WAL:
        restore the (verified) snapshot section, then replay this
        tree's frames below its ``flushed_lsn`` into one fresh run.
        Memtables are untouched — they own [flushed_lsn, now)."""
        g = self.group
        if g.wal is None:
            return False
        base = 0
        restored = []
        sec: dict = {}
        if self.store is not None:
            snap = self.store.load()
            if snap is not None:
                sections = snap.get("trees")
                if sections is None:
                    sections = [dict(snap, tree=0)]
                for s in sections:
                    if int(s.get("tree", 0)) == tree.tree_id:
                        sec = s
                        break
                if sec:
                    try:
                        restored = list(self.store.load_tree_tables(sec))
                    except CorruptionError:
                        return False    # snapshot itself is rotten
                    base = int(sec.get("flushed_lsn", 0))
        if g.wal.oldest_lsn > base:
            return False                # history gap: cannot rebuild
        upto = tree.flushed_lsn
        # wipe the disk plane (memtables stay)
        for t in list(tree._order):
            try:
                tree.meta.remove(t.component)
            except ValueError:
                pass
            tree._fstack.note_remove(t.component.cid)
        tree.tables.clear()
        tree._order = []
        for rm in tree.running.values():
            for c in rm.op.inputs:
                c.merging = False
        tree.running.clear()
        tree._invalidate_view()
        if restored:
            tree.restore_tables(restored, sec)
        # one fresh newest-stamped run holds the replayed suffix
        kv: dict[int, int] = {}
        for ftree, fbase, ks, vs in g.wal.frames_since(base):
            if ftree != tree.tree_id or fbase >= upto:
                continue
            end = min(len(ks), upto - fbase)
            skip = max(0, base - fbase)
            for k, v in zip(ks[skip:end].tolist(), vs[skip:end].tolist()):
                kv[k] = v
        if kv:
            sk = np.array(sorted(kv), np.uint32)
            sv = np.array([kv[int(k)] for k in sk], np.int32)
            run = SSTable.build(sk, sv,
                                level=tree.policy.flush_target_level(),
                                created_at=g.now, interpret=g.interpret)
            tree._bind_table(run)
        self._queue = [(t, c) for t, c in self._queue
                       if t != tree.tree_id]    # stale cids of this pass
        return True
