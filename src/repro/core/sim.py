"""Fluid discrete-event simulator of an LSM-tree under an I/O budget.

Faithful to the paper's experimental setup (Section 3): a write budget
(default 100 MB/s = 102400 entries/s at 1 KB/entry) shared by flushes
(strict priority, as in the paper) and merges (split by the pluggable
merge scheduler); two memory components; writes stall when the component
constraint is violated (or are slowed by an optional write-rate
controller, used by bLSM and the Figure 13 "Limit" variant).

Rates are piecewise-constant between events, so completions, queue
transitions and latencies are computed exactly — a 2-hour experiment
simulates in milliseconds, deterministically.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from .component import Component, FlushOp, LSMTree, MergeOp, MergeState
from .constraints import ComponentConstraint, NoConstraint
from .metrics import Trace
from .policies import MergePolicy
from .scheduler import MergeScheduler

EPS = 1e-9
INF = float("inf")


# --------------------------------------------------------------------------
# Arrival processes / clients — shared by BOTH two-phase backends: the
# fluid simulator below integrates them event-by-event, the engine-backed
# harness (``twophase.EngineSystem``) integrates them per tick via
# ``cum_entries`` and replays the result as real ``put_batch`` traffic.
# --------------------------------------------------------------------------
class ArrivalProcess:
    """Piecewise-constant arrival rate (entries/s)."""

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def next_change(self, t: float) -> float:
        return INF

    def cum_entries(self, t0: float, t1: float) -> float:
        """Exact integral of ``rate`` over ``[t0, t1)``, stepping through
        the piecewise-constant segments — the tick-level arrival count the
        engine-backed harness offers to ``put_batch``."""
        total, t = 0.0, t0
        while t < t1 - EPS:
            nxt = min(self.next_change(t), t1)
            if nxt <= t:
                nxt = t1
            total += self.rate(t) * (nxt - t)
            t = nxt
        return total


class ConstantArrival(ArrivalProcess):
    def __init__(self, rate: float):
        self._rate = float(rate)

    def rate(self, t: float) -> float:
        return self._rate


class BurstyArrival(ArrivalProcess):
    """Alternates normal_rate for normal_s seconds, burst_rate for burst_s
    (Figure 13: 2000/s for 25 min, 8000/s for 5 min)."""

    def __init__(self, normal_rate: float, burst_rate: float,
                 normal_s: float, burst_s: float):
        self.nr, self.br = float(normal_rate), float(burst_rate)
        self.ns, self.bs = float(normal_s), float(burst_s)

    def _phase(self, t: float) -> tuple[bool, float]:
        period = self.ns + self.bs
        u = t % period
        if u < self.ns:
            return False, (t - u) + self.ns
        return True, (t - u) + period

    def rate(self, t: float) -> float:
        burst, _ = self._phase(t)
        return self.br if burst else self.nr

    def next_change(self, t: float) -> float:
        _, nxt = self._phase(t)
        return nxt


@dataclass
class OpenClient:
    """Open system (Figure 5b): arrivals are independent of processing."""

    arrivals: ArrivalProcess
    closed = False


@dataclass
class ClosedClient:
    """Closed system (Figure 5a): next write submitted only after the
    previous completes; arrival rate == service capacity."""

    n_threads: int = 1
    per_thread_rate: float = 250_000.0  # in-memory insert rate, entries/s
    closed = True

    @property
    def capacity(self) -> float:
        return self.n_threads * self.per_thread_rate


# --------------------------------------------------------------------------
@dataclass
class SimConfig:
    bandwidth: float = 102_400.0       # write-budget entries/s (100 MB/s)
    entry_size: int = 1024
    memtable_entries: float = 131_072  # 128 MB
    num_memtables: int = 2
    unique_keys: float = 100e6
    mem_write_rate: float = 250_000.0  # open-system in-memory capacity
    flush_priority: bool = True        # flush preempts merge I/O


WriteRateController = Callable[[float, LSMTree], float]  # (t, tree) -> cap


class LSMSimulator:
    """Fluid simulation of one LSM-tree run."""

    def __init__(self, policy: MergePolicy, scheduler: MergeScheduler,
                 constraint: ComponentConstraint | None = None,
                 config: SimConfig | None = None,
                 write_controller: Optional[WriteRateController] = None,
                 fresh_tree: bool = False):
        self.policy = policy
        self.scheduler = scheduler
        self.constraint = constraint or NoConstraint()
        self.cfg = config or SimConfig()
        self.controller = write_controller
        self.tree = LSMTree(self.cfg.unique_keys, self.cfg.entry_size)
        if not fresh_tree:
            policy.initial_tree(self.tree)

    @property
    def write_capacity(self) -> float:
        """In-memory insert capacity (entries/s) — the per-thread rate
        ``run_two_phase`` gives the testing phase's closed client.  Part
        of the backend-agnostic system protocol (see ``twophase.py``)."""
        return self.cfg.mem_write_rate

    # -- main loop ----------------------------------------------------------
    def run(self, client, duration: float) -> Trace:
        cfg = self.cfg
        tr = Trace(duration=duration, closed_system=client.closed,
                   n_clients=getattr(client, "n_threads", 1))
        self.scheduler.reset()

        t = 0.0
        queue = 0.0                 # open-system backlog (entries)
        arrived = 0.0
        served = 0.0
        fill = 0.0                  # active memtable fill (entries)
        sealed: list[float] = []    # sealed memtable sizes awaiting flush
        flush: Optional[FlushOp] = None
        mem_stall = False           # active memtable full, no free slot
        ops: list[MergeOp] = []
        stall_start: Optional[float] = None
        constraint_stalled = self.constraint.violated(self.tree)

        # initial merges (a freshly loaded tree may already be mergeable)
        ops.extend(self.policy.collect_merges(self.tree, t))
        tr.record_components(t, self.tree.num_components())

        def capacity() -> float:
            if mem_stall or constraint_stalled:
                return 0.0
            cap = cfg.mem_write_rate if not client.closed else client.capacity
            if self.controller is not None:
                cap = min(cap, max(self.controller(t, self.tree), 0.0))
            return cap

        while t < duration - EPS:
            # ---- rates for this segment
            cap = capacity()
            mu = cap if client.closed else client.arrivals.rate(t)
            if client.closed:
                service = cap
            else:
                service = cap if queue > EPS else min(mu, cap)
            flush_rate = 0.0
            if flush is not None:
                flush_rate = cfg.bandwidth if cfg.flush_priority else cfg.bandwidth / 2
            merge_budget = max(cfg.bandwidth - flush_rate, 0.0)
            alloc = self.scheduler.allocate(ops) if ops else {}
            rates = {op.op_id: alloc.get(op.op_id, 0.0) * merge_budget for op in ops}

            tr.record_capacity(t, service if client.closed else cap)

            # ---- stall bookkeeping
            stalled_now = mem_stall or constraint_stalled
            if stalled_now and stall_start is None:
                stall_start = t
            elif not stalled_now and stall_start is not None:
                tr.stalls.append((stall_start, t))
                stall_start = None

            # ---- next event horizon
            dt = duration - t
            if service > EPS:
                room = cfg.memtable_entries - fill
                dt = min(dt, max(room, 0.0) / service)
            if not client.closed and queue > EPS and mu < service - EPS:
                dt = min(dt, queue / (service - mu))
            if flush is not None and flush_rate > EPS:
                dt = min(dt, flush.remaining / flush_rate)
            for op in ops:
                r = rates[op.op_id]
                if r > EPS:
                    dt = min(dt, op.remaining_output / r)
            if not client.closed:
                dt = min(dt, client.arrivals.next_change(t) - t)
            dt = max(dt, 0.0)
            if dt <= EPS and t > 0:
                dt = EPS  # defensive: avoid zero-progress loops

            # ---- integrate segment
            t2 = t + dt
            arrived += mu * dt
            served += service * dt
            if not client.closed:
                queue = max(0.0, queue + (mu - service) * dt)
            fill += service * dt
            if flush is not None:
                flush.written += flush_rate * dt
            for op in ops:
                op.written += rates[op.op_id] * dt
            tr.record_arrival(t2, arrived)
            tr.record_service(t2, served)
            t = t2

            # ---- fire events
            # memtable full?  (slots = active + sealed/flushing memtables)
            if fill >= cfg.memtable_entries - 1e-6 and not mem_stall:
                busy = len(sealed) + (1 if flush is not None else 0)
                if busy < cfg.num_memtables - 1:
                    sealed.append(fill)
                    fill = 0.0
                else:
                    # all slots busy -> writer must wait for a flush
                    mem_stall = True
            # start a flush if idle
            if flush is None and sealed:
                flush = FlushOp(size=sealed.pop(0))
            # flush done?
            if flush is not None and flush.remaining <= 1e-6:
                comp = Component(size=flush.size, level=self.policy.flush_target_level(),
                                 created_at=t)
                self.tree.add(comp)
                flush = None
                if mem_stall:
                    sealed.append(fill)
                    fill = 0.0
                    mem_stall = False
                if sealed:
                    flush = FlushOp(size=sealed.pop(0))
                ops.extend(self.policy.collect_merges(self.tree, t))
                constraint_stalled = self.constraint.violated(self.tree)
                tr.record_components(t, self.tree.num_components())
            # merges done?
            done = [op for op in ops if op.done]
            for op in done:
                op.state = MergeState.DONE
                ops.remove(op)
                self.policy.complete_merge(self.tree, op, t)
                tr.merges_completed += 1
                tr.merge_sizes.append(op.output_size)
                tr.merge_arity.append(len(op.inputs))
            if done:
                ops.extend(self.policy.collect_merges(self.tree, t))
                constraint_stalled = self.constraint.violated(self.tree)
                tr.record_components(t, self.tree.num_components())

        if stall_start is not None:
            tr.stalls.append((stall_start, duration))
        tr.record_arrival(duration, arrived)
        tr.record_service(duration, served)
        tr.record_components(duration, self.tree.num_components())
        return tr
