"""The paper's two-phase evaluation methodology (Sections 1, 3.2).

Testing phase: closed-system model, write as fast as possible, measure the
maximum write throughput (excluding the first 20 minutes of warm-up).

Running phase: open-system model, constant arrivals at ``utilization``
(default 95%) of the measured maximum; percentile *write* latencies
(queuing + processing) decide whether that maximum is sustainable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from .metrics import Trace
from .sim import (ArrivalProcess, ClosedClient, ConstantArrival, LSMSimulator,
                  OpenClient, SimConfig)

SystemFactory = Callable[[], LSMSimulator]


@dataclass
class TwoPhaseResult:
    max_throughput: float            # entries/s measured in the testing phase
    arrival_rate: float              # entries/s used in the running phase
    testing: Trace
    running: Trace
    write_latencies: dict[float, float] = field(default_factory=dict)
    processing_latencies: dict[float, float] = field(default_factory=dict)

    @property
    def sustainable(self) -> bool:
        """Paper's criterion: the running phase shows no large stalls and
        bounded tail write latency (we use p99 < 10 s as 'small')."""
        return self.write_latencies.get(99, float("inf")) < 10.0

    def summary(self) -> dict:
        return {
            "max_throughput": self.max_throughput,
            "arrival_rate": self.arrival_rate,
            "running_stalls": len(self.running.stalls),
            "running_stall_time": self.running.stall_time(),
            "p50_write_latency": self.write_latencies.get(50),
            "p99_write_latency": self.write_latencies.get(99),
            "sustainable": self.sustainable,
        }


def run_two_phase(testing_system: SystemFactory,
                  running_system: SystemFactory | None = None,
                  utilization: float = 0.95,
                  testing_duration: float = 7200.0,
                  running_duration: float = 7200.0,
                  warmup: float = 1200.0,
                  closed_threads: int = 1,
                  pcts=(50, 90, 99, 99.9),
                  arrivals: Callable[[float], ArrivalProcess] | None = None,
                  ) -> TwoPhaseResult:
    """Run the two-phase evaluation.

    ``testing_system`` builds the system used to *measure* max throughput
    (the paper uses the fair scheduler here — and, for size-tiered /
    partitioned policies, the force-min variants).  ``running_system``
    builds the system evaluated under constant 95% arrivals (defaults to
    the same factory).  ``arrivals`` optionally overrides the running-phase
    arrival process given the computed rate (e.g. BurstyArrival).
    """
    running_system = running_system or testing_system

    sim = testing_system()
    testing = sim.run(ClosedClient(n_threads=closed_threads,
                                   per_thread_rate=sim.cfg.mem_write_rate),
                      testing_duration)
    max_tp = testing.throughput(t_from=warmup)

    rate = utilization * max_tp
    proc = arrivals(rate) if arrivals is not None else ConstantArrival(rate)
    sim2 = running_system()
    running = sim2.run(OpenClient(arrivals=proc), running_duration)

    return TwoPhaseResult(
        max_throughput=max_tp,
        arrival_rate=rate,
        testing=testing,
        running=running,
        write_latencies=running.write_latency_percentiles(pcts),
        processing_latencies=running.processing_latency_percentiles(pcts),
    )
