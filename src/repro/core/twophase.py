"""The paper's two-phase evaluation methodology (Sections 1, 3.2) —
backend-agnostic: the same harness drives the fluid simulator AND the
real engine.

Testing phase: closed-system model, write as fast as possible, measure the
maximum write throughput (excluding the first 20 minutes of warm-up).

Running phase: open-system model, constant arrivals at ``utilization``
(default 95%) of the measured maximum; percentile *write* latencies
(queuing + processing, warm-up excluded) decide whether that maximum is
sustainable.

Backends.  ``run_two_phase`` takes factories of any object satisfying the
``TwoPhaseSystem`` protocol below:

* ``LSMSimulator`` / ``BLSMSimulator`` — the fluid model: multi-hour
  experiments integrated exactly in milliseconds (the paper's figures).
* ``EngineSystem`` — the REAL ``LSMEngine``: closed/open clients issue
  ``put_batch`` traffic while background I/O is paced at the configured
  bandwidth, either by the wall-clock ``BackgroundDriver`` pump thread
  (``realtime=True``) or by a deterministic virtual clock that pumps
  inline (``realtime=False``).  The engine's write path reports
  (admitted, offered) events into a ``metrics.WriteTraceRecorder``, so
  arrival/service curves, stall intervals and every ``Trace`` metric —
  and therefore ``TwoPhaseResult.sustainable`` — work unchanged.  The
  realtime harness inherits the engine's bounded background quanta
  (streaming merges + incremental read-view maintenance): each pump
  holds the lock for O(quantum), so measured tails reflect the
  scheduler's I/O allocation, not compute cliffs the scheduler cannot
  see (``benchmarks/latency_tail.py`` quantifies the difference).
* ``fleet.FleetSystem`` — an ``LSMFleet`` of key-partitioned shards:
  the same client loop, but batches scatter across N engines and the
  background budget is split fleet-wide by the ``GlobalBudgetArbiter``
  (``benchmarks/fleet_scaling.py`` runs the harness at shard counts
  1..8).

Both backends share the client abstractions in ``sim.py``
(``ClosedClient``/``OpenClient``/``ArrivalProcess``): the simulator
integrates them event-by-event, ``EngineSystem`` integrates them per tick
(``ArrivalProcess.cum_entries``) and replays the result as real batched
writes against the data plane.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from .engine import ENTRY_BYTES, BackgroundDriver, LSMEngine
from .metrics import Trace, WriteTraceRecorder
from .sim import ArrivalProcess, ClosedClient, ConstantArrival, OpenClient


@runtime_checkable
class TwoPhaseSystem(Protocol):
    """What ``run_two_phase`` needs from a backend: one run under a client
    for a duration, returning a ``Trace``, plus the in-memory write
    capacity the testing phase's closed client is allowed to offer."""

    @property
    def write_capacity(self) -> float: ...

    def run(self, client, duration: float) -> Trace: ...


SystemFactory = Callable[[], TwoPhaseSystem]


@dataclass
class TwoPhaseResult:
    max_throughput: float            # entries/s measured in the testing phase
    arrival_rate: float              # entries/s used in the running phase
    testing: Trace
    running: Trace
    write_latencies: dict[float, float] = field(default_factory=dict)
    processing_latencies: dict[float, float] = field(default_factory=dict)

    @property
    def sustainable(self) -> bool:
        """Paper's criterion: the running phase shows no large stalls and
        bounded tail write latency (we use p99 < 10 s as 'small').
        ``run_two_phase`` always computes p99 regardless of the caller's
        ``pcts``, so the verdict never falls back to the missing-key
        default."""
        return self.write_latencies.get(99, float("inf")) < 10.0

    def summary(self) -> dict:
        return {
            "max_throughput": self.max_throughput,
            "arrival_rate": self.arrival_rate,
            "running_stalls": len(self.running.stalls),
            "running_stall_time": self.running.stall_time(),
            "p50_write_latency": self.write_latencies.get(50),
            "p99_write_latency": self.write_latencies.get(99),
            "p999_write_latency": self.write_latencies.get(99.9),
            "sustainable": self.sustainable,
        }


# --------------------------------------------------------------------------
# The engine-backed system
# --------------------------------------------------------------------------
@dataclass
class EngineSystem:
    """Drives a real ``LSMEngine`` under the two-phase clients.

    Each ``run`` builds a fresh engine from ``engine_factory`` and ticks a
    client loop: open clients draw arrivals from the shared
    ``ArrivalProcess`` (queueing in front of the engine, as in Figure 5b),
    closed clients offer writes as fast as ``write_capacity`` accrues
    (Figure 5a); each tick's batch goes through ``put_batch`` under the
    engine lock.  Background I/O is paced at ``bandwidth_bytes_per_s``:

    * ``realtime=True`` — the ``BackgroundDriver`` pump thread delivers
      the budget against the wall clock while the client loop sleeps
      between ticks; timestamps are ``time.monotonic`` offsets.
    * ``realtime=False`` — a deterministic virtual clock: every tick
      advances ``tick_s`` and pumps the accrued entry budget inline
      (fractional quanta carry over), so runs are exactly reproducible.

    Measurement is the engine's own write path: an attached
    ``WriteTraceRecorder`` turns per-batch (admitted, offered) events into
    the arrival/service curves, writer-observed stall intervals and
    capacity steps that ``Trace``'s metrics consume.  The capacity model
    matches the fluid simulator: the in-memory insert budget accrues at
    ``write_capacity`` entries/s and stops accruing while the writer is
    stalled.
    """

    engine_factory: Callable[[], LSMEngine]
    bandwidth_bytes_per_s: float
    mem_write_rate: float = 50_000.0   # in-memory insert capacity, entries/s
    tick_s: float = 0.01               # client pacing quantum (run seconds)
    realtime: bool = False
    seed: int = 0
    key_space: int = 1 << 20           # uniform workload key universe
    max_batch: int = 1 << 15           # cap on a single put_batch call
    last_engine: LSMEngine | None = None   # engine of the most recent run
    # Optional write-rate controller (the paper's fig 27 ``cap(t) =
    # C/(a + b*n_components)`` law): called each tick as
    # ``controller(t, engine)`` under the engine lock and returns the
    # instantaneous insert-capacity ceiling in entries/s; the effective
    # capacity is ``min(write_capacity, controller(t, eng))``.  None
    # (default) keeps the uncontrolled constant-capacity model.
    write_controller: Callable[[float, LSMEngine], float] | None = None

    @property
    def write_capacity(self) -> float:
        return self.mem_write_rate

    def run(self, client, duration: float) -> Trace:
        eng = self.engine_factory()
        self.last_engine = eng
        tr = Trace(duration=duration, closed_system=client.closed,
                   n_clients=getattr(client, "n_threads", 1))
        vt = {"t": 0.0}
        if self.realtime:
            t0 = time.monotonic()
            clock = lambda: time.monotonic() - t0  # noqa: E731
        else:
            clock = lambda: vt["t"]                # noqa: E731
        capacity = client.capacity if client.closed else self.mem_write_rate
        rec = WriteTraceRecorder(tr, clock, capacity=capacity)
        eng.attach_write_recorder(rec)
        rng = np.random.default_rng(self.seed)
        pump_per_s = self.bandwidth_bytes_per_s / ENTRY_BYTES
        driver = None
        if self.realtime:
            driver = BackgroundDriver(eng, self.bandwidth_bytes_per_s,
                                      quantum_s=self.tick_s)
            driver.start()

        arrived = 0.0          # client arrivals generated so far
        admitted = 0           # entries the engine has accepted
        admit_credit = 0.0     # in-memory insert budget (entries)
        pump_credit = 0.0      # virtual-mode background budget carry
        lock = eng.lock()
        t_prev = 0.0
        try:
            while t_prev < duration - 1e-12:
                if self.realtime:
                    t = clock()
                    if t >= duration:
                        break
                    t = max(t, t_prev)
                else:
                    t = min(t_prev + self.tick_s, duration)
                    vt["t"] = t
                dt = t - t_prev

                # capacity is NOT bankable (the simulator's service is
                # min(mu, cap) with unused capacity discarded): at most
                # one tick's worth of insert budget accrues, so a backlog
                # drains at ``capacity`` — never in one giant batch.  The
                # 1.0 floor lets sub-entry-per-tick capacities accumulate
                # to whole entries instead of rounding to zero forever.
                cap_t = capacity
                if self.write_controller is not None:
                    with lock:
                        cap_t = min(capacity, self.write_controller(t, eng))
                admit_credit = min(admit_credit + cap_t * dt,
                                   max(cap_t * dt, 1.0))
                if client.closed:
                    offer = int(min(admit_credit, self.max_batch))
                else:
                    arrived += client.arrivals.cum_entries(t_prev, t)
                    rec.on_arrivals(arrived)
                    backlog = arrived - admitted
                    offer = int(min(backlog, admit_credit, self.max_batch))
                if offer > 0:
                    keys = rng.integers(0, self.key_space, offer,
                                        dtype=np.uint32)
                    vals = rng.integers(0, 1 << 30, offer, dtype=np.int32)
                    with lock:
                        n_ok = eng.put_batch(keys, vals)
                    admitted += n_ok
                    admit_credit -= n_ok
                    if client.closed and n_ok:
                        arrived += n_ok
                        rec.on_arrivals(arrived)
                    if n_ok < offer:
                        # writer blocked: insert capacity does not accrue
                        # across a stall (the simulator's capacity() is 0
                        # while stalled)
                        admit_credit = 0.0

                if not self.realtime:
                    pump_credit += pump_per_s * dt
                    q = int(pump_credit)
                    if q > 0:
                        eng.pump(q)
                        pump_credit -= q
                else:
                    time.sleep(self.tick_s)
                with lock:
                    tr.record_components(t, eng.num_components())
                t_prev = t
        finally:
            if driver is not None:
                driver.stop()
            eng.attach_write_recorder(None)
        rec.finish(duration)
        tr.record_arrival(duration, arrived)
        with lock:
            tr.record_components(duration, eng.num_components())
            tr.merges_completed = eng.stats["merges"]
        return tr


def run_two_phase(testing_system: SystemFactory,
                  running_system: SystemFactory | None = None,
                  utilization: float = 0.95,
                  testing_duration: float = 7200.0,
                  running_duration: float = 7200.0,
                  warmup: float = 1200.0,
                  closed_threads: int = 1,
                  pcts=(50, 90, 99, 99.9),
                  arrivals: Callable[[float], ArrivalProcess] | None = None,
                  ) -> TwoPhaseResult:
    """Run the two-phase evaluation.

    ``testing_system`` builds the system used to *measure* max throughput
    (the paper uses the fair scheduler here — and, for size-tiered /
    partitioned policies, the force-min variants).  ``running_system``
    builds the system evaluated under constant 95% arrivals (defaults to
    the same factory).  ``arrivals`` optionally overrides the running-phase
    arrival process given the computed rate (e.g. BurstyArrival).

    ``warmup`` is excluded from BOTH phases' metrics: the testing-phase
    throughput measurement and the running-phase latency percentiles
    (cold-start transients would otherwise pollute the tail and the
    ``sustainable`` verdict).  p99 is always computed even when the
    caller's ``pcts`` omits it — ``TwoPhaseResult.sustainable`` needs it.
    """
    running_system = running_system or testing_system
    pcts = tuple(pcts)
    if 99 not in pcts:
        pcts = pcts + (99,)

    sim = testing_system()
    cap = getattr(sim, "write_capacity", None)
    if cap is None:  # pre-protocol duck-typed systems
        cap = sim.cfg.mem_write_rate
    testing = sim.run(ClosedClient(n_threads=closed_threads,
                                   per_thread_rate=cap),
                      testing_duration)
    max_tp = testing.throughput(t_from=warmup)

    rate = utilization * max_tp
    proc = arrivals(rate) if arrivals is not None else ConstantArrival(rate)
    sim2 = running_system()
    running = sim2.run(OpenClient(arrivals=proc), running_duration)

    return TwoPhaseResult(
        max_throughput=max_tp,
        arrival_rate=rate,
        testing=testing,
        running=running,
        write_latencies=running.write_latency_percentiles(
            pcts, t_from=warmup),
        processing_latencies=running.processing_latency_percentiles(
            pcts, t_from=warmup),
    )
