"""Sharded multi-engine serving plane: a key-partitioned fleet of
``LSMEngine`` shards behind a batched router, with fleet-level merge
arbitration under ONE global I/O budget.

Routing / consistency contract
------------------------------
Keys are partitioned by a fixed stateless hash: shard(key) =
``mix64(key) % n_shards`` (a multiplicative Fibonacci mix, so adjacent
keys spread across shards even for sequential workloads).  Every version
of a key therefore lives on exactly one shard, which gives the fleet its
consistency contract:

* **per-key ordering is guaranteed per shard, not across shards** — all
  writes to one key land on one engine in issue order, so newest-wins
  reads of any single key are exact; writes to DIFFERENT keys in one
  batch may be admitted by their shards in any interleaving, and a
  partially-stalled ``put_batch`` admits a per-shard prefix rather than
  a global prefix — callers that must know WHICH keys landed use
  ``put_batch_admitted`` (returns the admitted mask) and retry
  ``keys[~mask]``; a count-based ``keys[n:]`` retry is wrong under
  partial admission.
* shards hold DISJOINT key sets, so the scan gather is a pure k-way
  merge-sort (the newest-wins dedup of ``merge_kway_host`` is a no-op
  across shards) and a fleet replay of any put/get/scan trace is
  bit-identical to a single engine fed the same trace (pinned by
  ``tests/test_fleet.py``).

The router is fully batched: ``put_batch``/``get_batch`` scatter one
numpy ``argsort`` bucketing pass (no per-key Python), issue ONE sub-batch
per shard, and gather results back into caller order by inverting the
same permutation.  ``scan_range`` fans the ``[lo, hi)`` window out to
every shard and gathers with the existing k-way merge.  Shards are
served by a worker-thread pool, so foreground sub-batches proceed in
parallel across per-shard engine locks — one shard flushing under its
lock no longer blocks the other shards' traffic (the engines lock
internally; the fleet adds no global lock).

Background plane: the paper's merge-scheduler comparison lifted one
level.  Each shard keeps its own within-engine scheduler, but the
fleet-wide I/O budget is split across shards each pump epoch by a
``GlobalBudgetArbiter``:

* ``fair``   — largest-remainder apportionment by pending background
  debt (``scheduler.apportion_largest_remainder``, the same helper
  ``LSMEngine.pump`` uses for merge quanta, so sub-1 shares never
  starve a shard);
* ``greedy`` — the fewest-remaining-bytes shard first (Theorem 2's
  fewest-remaining-pages rule, applied to shards);
* ``single`` — one shard at a time, FIFO and never preempted (the
  strawman; unspent budget is stranded within the epoch, exactly like
  the single-threaded merge scheduler inside one engine).

``sum(shard grants) <= global budget`` holds every epoch, and no shard
is granted beyond its debt.  ``FleetBackgroundDriver`` turns epochs into
a wall-clock pacing thread (same deficit-carry discipline as
``BackgroundDriver``); ``FleetSystem`` implements the ``TwoPhaseSystem``
protocol so the paper's two-phase stall harness and the open-loop
latency methodology run unchanged against the fleet
(``benchmarks/fleet_scaling.py``).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .backend import ExecBackend
from .engine import ENTRY_BYTES, LSMEngine
from .memtable import SENTINEL_KEY, TOMBSTONE, drop_tombstones
from .metrics import (Trace, WriteTraceRecorder, amplification_stats,
                      rollup_stats)
from .scheduler import apportion_largest_remainder

_MIX64 = np.uint64(0x9E3779B97F4A7C15)   # 2^64 / golden ratio

# Per-shard work (entries) below which a pool handoff costs more than it
# buys: submit + worker wake + result is ~0.1 ms/job, admission is ~ns
# per entry.  Point batches run inline; scans and large pump epochs fan
# out (their per-shard work is ms-scale numpy that releases the GIL).
POOL_MIN_PER_SHARD = 8192


class GlobalBudgetArbiter:
    """Splits one fleet-wide I/O budget (entries per epoch) across shards
    by pending background debt.  ``allocate(debts, budget)`` returns
    per-shard integer grants with two invariants the fleet relies on
    (and tests pin): ``sum(grants) <= budget`` and
    ``grants[i] <= debts[i]`` for every shard."""

    POLICIES = ("fair", "greedy", "single")

    def __init__(self, policy: str = "fair"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown arbiter policy {policy!r}")
        self.policy = policy
        self._active: Optional[int] = None   # sticky shard ("single")
        self.epochs = 0

    def reset(self) -> None:
        self._active = None
        self.epochs = 0

    def allocate(self, debts, budget: int) -> list[int]:
        debts = [int(d) for d in debts]
        n = len(debts)
        grants = [0] * n
        remaining = int(budget)
        self.epochs += 1
        if remaining <= 0 or n == 0:
            return grants
        if self.policy == "single":
            # one shard at a time, FIFO, never preempted: the sticky
            # shard takes what it can; leftover budget is STRANDED for
            # this epoch (matching the single-threaded merge scheduler's
            # within-engine behavior) — the next epoch re-picks.
            if self._active is None or debts[self._active] == 0:
                live = [i for i in range(n) if debts[i] > 0]
                self._active = live[0] if live else None
            if self._active is not None:
                grants[self._active] = min(debts[self._active], remaining)
            return grants
        if self.policy == "greedy":
            # fewest-remaining-bytes shard first (ties by shard index)
            for i in sorted(range(n), key=lambda i: (debts[i], i)):
                if remaining <= 0:
                    break
                g = min(debts[i], remaining)
                grants[i] += g
                remaining -= g
            return grants
        # fair: largest-remainder apportionment by debt, re-apportioning
        # the leftover when a grant caps at its shard's debt.  Each round
        # either exhausts the budget or fully satisfies a shard, so this
        # terminates in <= n rounds.
        while remaining > 0:
            live = [(i, debts[i] - grants[i]) for i in range(n)
                    if debts[i] - grants[i] > 0]
            if not live:
                break
            total = float(sum(d for _, d in live))
            shares = [(i, d / total) for i, d in live]
            quanta = apportion_largest_remainder(shares, remaining)
            progressed = False
            for (i, _), q in zip(shares, quanta):
                g = min(q, debts[i] - grants[i])
                if g > 0:
                    grants[i] += g
                    remaining -= g
                    progressed = True
            if not progressed:
                break
        assert sum(grants) <= budget, "arbiter granted beyond the budget"
        return grants


class LSMFleet:
    """N key-partitioned ``LSMEngine`` shards behind a batched router
    (see module docstring for the routing/consistency contract).

    ``engine_factory(shard_index)`` builds each shard; ``parallel=True``
    serves shards from a worker-thread pool (one worker per shard) so
    foreground sub-batches and background pump grants run concurrently
    across engine locks.  Call ``close()`` (or use the fleet as a
    context manager) to retire the pool."""

    def __init__(self, n_shards: int,
                 engine_factory: Callable[[int], LSMEngine],
                 arbiter: GlobalBudgetArbiter | str = "fair",
                 parallel: bool = True,
                 backend: "ExecBackend | str | None" = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = int(n_shards)
        self.engines = [engine_factory(i) for i in range(self.n_shards)]
        # ONE execution backend for the whole fleet: when given (an
        # ExecBackend or a mode string), every shard routes its launches
        # through the same dispatch table — calibration is loaded once,
        # and a forced mode is fleet-wide (tests pin that a forced
        # backend actually reaches the shards).  None keeps whatever
        # backend each factory-built engine already carries.
        self.backend = None
        if backend is not None:
            if isinstance(backend, str):
                backend = ExecBackend(mode=backend)
            self.backend = backend
            for e in self.engines:
                e.set_backend(backend)
        self.arbiter = (GlobalBudgetArbiter(arbiter)
                        if isinstance(arbiter, str) else arbiter)
        self._pool: Optional[ThreadPoolExecutor] = None
        if parallel and self.n_shards > 1:
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_shards, thread_name_prefix="fleet-shard")
        self._recorder = None

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Graceful shutdown: retire the worker pool, then close every
        shard engine (fsyncs per-shard WALs).  Idempotent."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        for e in self.engines:
            e.close()

    def __enter__(self) -> "LSMFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- routing
    def shard_ids(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized shard of each key: ``mix64(key) % n_shards``."""
        h = keys.astype(np.uint64) * _MIX64
        h ^= h >> np.uint64(32)
        return (h % np.uint64(self.n_shards)).astype(np.int64)

    def _scatter(self, keys: np.ndarray):
        """One bucketing pass: a stable argsort by shard id.  Returns
        ``(order, bounds)`` — ``keys[order[bounds[s]:bounds[s+1]]]`` is
        shard ``s``'s sub-batch, in issue order (stability preserves
        per-key write ordering within the batch)."""
        sid = self.shard_ids(keys)
        order = np.argsort(sid, kind="stable")
        bounds = np.searchsorted(sid[order], np.arange(self.n_shards + 1))
        return order, bounds

    def _map(self, jobs: list[tuple[int, Callable]],
             use_pool: bool = True) -> dict[int, object]:
        """Run ``(shard, thunk)`` jobs — on the worker pool when present
        and ``use_pool``, inline otherwise.  Returns {shard: result}.

        Dispatch is ADAPTIVE: a pool handoff costs ~0.1 ms per job
        (submit + wake + result), while admission costs nanoseconds per
        entry, so callers fan out only when per-shard work amortizes the
        handoff (``POOL_MIN_PER_SHARD``) — small point batches run inline
        and never queue behind a pump epoch's jobs (head-of-line
        blocking on the shared pool was the dominant open-loop tail cost
        pre-fix; ``benchmarks/fleet_scaling.py`` pins the tail bar)."""
        if self._pool is None or not use_pool or len(jobs) <= 1:
            return {s: fn() for s, fn in jobs}
        futs = {s: self._pool.submit(fn) for s, fn in jobs}
        return {s: f.result() for s, f in futs.items()}

    # ------------------------------------------------------------- write
    def attach_write_recorder(self, recorder) -> None:
        """Attach a fleet-level ``WriteTraceRecorder`` (or None): ONE
        (admitted, offered) report per fleet ``put_batch``, aggregated
        across shards.  Per-shard curves attach recorders to the shard
        engines directly (``fleet.engines[s].attach_write_recorder``) —
        both levels work simultaneously."""
        self._recorder = recorder

    def put_batch(self, keys, values) -> int:
        """Scatter the batch by shard and admit each sub-batch; returns
        the total admitted.  A reserved sentinel key anywhere rejects the
        WHOLE batch atomically (before any shard admits), matching
        ``MemTable.put_batch``'s all-or-nothing validation."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        n = len(keys)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        if self.n_shards == 1:
            n_ok = self.engines[0].put_batch(keys, values)
        else:
            order, bounds = self._scatter(keys)
            jobs = []
            for s in range(self.n_shards):
                lo, hi = int(bounds[s]), int(bounds[s + 1])
                if hi > lo:
                    idx = order[lo:hi]
                    jobs.append((s, lambda e=self.engines[s],
                                 k=keys[idx], v=values[idx]:
                                 e.put_batch(k, v)))
            n_ok = sum(self._map(
                jobs, use_pool=n >= POOL_MIN_PER_SHARD * self.n_shards
            ).values())
        if self._recorder is not None and n > 0:
            self._recorder.on_puts(n_ok, n)
        return n_ok

    def put_batch_admitted(self, keys, values) -> np.ndarray:
        """Like ``put_batch`` but returns the per-position admitted MASK.

        Each shard admits a PREFIX of its scattered sub-batch (engine
        admission is prefix-shaped), so under a partial admission the
        fleet-wide admitted set is NOT a prefix of the caller's batch: a
        count-based retry (``keys[n_ok:]``) re-sends keys that already
        landed and silently drops rejected ones.  Callers that track key
        identity retry ``keys[~mask]`` instead; the rejected remainder
        keeps its relative order, so per-key write ordering holds across
        retries."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        n = len(keys)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        mask = np.zeros(n, bool)
        if n == 0:
            return mask
        if self.n_shards == 1:
            n_ok = self.engines[0].put_batch(keys, values)
            mask[:n_ok] = True
        else:
            order, bounds = self._scatter(keys)
            jobs = []
            for s in range(self.n_shards):
                lo, hi = int(bounds[s]), int(bounds[s + 1])
                if hi > lo:
                    idx = order[lo:hi]
                    jobs.append((s, lambda e=self.engines[s],
                                 k=keys[idx], v=values[idx]:
                                 e.put_batch(k, v)))
            took = self._map(
                jobs, use_pool=n >= POOL_MIN_PER_SHARD * self.n_shards)
            for s, n_s in took.items():
                lo = int(bounds[s])
                mask[order[lo:lo + n_s]] = True
        if self._recorder is not None:
            self._recorder.on_puts(int(mask.sum()), n)
        return mask

    def delete(self, key: int) -> bool:
        """Blind single-key delete (see ``LSMEngine.delete``)."""
        return self.delete_batch(np.array([key], np.uint32)) == 1

    def delete_batch(self, keys) -> int:
        """Scatter blind deletes by shard — ``put_batch`` semantics with
        TOMBSTONE values (each shard admits a prefix of its sub-batch;
        returns total admitted).  Per-key ordering vs puts holds because
        every version of a key routes to the same shard."""
        keys = np.asarray(keys, np.uint32)
        n = len(keys)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        if self.n_shards == 1:
            n_ok = self.engines[0].delete_batch(keys)
        else:
            order, bounds = self._scatter(keys)
            jobs = []
            for s in range(self.n_shards):
                lo, hi = int(bounds[s]), int(bounds[s + 1])
                if hi > lo:
                    idx = order[lo:hi]
                    jobs.append((s, lambda e=self.engines[s], k=keys[idx]:
                                 e.delete_batch(k)))
            n_ok = sum(self._map(
                jobs, use_pool=n >= POOL_MIN_PER_SHARD * self.n_shards
            ).values())
        if self._recorder is not None and n > 0:
            self._recorder.on_puts(n_ok, n)
        return n_ok

    # ------------------------------------------------------------- read
    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Scatter the key batch, resolve one fused-probe ``get_batch``
        per shard in parallel, and gather (found, values) back into
        caller order."""
        keys = np.asarray(keys, np.uint32)
        q = len(keys)
        if self.n_shards == 1:
            return self.engines[0].get_batch(keys)
        found = np.zeros(q, bool)
        vals = np.zeros(q, np.int32)
        if q == 0:
            return found, vals
        order, bounds = self._scatter(keys)
        jobs = []
        for s in range(self.n_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if hi > lo:
                idx = order[lo:hi]
                jobs.append((s, lambda e=self.engines[s], k=keys[idx]:
                             e.get_batch(k)))
        results = self._map(
            jobs, use_pool=q >= POOL_MIN_PER_SHARD * self.n_shards)
        for s, (f, v) in results.items():
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            idx = order[lo:hi]
            found[idx] = f
            vals[idx] = v
        return found, vals

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """Fan the ``[lo, hi)`` window out to every shard and resolve ALL
        run windows in one flat k-way merge (``engine.scan_runs`` exposes
        the locked snapshots).  Within a shard the snapshot is newest
        first, so the merge's dedup order is correct; across shards keys
        are disjoint, so concatenating the shards' run lists in any order
        is safe and the cross-shard part of the merge is a pure
        merge-sort.  One merge instead of N+1 (per-shard merges plus a
        gather re-merge) — the dominant scan cost halves."""
        jobs = [(s, lambda e=self.engines[s]: e.scan_runs(lo, hi))
                for s in range(self.n_shards)]
        # the window width bounds every shard's result (disjoint keys),
        # so it is the dispatch-cost proxy: narrow scans run inline
        results = self._map(
            jobs,
            use_pool=(hi - lo) >= POOL_MIN_PER_SHARD * self.n_shards)
        runs = [r for rs in results.values() for r in rs]
        if not runs:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        if len(runs) == 1:
            # copy: windows may alias live run storage.  Raw run windows
            # still carry tombstones (the per-shard scan filter runs
            # post-merge); filter here like the engine's scan plane.
            ks, vs = drop_tombstones(runs[0][0], runs[0][1])
            return ks.copy(), vs.copy()
        # the gather merge routes through the fleet backend when one was
        # plumbed, else shard 0's (all shards share dispatch semantics)
        be = self.backend or self.engines[0].backend
        return be.scan_merge(runs, drop_value=int(TOMBSTONE))

    def scan_range_dict(self, lo: int, hi: int) -> dict[int, int]:
        ks, vs = self.scan_range(lo, hi)
        return dict(zip(ks.tolist(), vs.tolist()))

    # ------------------------------------------------------------- background
    def pending_debts(self) -> list[int]:
        """Per-shard background I/O debt (entries) — the arbiter's input."""
        return [e.pending_background_entries() for e in self.engines]

    def pump(self, budget_entries: int) -> int:
        """One fleet pump epoch: the arbiter splits the global budget
        across shards by pending debt, then every granted shard pumps its
        grant (in parallel across engine locks).  Returns total entries
        spent; ``sum(grants) <= budget_entries`` always."""
        grants = self.arbiter.allocate(self.pending_debts(), budget_entries)
        jobs = [(s, lambda e=self.engines[s], g=g: e.pump(g))
                for s, g in enumerate(grants) if g > 0]
        return sum(self._map(
            jobs, use_pool=max(grants, default=0) >= POOL_MIN_PER_SHARD
        ).values())

    def drain(self, budget_entries: int = 1 << 30,
              max_pumps: int = 10_000) -> None:
        """Pump every shard until no background work remains."""
        jobs = [(s, lambda e=e: e.drain(budget_entries, max_pumps))
                for s, e in enumerate(self.engines)]
        self._map(jobs)

    # ------------------------------------------------------------- durability
    def snapshot(self, stores) -> list[dict]:
        """Per-shard snapshots: ``stores`` is one
        ``EngineSnapshotStore`` per shard (each shard fsyncs its WAL,
        saves its tables, and truncates its replayed prefix).  Returns
        the per-shard manifests."""
        jobs = [(s, lambda e=e, st=st: e.snapshot(st))
                for s, (e, st) in enumerate(zip(self.engines, stores))]
        res = self._map(jobs)
        return [res[s] for s in sorted(res)]

    def recover(self, stores, budget_per_epoch: int = 1 << 30,
                max_epochs: int = 1_000_000,
                serve_during_recovery: bool = False):
        """Fleet crash recovery under the GLOBAL budget: one
        ``wal.RecoverySession`` per shard; each epoch the arbiter splits
        ``budget_per_epoch`` across shards by remaining replay debt
        (WAL entries left plus replay-induced background work) — the
        same arbitration normal background I/O runs under, so recovery
        bandwidth competes fleet-wide exactly like merges do.  Returns
        the epoch count (virtual recovery time).

        With ``serve_during_recovery=True`` the fleet goes ONLINE
        instead: every shard opens an online ``RecoverySession`` (reads
        and writes admitted immediately, consistency per the engine's
        online-recovery contract) and the list of sessions is returned
        at once — ordinary ``fleet.pump`` epochs then drive replay as a
        per-shard debt stream, arbitrated against serving I/O by the
        same global arbiter."""
        from .wal import RecoverySession
        if serve_during_recovery:
            return [RecoverySession(e, st, online=True)
                    for e, st in zip(self.engines, stores)]
        sessions = [RecoverySession(e, st)
                    for e, st in zip(self.engines, stores)]
        epochs = 0
        for _ in range(max_epochs):
            if all(s.done for s in sessions):
                return epochs
            epochs += 1
            debts = [0 if s.done
                     else s.remaining + s.engine.pending_background_entries()
                     for s in sessions]
            grants = self.arbiter.allocate(debts, budget_per_epoch)
            jobs = [(i, lambda s=sessions[i], g=g: s.advance(g))
                    for i, g in enumerate(grants)
                    if g > 0 and not sessions[i].done]
            progressed = sum(self._map(jobs).values()) if jobs else 0
            if progressed <= 0:
                raise RuntimeError("fleet recovery stalled: budget too "
                                   "small to make progress")
        raise RuntimeError("fleet recovery exceeded max_epochs")

    # ------------------------------------------------------------- info
    @property
    def stats(self) -> dict:
        """Fleet-wide rollup of the per-shard engine ``stats`` counters
        (``metrics.rollup_stats``): ``stall_events``, ``merge_touched``,
        ``merges``, ... summed across shards."""
        return rollup_stats([e.stats for e in self.engines])

    def per_shard_stats(self) -> list[dict]:
        return [dict(e.stats) for e in self.engines]

    def health(self) -> dict:
        """Fleet-wide fault-plane counters: the per-shard
        ``engine.health()`` dicts summed key-wise (all values are flat
        numbers, so the rollup is exact; ``recovering`` becomes the
        COUNT of shards still replaying)."""
        out: dict = {}
        for e in self.engines:
            for k, v in e.health().items():
                out[k] = out.get(k, 0) + v
        return out

    def num_components(self) -> int:
        return sum(e.num_components() for e in self.engines)

    def total_entries(self) -> int:
        return sum(e.total_entries() for e in self.engines)

    def live_entries(self) -> int:
        """Fleet-wide live entries: shards hold disjoint keys, so the
        per-shard counts sum exactly."""
        return sum(e.live_entries() for e in self.engines)

    def amplification(self) -> dict:
        """Fleet-wide write/space amplification
        (``metrics.amplification_stats`` over the rolled-up counters —
        the fleet surface of the satellite accounting fix)."""
        return amplification_stats(self.stats,
                                   physical_entries=self.total_entries(),
                                   live_entries=self.live_entries())


class FleetBackgroundDriver:
    """Wall-clock driver for a fleet: pumps ``fleet.pump`` epochs at
    ``bandwidth_bytes_per_s`` TOTAL across all shards, with the same
    monotonic deficit-carry pacing as the single-engine
    ``BackgroundDriver`` (slow epochs are repaid by larger quanta, capped
    at 4x pace so catch-up bursts stay bounded)."""

    def __init__(self, fleet: LSMFleet, bandwidth_bytes_per_s: float,
                 quantum_s: float = 0.01):
        self.fleet = fleet
        self.rate = bandwidth_bytes_per_s
        self.quantum_s = quantum_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        t0 = time.monotonic()
        delivered = 0.0
        per_s = self.rate / ENTRY_BYTES
        q_max = max(1, int(4 * per_s * self.quantum_s))
        while not self._stop.is_set():
            deficit = (time.monotonic() - t0) * per_s - delivered
            quantum = min(int(deficit), q_max)
            if quantum >= 1:
                self.fleet.pump(quantum)
                delivered += quantum
            self._stop.wait(self.quantum_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def close(self) -> None:
        """Graceful shutdown: stop the pacing thread (in-flight epoch
        completes), then close the fleet (pool + per-shard WAL fsync).
        Idempotent."""
        self.stop()
        self.fleet.close()

    def __enter__(self) -> "FleetBackgroundDriver":
        if self._thread is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# The fleet as a TwoPhaseSystem backend
# --------------------------------------------------------------------------
@dataclass
class FleetSystem:
    """Drives an ``LSMFleet`` under the two-phase clients — the
    ``TwoPhaseSystem`` protocol implementation for the fleet, so
    ``run_two_phase`` and the open-loop latency methodology run unchanged
    against N shards (the fleet-level ``WriteTraceRecorder`` sees one
    aggregated (admitted, offered) event per batch).

    Mirrors ``twophase.EngineSystem``: closed clients offer writes as
    fast as ``write_capacity`` accrues, open clients draw arrivals from
    the shared ``ArrivalProcess``; background I/O is paced at
    ``bandwidth_bytes_per_s`` GLOBALLY — split across shards each epoch
    by the fleet's arbiter — either on the wall clock
    (``FleetBackgroundDriver``, ``realtime=True``) or by a deterministic
    inline-epoch virtual clock."""

    fleet_factory: Callable[[], LSMFleet]
    bandwidth_bytes_per_s: float
    mem_write_rate: float = 50_000.0
    tick_s: float = 0.01
    realtime: bool = False
    seed: int = 0
    key_space: int = 1 << 20
    max_batch: int = 1 << 15
    last_fleet: LSMFleet | None = None

    @property
    def write_capacity(self) -> float:
        return self.mem_write_rate

    def run(self, client, duration: float) -> Trace:
        fleet = self.fleet_factory()
        self.last_fleet = fleet
        tr = Trace(duration=duration, closed_system=client.closed,
                   n_clients=getattr(client, "n_threads", 1))
        vt = {"t": 0.0}
        if self.realtime:
            t0 = time.monotonic()
            clock = lambda: time.monotonic() - t0  # noqa: E731
        else:
            clock = lambda: vt["t"]                # noqa: E731
        capacity = client.capacity if client.closed else self.mem_write_rate
        rec = WriteTraceRecorder(tr, clock, capacity=capacity)
        fleet.attach_write_recorder(rec)
        rng = np.random.default_rng(self.seed)
        pump_per_s = self.bandwidth_bytes_per_s / ENTRY_BYTES
        driver = None
        if self.realtime:
            driver = FleetBackgroundDriver(fleet, self.bandwidth_bytes_per_s,
                                           quantum_s=self.tick_s)
            driver.start()

        arrived = 0.0
        admitted = 0
        admit_credit = 0.0
        pump_credit = 0.0
        t_prev = 0.0
        try:
            while t_prev < duration - 1e-12:
                if self.realtime:
                    t = clock()
                    if t >= duration:
                        break
                    t = max(t, t_prev)
                else:
                    t = min(t_prev + self.tick_s, duration)
                    vt["t"] = t
                dt = t - t_prev
                admit_credit = min(admit_credit + capacity * dt,
                                   max(capacity * dt, 1.0))
                if client.closed:
                    offer = int(min(admit_credit, self.max_batch))
                else:
                    arrived += client.arrivals.cum_entries(t_prev, t)
                    rec.on_arrivals(arrived)
                    backlog = arrived - admitted
                    offer = int(min(backlog, admit_credit, self.max_batch))
                if offer > 0:
                    keys = rng.integers(0, self.key_space, offer,
                                        dtype=np.uint32)
                    vals = rng.integers(0, 1 << 30, offer, dtype=np.int32)
                    n_ok = fleet.put_batch(keys, vals)
                    admitted += n_ok
                    admit_credit -= n_ok
                    if client.closed and n_ok:
                        arrived += n_ok
                        rec.on_arrivals(arrived)
                    if n_ok < offer:
                        admit_credit = 0.0
                if not self.realtime:
                    pump_credit += pump_per_s * dt
                    q = int(pump_credit)
                    if q > 0:
                        fleet.pump(q)
                        pump_credit -= q
                else:
                    time.sleep(self.tick_s)
                tr.record_components(t, fleet.num_components())
                t_prev = t
        finally:
            if driver is not None:
                driver.stop()
            fleet.attach_write_recorder(None)
            fleet.close()
        rec.finish(duration)
        tr.record_arrival(duration, arrived)
        tr.record_components(duration, fleet.num_components())
        tr.merges_completed = fleet.stats["merges"]
        return tr
