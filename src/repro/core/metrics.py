"""Trace capture and metric extraction, shared by the fluid simulator and
the real engine's two-phase harness.

Both backends record cumulative arrivals A(t) and cumulative completions
S(t) as piecewise-linear breakpoint lists.  Open-system write latency of
the x-th write is then exactly  S^-1(x) - A^-1(x)  (queuing + processing),
computed by vectorized inversion — deterministic, no sampling noise.  The
fluid simulator emits breakpoints at its event boundaries;
``WriteTraceRecorder`` ingests the real engine's discrete write-path
events (wall- or virtual-clock timestamps, one call per ``put_batch``)
into the same curves, so every metric below works unchanged for either
backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def rollup_stats(per_shard: "list[dict] | tuple[dict, ...]") -> dict:
    """Aggregate per-shard engine ``stats`` dicts (or any dicts of numeric
    counters) into one fleet-wide dict: every key present in any shard is
    summed across shards (missing keys count 0).  The fleet's
    ``LSMFleet.stats`` property and the fleet benchmarks use this so
    ``stall_events`` / ``merge_touched`` / admitted-offered accounting
    reads identically per-shard and fleet-wide."""
    out: dict = {}
    for stats in per_shard:
        for k, v in stats.items():
            out[k] = out.get(k, 0) + v
    return out


def amplification_stats(stats: dict, physical_entries: int | None = None,
                        live_entries: int | None = None) -> dict:
    """Write/space amplification from an engine (or rolled-up fleet)
    ``stats`` dict — the LSM survey's two cost axes, computable now that
    entries can die (PR 7).

    ``write_amp`` = bytes physically written (flush + merge + WAL) per
    logical byte ingested (puts AND deletes — a tombstone is a write).
    ``space_amp`` = physical entries stored (every version, every run)
    per LIVE entry (distinct keys whose newest version is not a
    tombstone); pass ``physical_entries``/``live_entries`` from the
    store (``LSMEngine.amplification`` / ``LSMFleet.amplification`` do)
    — with them omitted only ``write_amp`` is reported.  A fully
    deleted, fully compacted store has ``physical_entries ~ 0``, which
    the durability tests pin."""
    logical = float(stats.get("logical_bytes", 0))
    written = float(stats.get("flush_bytes", 0)
                    + stats.get("merge_bytes", 0)
                    + stats.get("wal_bytes", 0))
    out = {"logical_bytes": logical, "bytes_written": written,
           "write_amp": written / logical if logical > 0 else 0.0}
    if physical_entries is not None:
        live = int(live_entries or 0)
        out["physical_entries"] = int(physical_entries)
        out["live_entries"] = live
        out["space_amp"] = float(physical_entries) / max(live, 1)
    return out


def _invert(pts_t: np.ndarray, pts_v: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Given monotone piecewise-linear (t, v) breakpoints, find t(v)."""
    idx = np.searchsorted(pts_v, values, side="left")
    idx = np.clip(idx, 1, len(pts_v) - 1)
    v0, v1 = pts_v[idx - 1], pts_v[idx]
    t0, t1 = pts_t[idx - 1], pts_t[idx]
    dv = np.maximum(v1 - v0, 1e-12)
    return t0 + (values - v0) / dv * (t1 - t0)


@dataclass
class Trace:
    arrival_t: list[float] = field(default_factory=lambda: [0.0])
    arrival_v: list[float] = field(default_factory=lambda: [0.0])
    service_t: list[float] = field(default_factory=lambda: [0.0])
    service_v: list[float] = field(default_factory=lambda: [0.0])
    capacity_t: list[float] = field(default_factory=list)   # (t, capacity entries/s)
    capacity_v: list[float] = field(default_factory=list)
    comp_t: list[float] = field(default_factory=list)       # (t, #disk components)
    comp_v: list[int] = field(default_factory=list)
    stalls: list[tuple[float, float]] = field(default_factory=list)
    merges_completed: int = 0
    merge_sizes: list[float] = field(default_factory=list)  # entries written
    merge_arity: list[int] = field(default_factory=list)
    duration: float = 0.0
    closed_system: bool = False
    n_clients: int = 1

    # -- recording helpers ----------------------------------------------
    def record_arrival(self, t: float, cum: float) -> None:
        if cum > self.arrival_v[-1] or t > self.arrival_t[-1]:
            self.arrival_t.append(t)
            self.arrival_v.append(cum)

    def record_service(self, t: float, cum: float) -> None:
        if cum > self.service_v[-1] or t > self.service_t[-1]:
            self.service_t.append(t)
            self.service_v.append(cum)

    def record_capacity(self, t: float, c: float) -> None:
        if not self.capacity_t or self.capacity_v[-1] != c:
            self.capacity_t.append(t)
            self.capacity_v.append(c)

    def record_components(self, t: float, n: int) -> None:
        self.comp_t.append(t)
        self.comp_v.append(n)

    # -- metrics ----------------------------------------------------------
    @property
    def total_written(self) -> float:
        return self.service_v[-1]

    def throughput(self, t_from: float = 0.0, t_to: float | None = None) -> float:
        t_to = t_to if t_to is not None else self.duration
        st = np.asarray(self.service_t)
        sv = np.asarray(self.service_v)
        v0 = float(np.interp(t_from, st, sv))
        v1 = float(np.interp(t_to, st, sv))
        return (v1 - v0) / max(t_to - t_from, 1e-9)

    def windowed_throughput(self, window: float = 30.0) -> tuple[np.ndarray, np.ndarray]:
        edges = np.arange(0.0, self.duration + window, window)
        st = np.asarray(self.service_t)
        sv = np.asarray(self.service_v)
        cum = np.interp(edges, st, sv)
        return edges[1:], np.diff(cum) / window

    def write_latency_percentiles(self, pcts=(50, 90, 99, 99.9),
                                  n: int = 200_001,
                                  t_from: float = 0.0) -> dict[float, float]:
        """Latency (queue + processing) of the x-th write, for open systems."""
        at = np.asarray(self.arrival_t)
        av = np.asarray(self.arrival_v)
        stt = np.asarray(self.service_t)
        sv = np.asarray(self.service_v)
        lo = float(np.interp(t_from, at, av))
        # only writes that were *completed* in-window have defined latency;
        # pending writes at the end are right-censored -> extend service
        # line flat (their latency is a lower bound, conservative).
        hi = min(av[-1], sv[-1])
        if hi <= lo:
            return {p: 0.0 for p in pcts}
        xs = np.linspace(lo, hi, n)
        t_arr = _invert(at, av, xs)
        t_done = _invert(stt, sv, xs)
        lat = np.maximum(t_done - t_arr, 0.0)
        return {p: float(np.percentile(lat, p)) for p in pcts}

    def processing_latency_percentiles(self, pcts=(50, 90, 99, 99.9),
                                       n: int = 200_001,
                                       t_from: float = 0.0) -> dict[float, float]:
        """Per-write processing time = inverse instantaneous capacity at the
        write's completion time (the delay injected into that write), with
        stalled intervals contributing the remaining stall length for the
        writes in flight.  Closed systems additionally expose stall time to
        the ``n_clients`` in-flight writes only (Figure 5a discussion).
        ``t_from`` excludes writes completed before it (warm-up cutoff,
        matching ``write_latency_percentiles``)."""
        if not self.capacity_t:
            return {p: 0.0 for p in pcts}
        stt = np.asarray(self.service_t)
        sv = np.asarray(self.service_v)
        lo = float(np.interp(t_from, stt, sv)) if t_from > 0.0 else 0.0
        if sv[-1] <= lo:
            return {p: 0.0 for p in pcts}
        xs = np.linspace(lo, sv[-1], n)
        t_done = _invert(stt, sv, xs)
        ct = np.asarray(self.capacity_t)
        cv = np.asarray(self.capacity_v)
        idx = np.clip(np.searchsorted(ct, t_done, side="right") - 1, 0, len(cv) - 1)
        cap = cv[idx]
        lat = 1.0 / np.maximum(cap, 1e-9)
        if self.closed_system and self.stalls:
            # in-flight writes at each stall onset wait out the stall
            # (warm-up stalls are excluded together with warm-up writes;
            # a stall straddling the cutoff contributes its in-window part)
            extra = [s1 - max(s0, t_from) for (s0, s1) in self.stalls
                     if s1 > t_from] * self.n_clients
            if extra:
                lat = np.concatenate([lat, np.asarray(extra)])
        return {p: float(np.percentile(lat, p)) for p in pcts}

    def stall_time(self) -> float:
        return sum(s1 - s0 for (s0, s1) in self.stalls)

    def max_components(self) -> int:
        return max(self.comp_v) if self.comp_v else 0

    def summary(self) -> dict:
        return {
            "throughput": self.throughput(),
            "stall_time": self.stall_time(),
            "n_stalls": len(self.stalls),
            "merges": self.merges_completed,
            "max_components": self.max_components(),
        }


class LatencyRecorder:
    """Per-operation latency sampler for wall-clock foreground loops (the
    tail-latency benchmark's writer/reader threads).

    ``observe`` appends one operation's latency in seconds; ``percentiles``
    summarizes.  Callers measuring under a concurrent background plane
    should drive an OPEN loop — schedule operations at fixed arrival
    times and observe ``completion - scheduled`` rather than
    ``completion - issue`` — so a stall charges every operation it
    delays instead of just the one that happened to be in flight
    (coordinated-omission-free, the discipline the paper's running-phase
    latency metric assumes).
    """

    def __init__(self):
        self._samples: list[float] = []

    def __len__(self) -> int:
        return len(self._samples)

    def observe(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def percentiles(self, pcts=(50.0, 99.0, 99.9)) -> dict[float, float]:
        if not self._samples:
            return {float(p): 0.0 for p in pcts}
        a = np.asarray(self._samples)
        return {float(p): float(np.percentile(a, p)) for p in pcts}

    def summary(self) -> dict:
        p = self.percentiles()
        return {"n": len(self), "p50": p[50.0], "p99": p[99.0],
                "p999": p[99.9], "max": float(max(self._samples))
                if self._samples else 0.0}


class WriteTraceRecorder:
    """Ingests the real engine's discrete write-path events into a ``Trace``.

    The engine calls ``on_puts(admitted, offered)`` once per ``put`` /
    ``put_batch`` (one call per batch — the hot path stays vectorized);
    the harness calls ``on_arrivals(cum)`` when it generates client
    arrivals and ``finish(duration)`` at run end.  Timestamps come from
    ``clock`` — ``time.monotonic`` relative to the run start for the
    wall-clock harness, a virtual tick counter for the deterministic one —
    so the resulting arrival/service curves, stall intervals and capacity
    steps feed ``Trace``'s fluid-trace metrics unchanged.

    A stall interval opens at the first attempt that admits less than it
    offered (``admitted < offered``) and closes at the next attempt that
    admits anything — the writer-observed stall, exactly what the paper's
    write-latency metric charges.  Capacity drops to 0 during the stall so
    ``processing_latency_percentiles`` sees the injected delay.
    """

    def __init__(self, trace: "Trace", clock, capacity: float):
        self.trace = trace
        self.clock = clock
        self.capacity = float(capacity)
        self.cum = 0.0
        self.offered = 0.0        # cumulative entries offered (admitted or
                                  # not) — with ``cum`` (admitted), the
                                  # pair ``rollup_stats`` aggregates for
                                  # fleet-wide admitted/offered accounting
        self._stall_t0: float | None = None
        trace.record_capacity(0.0, self.capacity)

    @property
    def admitted(self) -> float:
        return self.cum

    def counters(self) -> dict:
        """The recorder's cumulative counters in ``rollup_stats`` shape."""
        return {"admitted": self.cum, "offered": self.offered,
                "stall_intervals": len(self.trace.stalls)
                + (1 if self._stall_t0 is not None else 0)}

    @property
    def stalled(self) -> bool:
        return self._stall_t0 is not None

    def _now(self) -> float:
        """Clock reading clamped to the trace's duration: a wall-clock
        harness can observe a put slightly after its cutoff (the loop's
        duration check happens before a possibly-blocking engine call),
        and an event stamped past ``duration`` would invert the stall
        interval ``finish`` closes at ``duration``."""
        t = self.clock()
        d = self.trace.duration
        return min(t, d) if d > 0 else t

    def on_puts(self, admitted: int, offered: int) -> None:
        if offered <= 0:
            return
        self.offered += offered
        t = self._now()
        if self._stall_t0 is not None and admitted > 0:
            # close the stall with a flat service plateau so latency
            # inversion sees no progress during [stall_t0, t]
            self.trace.record_service(t, self.cum)
            self.trace.stalls.append((self._stall_t0, t))
            self.trace.record_capacity(t, self.capacity)
            self._stall_t0 = None
        if admitted > 0:
            self.cum += admitted
            self.trace.record_service(t, self.cum)
        if admitted < offered and self._stall_t0 is None:
            self.trace.record_service(t, self.cum)
            self.trace.record_capacity(t, 0.0)
            self._stall_t0 = t

    def on_arrivals(self, cum: float) -> None:
        self.trace.record_arrival(self._now(), cum)

    def finish(self, duration: float) -> None:
        if self._stall_t0 is not None:
            self.trace.stalls.append((self._stall_t0, duration))
            self._stall_t0 = None
        self.trace.record_service(duration, self.cum)
