"""In-memory LSM component (memtable) for the real engine.

Writes append to unsorted numpy buffers (O(1) per put, like a skiplist's
amortized role here); sealing sorts once and deduplicates newest-wins,
producing the sorted run a flush turns into an SSTable.  Keys are uint32
(key == 2**32-1 is reserved as the merge kernel's sentinel), values are
int32 payload handles.
"""
from __future__ import annotations

import numpy as np

SENTINEL_KEY = np.uint32(0xFFFFFFFF)


class MemTable:
    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._keys = np.empty(self.capacity, np.uint32)
        self._vals = np.empty(self.capacity, np.int32)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def full(self) -> bool:
        return self._n >= self.capacity

    def put(self, key: int, value: int) -> None:
        if self._n >= self.capacity:
            raise RuntimeError("memtable full; seal it first")
        k = np.uint32(key)
        if k == SENTINEL_KEY:
            raise ValueError("key 2**32-1 is reserved")
        self._keys[self._n] = k
        self._vals[self._n] = np.int32(value)
        self._n += 1

    def put_batch(self, keys, values) -> None:
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        n = len(keys)
        if self._n + n > self.capacity:
            raise RuntimeError("memtable overflow")
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        self._keys[self._n:self._n + n] = keys
        self._vals[self._n:self._n + n] = values
        self._n += n

    def get(self, key: int):
        """Newest-wins lookup over the unsorted tail (scan newest-first)."""
        k = np.uint32(key)
        idx = np.flatnonzero(self._keys[:self._n] == k)
        if idx.size:
            return int(self._vals[idx[-1]])
        return None

    def seal(self):
        """Sorted, newest-wins-deduplicated (keys, values) arrays."""
        keys = self._keys[:self._n]
        vals = self._vals[:self._n]
        # stable sort keeps insertion order within equal keys; keep the last
        order = np.argsort(keys, kind="stable")
        sk, sv = keys[order], vals[order]
        last = np.ones(len(sk), bool)
        if len(sk) > 1:
            last[:-1] = sk[1:] != sk[:-1]
        return sk[last], sv[last]
