"""In-memory LSM component (memtable) for the real engine.

Writes append to unsorted numpy buffers (O(1) per put, like a skiplist's
amortized role here); sealing sorts once and deduplicates newest-wins,
producing the sorted run a flush turns into an SSTable.  Keys are uint32
(key == 2**32-1 is reserved as the merge kernel's sentinel), values are
int32 payload handles.

Deletes are TOMBSTONES: an entry whose value is the reserved
``TOMBSTONE`` sentinel (int32 min, rejected on the user put path) is a
delete marker.  It flows through seal/flush/merge as ordinary data —
newest-wins dedup resolves put-vs-delete races for free — and only the
READ plane (engine get/scan) and the bottom-level merge drop it.  The
memtable itself is tombstone-agnostic: ``get``/``get_batch``/
``scan_range`` return tombstoned entries like any other so the engine's
newest-first resolution can distinguish "deleted here" (stop searching
older runs) from "not present" (keep searching).
"""
from __future__ import annotations

import numpy as np

SENTINEL_KEY = np.uint32(0xFFFFFFFF)
TOMBSTONE = np.int32(-2**31)       # reserved value: a delete marker


def drop_tombstones(keys: np.ndarray,
                    vals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Filter delete markers out of a merged run — the read plane's last
    step (scans) and the bottom-level merge's reclamation step share it."""
    live = vals != TOMBSTONE
    if live.all():
        return keys, vals
    return keys[live], vals[live]


def sorted_lookup(sk: np.ndarray, sv: np.ndarray,
                  keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(found mask, values) for ``keys`` against a sorted unique run
    ``(sk, sv)`` — the one sorted-search used by memtables and SSTables."""
    if len(sk) == 0 or len(keys) == 0:
        return np.zeros(len(keys), bool), np.zeros(len(keys), np.int32)
    pos = np.minimum(np.searchsorted(sk, keys), len(sk) - 1)
    found = sk[pos] == keys
    return found, np.where(found, sv[pos], 0).astype(np.int32)


def scan_window(sk: np.ndarray, sv: np.ndarray, lo: int,
                hi: int) -> tuple[np.ndarray, np.ndarray]:
    """The ``[lo, hi)`` window of a sorted unique run — the one
    ``searchsorted`` slice used by memtables and SSTables on the range
    plane.  Bounds are clamped to the uint32 key space: the sentinel
    2**32-1 is never stored, so a clamped ``hi`` of 2**32 loses
    nothing."""
    i = int(np.searchsorted(sk, np.uint32(min(max(lo, 0), 0xFFFFFFFF))))
    j = int(np.searchsorted(sk, np.uint32(min(max(hi, 0), 0xFFFFFFFF))))
    return sk[i:j], sv[i:j]


class MemTable:
    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._keys = np.empty(self.capacity, np.uint32)
        self._vals = np.empty(self.capacity, np.int32)
        self._n = 0
        self.start_lsn = 0             # WAL LSN of this memtable's first
                                       # entry (set by the engine; the
                                       # oldest unflushed memtable's
                                       # start_lsn is the replay origin)
        # sorted newest-wins view, cached between writes (sealed
        # memtables are immutable, so theirs is computed exactly once)
        self._sealed: tuple[np.ndarray, np.ndarray] | None = None

    def __len__(self) -> int:
        return self._n

    @property
    def full(self) -> bool:
        return self._n >= self.capacity

    def put(self, key: int, value: int) -> None:
        if self._n >= self.capacity:
            raise RuntimeError("memtable full; seal it first")
        k = np.uint32(key)
        if k == SENTINEL_KEY:
            raise ValueError("key 2**32-1 is reserved")
        self._keys[self._n] = k
        self._vals[self._n] = np.int32(value)
        self._n += 1
        self._sealed = None

    def put_batch(self, keys, values) -> int:
        """Admit the longest prefix that fits; returns the count admitted
        (0 when full — never raises on overflow, so bulk admission needs
        no try/except on the hot path).  A reserved sentinel key anywhere
        in the batch is rejected ATOMICALLY (ValueError before any entry
        is admitted) — unlike the scalar ``put`` loop, which would admit
        the prefix before raising; batch callers get all-or-nothing
        validation instead."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        take = min(len(keys), self.capacity - self._n)
        if take > 0:
            self._keys[self._n:self._n + take] = keys[:take]
            self._vals[self._n:self._n + take] = values[:take]
            self._n += take
            self._sealed = None
        return take

    def get(self, key: int):
        """Newest-wins lookup over the unsorted tail (scan newest-first)."""
        k = np.uint32(key)
        idx = np.flatnonzero(self._keys[:self._n] == k)
        if idx.size:
            return int(self._vals[idx[-1]])
        return None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized newest-wins lookup: (found mask, values) for a key
        batch.  Small batches against a write-dirtied buffer use the
        O(n)-per-key linear scan (the scalar hot path under interleaved
        put/get); larger batches amortize one sort via the cached sealed
        view."""
        keys = np.asarray(keys, np.uint32)
        q = len(keys)
        if self._n == 0 or q == 0:
            return np.zeros(q, bool), np.zeros(q, np.int32)
        if self._sealed is None and q < 16:
            found = np.zeros(q, bool)
            vals = np.zeros(q, np.int32)
            buf_k = self._keys[:self._n]
            buf_v = self._vals[:self._n]
            for i in range(q):
                idx = np.flatnonzero(buf_k == keys[i])
                if idx.size:
                    found[i] = True
                    vals[i] = buf_v[idx[-1]]      # last write wins
            return found, vals
        sk, sv = self.seal()
        return sorted_lookup(sk, sv, keys)

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """All (key, value) with lo <= key < hi, sorted newest-wins —
        a ``scan_window`` over the cached sealed view, so a memtable
        enters the engine's k-way range merge as one sorted run exactly
        like an SSTable."""
        sk, sv = self.seal()
        return scan_window(sk, sv, lo, hi)

    def seal(self):
        """Sorted, newest-wins-deduplicated (keys, values) arrays
        (cached until the next write)."""
        if self._sealed is None:
            keys = self._keys[:self._n]
            vals = self._vals[:self._n]
            # stable sort keeps insertion order within equal keys; keep
            # the last
            order = np.argsort(keys, kind="stable")
            sk, sv = keys[order], vals[order]
            last = np.ones(len(sk), bool)
            if len(sk) > 1:
                last[:-1] = sk[1:] != sk[:-1]
            self._sealed = (sk[last], sv[last])
        return self._sealed
