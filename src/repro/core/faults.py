"""Fault injection for the durability plane: named crash points,
transient I/O fault schedules, torn-tail WAL truncation, bit-flip
corruption, and the crash/recover differential harness.

A ``FaultInjector`` is shared by an engine (or every shard of a fleet)
and armed at one of the ``CRASH_POINTS``; the instrumented site raises
``SimulatedCrash`` on the armed hit.  A "crash" in this model is the
loss of ALL in-memory state — the harness abandons the engine object
mid-operation (whatever half-updated state it holds is garbage, exactly
like a killed process) and keeps only what the durability plane put on
disk: the snapshot directory and the WAL file.  ``apply_torn_tail``
then models the page cache: everything fsynced survives; of the
appended-but-unsynced tail, an arbitrary byte prefix survives (possibly
cutting a frame in half — the WAL's CRC framing absorbs the cut).

Arming modes (both crash and I/O points): the legacy one-shot
``arm(point, after=N)`` fires exactly once on the N-th hit; persistent
mode (``every=k``) fires every k-th hit after the countdown without
re-arming ("every 3rd fsync fails"); probabilistic mode (``p=q,
seed=s``) fires each eligible hit with probability q from a SEEDED rng
(deterministic schedules for tests); ``count=c`` bounds the total
firings of a persistent/probabilistic arm (None = unbounded).

I/O faults (``IO_POINTS``, consumed by ``core/iostack.IOStack``) are
armed with ``arm_io(point, error=...)``: ``error="EIO"`` injects a
transient read/write/fsync failure the stack retries under capped
exponential backoff; ``error="ENOSPC"`` raises ``StorageFull`` (the
engine converts it to a write stall that drains when the fault is
disarmed); ``latency=seconds`` injects a slow-I/O spike (served, timed,
and counted — never an error).  ``flip_bit`` models bit-rot in a live
SSTable's payload for the scrub pass to detect.

Crash points::

    pre-flush             pump is about to build an SSTable from a
                          sealed memtable (memtable contents are only
                          in the WAL)
    mid-merge-quantum     a streaming merge quantum is about to run
                          (merge progress exists only in memory)
    post-wal-pre-memtable a write batch is logged but not yet admitted
                          (the classic ack-unknown window: the entry is
                          durable though the caller never saw True)
    mid-snapshot          between two table files of a snapshot save
                          (the manifest is not yet committed, so
                          recovery must use the previous snapshot)
    post-primary-pre-index  a write batch has been WAL-logged and
                          admitted to the PRIMARY tree but its eager
                          index maintenance has not run (multi-tree
                          groups only — recovery must rebuild index
                          consistency from the tree-tagged WAL frames)

The differential contract (``tests/test_durability.py`` pins it across
every crash point x {tiering, leveling, partitioned} x {single engine,
2-shard fleet}): entries are logged to the WAL in admission order, so
LSNs enumerate the admitted-write history.  After a crash at ANY point
plus a torn tail, recovery restores a PREFIX of that history — at least
everything synced, at most everything appended — and a reference engine
fed exactly that prefix must answer every get/get_batch/scan_range
identically.  ``WorkloadLog`` records the admitted history as it
happens; ``apply_entries`` feeds a prefix to a reference store;
``assert_reads_equal`` compares the read planes.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from .memtable import TOMBSTONE

CRASH_POINTS = ("pre-flush", "mid-merge-quantum", "post-wal-pre-memtable",
                "mid-snapshot", "post-primary-pre-index")
IO_POINTS = ("io-read", "io-write", "io-fsync", "io-replace", "io-unlink")


class SimulatedCrash(RuntimeError):
    """Raised at an armed crash point; carries the point name."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at {point!r}")
        self.point = point


class _ArmSpec:
    """One armed point's firing schedule (shared by crash and I/O
    points): countdown (``after``), then one-shot / every-k-th /
    probabilistic, optionally bounded by a total firing ``count``."""

    __slots__ = ("after", "every", "p", "count", "rng", "hits", "payload")

    def __init__(self, after: int, every: Optional[int],
                 p: Optional[float], count: Optional[int], seed: int,
                 payload: Optional[dict] = None):
        if after < 1:
            raise ValueError("after must be >= 1")
        if every is not None and every < 1:
            raise ValueError("every must be >= 1")
        if p is not None and not 0.0 < p <= 1.0:
            raise ValueError("p must be in (0, 1]")
        self.after = int(after)
        self.every = None if every is None else int(every)
        self.p = None if p is None else float(p)
        # default: legacy one-shot (a single firing disarms the point)
        if count is None and every is None and p is None:
            count = 1
        self.count = None if count is None else int(count)
        self.rng = np.random.default_rng(seed) if p is not None else None
        self.hits = 0
        self.payload = payload or {}

    def fire(self) -> bool:
        """Account one hit; True when the fault fires this hit."""
        self.hits += 1
        if self.hits < self.after:
            return False
        if self.every is not None and \
                (self.hits - self.after) % self.every != 0:
            return False
        if self.p is not None and float(self.rng.random()) >= self.p:
            return False
        if self.count is not None:
            self.count -= 1
        return True

    @property
    def exhausted(self) -> bool:
        return self.count is not None and self.count <= 0


class FaultInjector:
    """Armed crash points + transient-I/O fault schedules.  Unarmed
    points are free (one dict probe).  One injector may be shared
    across engines (fleet shards) — whichever shard hits an armed crash
    point first crashes the whole process, like reality; I/O fault
    schedules likewise apply to whichever shard's stack hits them."""

    def __init__(self):
        self._armed: dict[str, _ArmSpec] = {}
        self._io: dict[str, _ArmSpec] = {}
        self.fired: Optional[str] = None

    def arm(self, point: str, after: int = 1, every: Optional[int] = None,
            p: Optional[float] = None, count: Optional[int] = None,
            seed: int = 0) -> None:
        """Arm a crash point.  Default = the legacy one-shot countdown
        (fires on the ``after``-th hit, then disarms); ``every``/``p``
        make it persistent/probabilistic (see module docstring)."""
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r}; "
                             f"expected one of {CRASH_POINTS}")
        self._armed[point] = _ArmSpec(after, every, p, count, seed)

    def arm_io(self, point: str, error: Optional[str] = "EIO",
               after: int = 1, every: Optional[int] = None,
               p: Optional[float] = None, count: Optional[int] = None,
               seed: int = 0, latency: float = 0.0) -> None:
        """Arm a transient I/O fault at one of ``IO_POINTS``.
        ``error`` is ``"EIO"`` (retryable), ``"ENOSPC"`` (stall until
        disarmed) or ``None`` (latency-only spike); ``latency`` seconds
        are injected on every firing either way."""
        if point not in IO_POINTS:
            raise ValueError(f"unknown I/O point {point!r}; "
                             f"expected one of {IO_POINTS}")
        if error not in ("EIO", "ENOSPC", None):
            raise ValueError(f"unknown I/O error kind {error!r}")
        self._io[point] = _ArmSpec(after, every, p, count, seed,
                                   payload={"error": error,
                                            "latency": float(latency)})

    def disarm(self, point: Optional[str] = None) -> None:
        """Disarm one point (crash or I/O) or, with no argument,
        everything."""
        if point is None:
            self._armed.clear()
            self._io.clear()
            return
        self._armed.pop(point, None)
        self._io.pop(point, None)

    def hit(self, point: str) -> None:
        spec = self._armed.get(point)
        if spec is None:
            return
        if spec.fire():
            if spec.exhausted:
                del self._armed[point]
            self.fired = point
            raise SimulatedCrash(point)

    def check_io(self, point: str) -> Optional[dict]:
        """One I/O-point hit: the fault payload (``{"error", "latency"}``)
        when the schedule fires, else None.  Called by ``IOStack`` before
        each attempt, so a persistent schedule fails retries too."""
        spec = self._io.get(point)
        if spec is None:
            return None
        if not spec.fire():
            return None
        if spec.exhausted:
            del self._io[point]
        return dict(spec.payload)


def apply_torn_tail(wal, frac: float) -> int:
    """Crash the WAL: close its handle WITHOUT syncing, then keep the
    synced prefix plus ``frac`` of the unsynced appended bytes (``frac``
    in [0, 1]; a mid-frame cut is expected — reopening validates frame
    CRCs and drops the remainder).  Only the TAIL segment can tear:
    sealed segments were fsynced at rotation, so the cut lands in
    ``wal.tail_path`` alone.  Returns the total surviving byte length
    across all segments.  The ``wal`` object is dead afterwards; reopen
    the path with a fresh ``WriteAheadLog`` to recover."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError("frac must be in [0, 1]")
    wal.abort()
    sealed_bytes = wal.written_bytes - wal.tail_written_bytes
    tail_keep = wal.tail_synced_bytes + int(round(
        frac * (wal.tail_written_bytes - wal.tail_synced_bytes)))
    os.truncate(wal.tail_path, tail_keep)
    return sealed_bytes + tail_keep


def flip_bit(table, entry: int = 0, bit: int = 0) -> None:
    """Bit-rot model: flip one bit of ``entry``'s VALUE in a live
    SSTable's authoritative host mirror (values, not keys, so the run
    stays sorted and the corruption is invisible to every structural
    check — only a checksum can catch it).  The scrub pass
    (``core/scrub.py``) must detect the mismatch against the table's
    sealed CRC and quarantine + repair."""
    vals = table.vals_np
    if len(vals) == 0:
        raise ValueError("cannot corrupt an empty table")
    b = vals.view(np.uint8)
    i = int(entry) % len(vals) * vals.itemsize + (int(bit) // 8)
    b[i] ^= np.uint8(1 << (int(bit) % 8))


# ---------------------------------------------------------------------------
# Differential-harness pieces (shared by tests, the example and the
# recovery benchmark)
# ---------------------------------------------------------------------------
class WorkloadLog:
    """The admitted-write history, recorded in admission (== LSN) order.

    Append each admitted chunk as the engine acknowledges it; entry i of
    the log is the write with LSN ``base + i``, so "the durable prefix
    up to LSN L" is exactly ``log[:L - base]``.  Deletes are recorded as
    ``TOMBSTONE`` values, matching the WAL's encoding."""

    def __init__(self):
        self._keys: list[np.ndarray] = []
        self._vals: list[np.ndarray] = []
        self.n = 0

    def record(self, keys, vals) -> None:
        keys = np.asarray(keys, np.uint32)
        if len(keys) == 0:
            return
        self._keys.append(keys.copy())
        self._vals.append(np.asarray(vals, np.int32).copy())
        self.n += len(keys)

    def record_deletes(self, keys) -> None:
        keys = np.asarray(keys, np.uint32)
        self.record(keys, np.full(len(keys), TOMBSTONE, np.int32))

    def prefix(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """The first ``n`` admitted (key, value) entries."""
        if not self._keys:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        ks = np.concatenate(self._keys)[:n]
        vs = np.concatenate(self._vals)[:n]
        return ks, vs


def apply_entries(store, keys, vals, chunk: int = 512,
                  pump_budget: int = 1 << 16) -> None:
    """Feed a recorded entry sequence into an uncrashed reference store
    (engine or fleet) in order, splitting each chunk into contiguous
    put/delete runs (a ``TOMBSTONE`` value is a delete) and pumping
    through admission stalls.  Order-preserving, so the reference's
    newest-wins state matches the recorded history exactly."""
    keys = np.asarray(keys, np.uint32)
    vals = np.asarray(vals, np.int32)
    pos = 0
    while pos < len(keys):
        end = min(pos + chunk, len(keys))
        ck, cv = keys[pos:end], vals[pos:end]
        tomb = cv == TOMBSTONE
        # contiguous same-kind runs keep intra-chunk write order exact
        cuts = np.flatnonzero(np.diff(tomb)) + 1
        for rk, rv, rt in zip(np.split(ck, cuts), np.split(cv, cuts),
                              np.split(tomb, cuts)):
            done = 0
            while done < len(rk):
                if rt[0]:
                    n_ok = store.delete_batch(rk[done:])
                else:
                    n_ok = store.put_batch(rk[done:], rv[done:])
                done += n_ok
                if done < len(rk):
                    store.pump(pump_budget)
        pos = end


def assert_reads_equal(got, want, key_space: int, rng=None,
                       n_windows: int = 4) -> None:
    """Bit-identical read-plane comparison between two stores (engine or
    fleet): full-universe ``get_batch``, full-range ``scan_range``, and
    a few random sub-range scans."""
    qs = np.arange(key_space, dtype=np.uint32)
    gf, gv = got.get_batch(qs)
    wf, wv = want.get_batch(qs)
    assert np.array_equal(gf, wf), "found masks diverge"
    assert np.array_equal(gv[gf], wv[wf]), "values diverge"
    gk, gvv = got.scan_range(0, key_space)
    wk, wvv = want.scan_range(0, key_space)
    assert np.array_equal(gk, wk), "scan keys diverge"
    assert np.array_equal(gvv, wvv), "scan values diverge"
    rng = rng or np.random.default_rng(0)
    for _ in range(n_windows):
        lo = int(rng.integers(0, key_space))
        hi = int(rng.integers(lo, key_space)) + 1
        gk, gvv = got.scan_range(lo, hi)
        wk, wvv = want.scan_range(lo, hi)
        assert np.array_equal(gk, wk) and np.array_equal(gvv, wvv), \
            f"window scan [{lo},{hi}) diverges"
