"""Fault injection for the durability plane: named crash points, torn-tail
WAL truncation, and the crash/recover differential harness.

A ``FaultInjector`` is shared by an engine (or every shard of a fleet)
and armed at one of the ``CRASH_POINTS``; the instrumented site raises
``SimulatedCrash`` on the armed hit.  A "crash" in this model is the
loss of ALL in-memory state — the harness abandons the engine object
mid-operation (whatever half-updated state it holds is garbage, exactly
like a killed process) and keeps only what the durability plane put on
disk: the snapshot directory and the WAL file.  ``apply_torn_tail``
then models the page cache: everything fsynced survives; of the
appended-but-unsynced tail, an arbitrary byte prefix survives (possibly
cutting a frame in half — the WAL's CRC framing absorbs the cut).

Crash points::

    pre-flush             pump is about to build an SSTable from a
                          sealed memtable (memtable contents are only
                          in the WAL)
    mid-merge-quantum     a streaming merge quantum is about to run
                          (merge progress exists only in memory)
    post-wal-pre-memtable a write batch is logged but not yet admitted
                          (the classic ack-unknown window: the entry is
                          durable though the caller never saw True)
    mid-snapshot          between two table files of a snapshot save
                          (the manifest is not yet committed, so
                          recovery must use the previous snapshot)
    post-primary-pre-index  a write batch has been WAL-logged and
                          admitted to the PRIMARY tree but its eager
                          index maintenance has not run (multi-tree
                          groups only — recovery must rebuild index
                          consistency from the tree-tagged WAL frames)

The differential contract (``tests/test_durability.py`` pins it across
every crash point x {tiering, leveling, partitioned} x {single engine,
2-shard fleet}): entries are logged to the WAL in admission order, so
LSNs enumerate the admitted-write history.  After a crash at ANY point
plus a torn tail, recovery restores a PREFIX of that history — at least
everything synced, at most everything appended — and a reference engine
fed exactly that prefix must answer every get/get_batch/scan_range
identically.  ``WorkloadLog`` records the admitted history as it
happens; ``apply_entries`` feeds a prefix to a reference store;
``assert_reads_equal`` compares the read planes.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from .memtable import TOMBSTONE

CRASH_POINTS = ("pre-flush", "mid-merge-quantum", "post-wal-pre-memtable",
                "mid-snapshot", "post-primary-pre-index")


class SimulatedCrash(RuntimeError):
    """Raised at an armed crash point; carries the point name."""

    def __init__(self, point: str):
        super().__init__(f"simulated crash at {point!r}")
        self.point = point


class FaultInjector:
    """Countdown-armed crash points.  ``arm(point, after=k)`` fires on
    the k-th hit of ``point``; unarmed points are free (one dict probe).
    One injector may be shared across engines (fleet shards) — whichever
    shard hits the armed point first crashes the whole process, like
    reality."""

    def __init__(self):
        self._armed: dict[str, int] = {}
        self.fired: Optional[str] = None

    def arm(self, point: str, after: int = 1) -> None:
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r}; "
                             f"expected one of {CRASH_POINTS}")
        if after < 1:
            raise ValueError("after must be >= 1")
        self._armed[point] = int(after)

    def disarm(self) -> None:
        self._armed.clear()

    def hit(self, point: str) -> None:
        count = self._armed.get(point)
        if count is None:
            return
        if count <= 1:
            del self._armed[point]
            self.fired = point
            raise SimulatedCrash(point)
        self._armed[point] = count - 1


def apply_torn_tail(wal, frac: float) -> int:
    """Crash the WAL: close its handle WITHOUT syncing, then keep the
    synced prefix plus ``frac`` of the unsynced appended bytes (``frac``
    in [0, 1]; a mid-frame cut is expected — reopening validates frame
    CRCs and drops the remainder).  Only the TAIL segment can tear:
    sealed segments were fsynced at rotation, so the cut lands in
    ``wal.tail_path`` alone.  Returns the total surviving byte length
    across all segments.  The ``wal`` object is dead afterwards; reopen
    the path with a fresh ``WriteAheadLog`` to recover."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError("frac must be in [0, 1]")
    wal.abort()
    sealed_bytes = wal.written_bytes - wal.tail_written_bytes
    tail_keep = wal.tail_synced_bytes + int(round(
        frac * (wal.tail_written_bytes - wal.tail_synced_bytes)))
    os.truncate(wal.tail_path, tail_keep)
    return sealed_bytes + tail_keep


# ---------------------------------------------------------------------------
# Differential-harness pieces (shared by tests, the example and the
# recovery benchmark)
# ---------------------------------------------------------------------------
class WorkloadLog:
    """The admitted-write history, recorded in admission (== LSN) order.

    Append each admitted chunk as the engine acknowledges it; entry i of
    the log is the write with LSN ``base + i``, so "the durable prefix
    up to LSN L" is exactly ``log[:L - base]``.  Deletes are recorded as
    ``TOMBSTONE`` values, matching the WAL's encoding."""

    def __init__(self):
        self._keys: list[np.ndarray] = []
        self._vals: list[np.ndarray] = []
        self.n = 0

    def record(self, keys, vals) -> None:
        keys = np.asarray(keys, np.uint32)
        if len(keys) == 0:
            return
        self._keys.append(keys.copy())
        self._vals.append(np.asarray(vals, np.int32).copy())
        self.n += len(keys)

    def record_deletes(self, keys) -> None:
        keys = np.asarray(keys, np.uint32)
        self.record(keys, np.full(len(keys), TOMBSTONE, np.int32))

    def prefix(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """The first ``n`` admitted (key, value) entries."""
        if not self._keys:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        ks = np.concatenate(self._keys)[:n]
        vs = np.concatenate(self._vals)[:n]
        return ks, vs


def apply_entries(store, keys, vals, chunk: int = 512,
                  pump_budget: int = 1 << 16) -> None:
    """Feed a recorded entry sequence into an uncrashed reference store
    (engine or fleet) in order, splitting each chunk into contiguous
    put/delete runs (a ``TOMBSTONE`` value is a delete) and pumping
    through admission stalls.  Order-preserving, so the reference's
    newest-wins state matches the recorded history exactly."""
    keys = np.asarray(keys, np.uint32)
    vals = np.asarray(vals, np.int32)
    pos = 0
    while pos < len(keys):
        end = min(pos + chunk, len(keys))
        ck, cv = keys[pos:end], vals[pos:end]
        tomb = cv == TOMBSTONE
        # contiguous same-kind runs keep intra-chunk write order exact
        cuts = np.flatnonzero(np.diff(tomb)) + 1
        for rk, rv, rt in zip(np.split(ck, cuts), np.split(cv, cuts),
                              np.split(tomb, cuts)):
            done = 0
            while done < len(rk):
                if rt[0]:
                    n_ok = store.delete_batch(rk[done:])
                else:
                    n_ok = store.put_batch(rk[done:], rv[done:])
                done += n_ok
                if done < len(rk):
                    store.pump(pump_budget)
        pos = end


def assert_reads_equal(got, want, key_space: int, rng=None,
                       n_windows: int = 4) -> None:
    """Bit-identical read-plane comparison between two stores (engine or
    fleet): full-universe ``get_batch``, full-range ``scan_range``, and
    a few random sub-range scans."""
    qs = np.arange(key_space, dtype=np.uint32)
    gf, gv = got.get_batch(qs)
    wf, wv = want.get_batch(qs)
    assert np.array_equal(gf, wf), "found masks diverge"
    assert np.array_equal(gv[gf], wv[wf]), "values diverge"
    gk, gvv = got.scan_range(0, key_space)
    wk, wvv = want.scan_range(0, key_space)
    assert np.array_equal(gk, wk), "scan keys diverge"
    assert np.array_equal(gvv, wvv), "scan values diverge"
    rng = rng or np.random.default_rng(0)
    for _ in range(n_windows):
        lo = int(rng.integers(0, key_space))
        hi = int(rng.integers(lo, key_space)) + 1
        gk, gvv = got.scan_range(lo, hi)
        wk, wvv = want.scan_range(lo, hi)
        assert np.array_equal(gk, wk) and np.array_equal(gvv, wvv), \
            f"window scan [{lo},{hi}) diverges"
