"""Merge schedulers (Section 4/5.1): how I/O bandwidth is allocated among
concurrently active merge operations.

A scheduler maps the set of live merge operations to bandwidth *fractions*
(summing to <= 1).  The same allocation law drives both the fluid
discrete-event simulator (``sim.py``) and the real engine's token-bucket
rate limiters (``engine.py``), so the paper's scheduling decisions are
exercised identically in simulation and on the real data plane.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from typing import Sequence

from .component import MergeOp


def apportion_largest_remainder(shares: Sequence[tuple[int, float]],
                                budget: int) -> list[int]:
    """Split an integer ``budget`` across fractional ``shares`` by
    largest-remainder apportionment: flooring each share (the seed's
    ``int(budget * frac)``) drops every sub-1 share, so small fractions
    starve and budget silently vanishes at small quanta — instead the
    floored shares are topped up, largest fractional part first (ties by
    id), until they sum to ``min(budget, round(sum(targets)))``.

    ``shares`` is a sequence of ``(id, fraction)`` pairs (fractions sum
    to <= 1); the returned quanta align with ``shares`` and always sum to
    at most ``budget``.  Shared by three budget-splitting layers — merge
    quanta within one tree (``LSMTree.pump_tree``), the pump epoch
    across a ``StorageGroup``'s trees (primary + secondary indexes,
    split by background debt), and the fleet's ``GlobalBudgetArbiter``
    (shard budgets across engines) — so the sub-1-share starvation fix
    lives in exactly one place."""
    if not shares or budget <= 0:
        return [0] * len(shares)
    targets = [budget * frac for _, frac in shares]
    quanta = [int(t) for t in targets]
    total = min(budget, int(round(sum(targets))))
    leftover = total - sum(quanta)
    order = sorted(range(len(shares)),
                   key=lambda i: (quanta[i] - targets[i], shares[i][0]))
    for i in order[:leftover]:
        quanta[i] += 1
    return quanta


class MergeScheduler(ABC):
    name: str = "abstract"

    @abstractmethod
    def allocate(self, ops: Sequence[MergeOp]) -> dict[int, float]:
        """Return {op_id: bandwidth fraction}.  Fractions sum to <= 1."""

    def reset(self) -> None:  # pragma: no cover - stateless by default
        pass


class SingleThreadedScheduler(MergeScheduler):
    """One merge at a time, in creation (FIFO) order, never preempted.

    The paper shows this is insufficient for full merges: while a level-i
    merge runs, ~T^i/L flushed components pile up (Section 5.1.3).
    """

    name = "single"

    def __init__(self) -> None:
        self._active: int | None = None

    def reset(self) -> None:
        self._active = None

    def allocate(self, ops: Sequence[MergeOp]) -> dict[int, float]:
        if not ops:
            self._active = None
            return {}
        live = {op.op_id for op in ops}
        if self._active not in live:
            self._active = min(ops, key=lambda o: o.op_id).op_id
        return {self._active: 1.0}


class FairScheduler(MergeScheduler):
    """Even split among all active merges (HBase/Cassandra/RocksDB default).

    The right scheduler for the *testing* phase: merges at every level make
    steady progress, so the measured maximum throughput is not inflated by
    starving large merges (Section 5.2.2).
    """

    name = "fair"

    def allocate(self, ops: Sequence[MergeOp]) -> dict[int, float]:
        if not ops:
            return {}
        share = 1.0 / len(ops)
        return {op.op_id: share for op in ops}


class GreedyScheduler(MergeScheduler):
    """Full bandwidth to the merge with the fewest remaining input pages
    (Figure 7).  Theorem 2: for a static set of same-arity merges this
    minimizes the number of disk components at every time instant.

    ``k`` generalizes to the smallest-k merges for budgets a single merge
    cannot saturate (Section 5.1.5).
    """

    name = "greedy"

    def __init__(self, k: int = 1):
        assert k >= 1
        self.k = k

    def allocate(self, ops: Sequence[MergeOp]) -> dict[int, float]:
        if not ops:
            return {}
        chosen = sorted(ops, key=lambda o: (o.remaining_input, o.op_id))[: self.k]
        share = 1.0 / len(chosen)
        return {op.op_id: share for op in chosen}


SCHEDULERS = {
    "single": SingleThreadedScheduler,
    "fair": FairScheduler,
    "greedy": GreedyScheduler,
}


def make_scheduler(name: str, **kw) -> MergeScheduler:
    return SCHEDULERS[name](**kw)
