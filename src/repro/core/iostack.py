"""Fault-tolerant file-I/O layer for the durability plane (the
"storage stack" under the WAL and the snapshot store).

Every file operation the ``WriteAheadLog`` and the
``EngineSnapshotStore`` perform routes through ONE ``IOStack``: a thin
guard that (a) consults the shared ``FaultInjector`` for an injected
transient fault at a named I/O point, (b) retries transient errors
(EIO) under a capped-exponential-backoff policy with a wall-clock
deadline, and (c) classifies the failures that remain into TYPED
errors the engine maps to its existing degradation paths:

* ``IOFaultError``   — a transient fault outlived the retry policy
  (retries + deadline exhausted).  Surfaced to the caller; never a
  silent wrong answer.
* ``StorageFull``    — ENOSPC.  NOT retried under backoff (waiting does
  not free space): the engine's write path catches it and converts the
  rejection into an ordinary constraint stall
  (``stats["stall_events"]`` + ``health()["enospc_stalls"]``), so
  writes stall gracefully and drain when space returns.
* ``CorruptionError``— a checksum mismatch (snapshot file, manifest
  table entry, or a live SSTable caught by the scrub pass).  Raised on
  restore; the live scrub path quarantines + repairs instead (see
  ``core/scrub.py``), escalating to ``UnrepairableCorruptionError``
  only when no durable copy of the data survives.

Slow-I/O latency spikes are injected as a per-op sleep (the injector's
``latency`` spec); the stack records the injected seconds so tests and
benchmarks can assert the spike was served, not dropped.

The stack keeps flat numeric counters (``stats``) — retries, backoff
seconds, faults injected by kind — which ``engine.health()`` rolls up
per group and the fleet sums across shards.
"""
from __future__ import annotations

import os
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional

import numpy as np


class IOFaultError(OSError):
    """A transient I/O fault outlived the retry policy (typed: callers
    see the failure, never silently-wrong data)."""

    def __init__(self, point: str, attempts: int):
        super().__init__(f"I/O fault at {point!r} persisted through "
                         f"{attempts} attempts")
        self.point = point
        self.attempts = attempts


class StorageFull(OSError):
    """ENOSPC: the write path converts this into a constraint-style
    stall (writes drain when space returns) instead of crashing."""

    def __init__(self, point: str):
        super().__init__(f"no space left on device (at {point!r})")
        self.point = point


class CorruptionError(RuntimeError):
    """A checksum mismatch on durable data (snapshot file or live
    table).  Restore raises it; the live scrub pass repairs instead."""


class UnrepairableCorruptionError(CorruptionError):
    """Corruption with no surviving durable copy to rebuild from:
    reads of the affected tree raise this rather than answer wrong."""


def data_crc32(keys: np.ndarray, vals: np.ndarray) -> int:
    """The one checksum formula for a sorted run's content: CRC32 over
    the key bytes then the value bytes (little-endian mirrors).  Shared
    by ``SSTable.seal_checksum``, the snapshot store's manifest entries
    and the scrub pass, so a live table and its snapshot file match
    checksums iff they hold identical data."""
    crc = zlib.crc32(np.ascontiguousarray(keys, np.uint32).tobytes())
    return zlib.crc32(np.ascontiguousarray(vals, np.int32).tobytes(), crc)


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with a per-operation deadline."""
    max_retries: int = 6               # attempts = 1 + max_retries
    backoff_s: float = 0.001           # first retry's sleep
    backoff_cap_s: float = 0.05        # per-sleep ceiling
    deadline_s: float = 2.0            # wall-clock budget per operation

    def sleep_for(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return min(self.backoff_s * (2 ** (attempt - 1)),
                   self.backoff_cap_s)


class IOStack:
    """Retrying guard around the durability plane's file operations.

    ``faults`` is the shared ``FaultInjector`` (or None — then every op
    runs bare).  ``sleep``/``clock`` are injectable so tests run the
    backoff schedule without real waiting (the stack still counts the
    seconds it WOULD have slept in ``stats["backoff_s"]``)."""

    def __init__(self, faults=None, policy: Optional[RetryPolicy] = None,
                 sleep: Callable[[float], None] = time.sleep,
                 clock: Callable[[], float] = time.monotonic):
        self.faults = faults
        self.policy = policy or RetryPolicy()
        self._sleep = sleep
        self._clock = clock
        self.stats = {"io_retries": 0, "io_backoff_s": 0.0,
                      "io_faults": 0, "io_enospc": 0,
                      "io_latency_injected_s": 0.0}

    # ------------------------------------------------------------ guard
    def call(self, point: str, fn: Callable, *args, **kwargs):
        """Run ``fn`` under the fault/retry guard for I/O point
        ``point`` (one of ``faults.IO_POINTS``).  Injected EIO retries
        with capped exponential backoff until the policy's retry count
        or deadline runs out (then ``IOFaultError``); injected ENOSPC
        raises ``StorageFull`` immediately (backoff cannot free space);
        an injected latency spike sleeps, records, and proceeds."""
        pol = self.policy
        t0 = self._clock()
        attempt = 0
        while True:
            attempt += 1
            spec = None
            if self.faults is not None:
                spec = self.faults.check_io(point)
            if spec is not None:
                lat = float(spec.get("latency", 0.0))
                if lat > 0.0:
                    self.stats["io_latency_injected_s"] += lat
                    self._sleep(lat)
                err = spec.get("error")
                if err == "ENOSPC":
                    self.stats["io_faults"] += 1
                    self.stats["io_enospc"] += 1
                    raise StorageFull(point)
                if err == "EIO":
                    self.stats["io_faults"] += 1
                    if attempt > pol.max_retries or \
                            self._clock() - t0 > pol.deadline_s:
                        raise IOFaultError(point, attempt)
                    delay = pol.sleep_for(attempt)
                    self.stats["io_retries"] += 1
                    self.stats["io_backoff_s"] += delay
                    self._sleep(delay)
                    continue
            try:
                return fn(*args, **kwargs)
            except OSError as e:               # real transient I/O error
                if getattr(e, "errno", None) == 28:         # ENOSPC
                    self.stats["io_faults"] += 1
                    self.stats["io_enospc"] += 1
                    raise StorageFull(point) from e
                self.stats["io_faults"] += 1
                if attempt > pol.max_retries or \
                        self._clock() - t0 > pol.deadline_s:
                    raise IOFaultError(point, attempt) from e
                delay = pol.sleep_for(attempt)
                self.stats["io_retries"] += 1
                self.stats["io_backoff_s"] += delay
                self._sleep(delay)

    # ----------------------------------------------------- file primitives
    def write(self, f, data: bytes) -> None:
        """One guarded buffered write + flush (to the OS, not disk).
        The injector fires BEFORE any byte is written, so an injected
        failure never leaves a partial frame — torn tails come from the
        crash model (``apply_torn_tail``), not from fault injection."""
        def _op():
            f.write(data)
            f.flush()
        self.call("io-write", _op)

    def fsync(self, f) -> None:
        self.call("io-fsync", lambda: os.fsync(f.fileno()))

    def read_bytes(self, path: os.PathLike) -> bytes:
        return self.call("io-read", Path(path).read_bytes)

    def read_text(self, path: os.PathLike) -> str:
        return self.call("io-read", Path(path).read_text)

    def truncate(self, path: os.PathLike, n: int) -> None:
        self.call("io-write", os.truncate, path, n)

    def replace(self, src: os.PathLike, dst: os.PathLike) -> None:
        self.call("io-replace", os.replace, src, dst)

    def unlink(self, path: os.PathLike) -> None:
        self.call("io-unlink",
                  lambda: Path(path).unlink(missing_ok=True))

    def write_atomic_text(self, path: Path, text: str) -> None:
        """The manifest-commit idiom, guarded end to end: write a
        sibling tmp file, then atomically replace the target."""
        tmp = path.with_suffix(".tmp")
        self.call("io-write", tmp.write_text, text)
        self.replace(tmp, path)

    def savez(self, path: os.PathLike, **arrays) -> None:
        self.call("io-write", np.savez, path, **arrays)

    def load_npz(self, path: os.PathLike):
        return self.call("io-read", np.load, path)
