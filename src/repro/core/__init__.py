"""Core LSM library: the paper's contribution (policies, schedulers,
constraints, the fluid simulator, the two-phase evaluation methodology and
the JAX-backed storage engine)."""
from .component import Component, FlushOp, LSMTree, MergeOp, MergeState, fresh_id
from .constraints import (ComponentConstraint, GlobalConstraint, L0Constraint,
                          LocalConstraint, NoConstraint)
from .metrics import LatencyRecorder, Trace, WriteTraceRecorder, rollup_stats
from .policies import (LevelingPolicy, MergePolicy, PartitionedLevelingPolicy,
                       POLICIES, SizeTieredPolicy, TieringPolicy)
from .scheduler import (FairScheduler, GreedyScheduler, MergeScheduler,
                        SCHEDULERS, SingleThreadedScheduler,
                        apportion_largest_remainder, make_scheduler)
from .sim import (ArrivalProcess, BurstyArrival, ClosedClient, ConstantArrival,
                  LSMSimulator, OpenClient, SimConfig)
from .blsm import BLSMSimulator
from .twophase import (EngineSystem, TwoPhaseResult, TwoPhaseSystem,
                       run_two_phase)
from .engine import BackgroundDriver, LSMEngine, merge_kway_host
from .fleet import (FleetBackgroundDriver, FleetSystem, GlobalBudgetArbiter,
                    LSMFleet)
from .memtable import MemTable
from .sstable import SSTable

__all__ = [
    "Component", "FlushOp", "LSMTree", "MergeOp", "MergeState", "fresh_id",
    "ComponentConstraint", "GlobalConstraint", "L0Constraint",
    "LocalConstraint", "NoConstraint", "LatencyRecorder", "Trace",
    "WriteTraceRecorder", "rollup_stats", "apportion_largest_remainder",
    "LevelingPolicy", "MergePolicy", "PartitionedLevelingPolicy", "POLICIES",
    "SizeTieredPolicy", "TieringPolicy",
    "FairScheduler", "GreedyScheduler", "MergeScheduler", "SCHEDULERS",
    "SingleThreadedScheduler", "make_scheduler",
    "ArrivalProcess", "BurstyArrival", "ClosedClient", "ConstantArrival",
    "LSMSimulator", "OpenClient", "SimConfig",
    "BLSMSimulator", "EngineSystem", "TwoPhaseResult", "TwoPhaseSystem",
    "run_two_phase",
    "BackgroundDriver", "LSMEngine", "MemTable", "SSTable",
    "merge_kway_host", "LSMFleet", "GlobalBudgetArbiter",
    "FleetBackgroundDriver", "FleetSystem",
]
