"""Core LSM library: the paper's contribution (policies, schedulers,
constraints, the fluid simulator, the two-phase evaluation methodology and
the JAX-backed storage engine)."""
from .component import Component, FlushOp, LSMTree, MergeOp, MergeState, fresh_id
from .constraints import (ComponentConstraint, GlobalConstraint, L0Constraint,
                          LocalConstraint, NoConstraint)
from .metrics import (LatencyRecorder, Trace, WriteTraceRecorder,
                      amplification_stats, rollup_stats)
from .policies import (LevelingPolicy, MergePolicy, PartitionedLevelingPolicy,
                       POLICIES, SizeTieredPolicy, TieringPolicy)
from .scheduler import (FairScheduler, GreedyScheduler, MergeScheduler,
                        SCHEDULERS, SingleThreadedScheduler,
                        apportion_largest_remainder, make_scheduler)
from .sim import (ArrivalProcess, BurstyArrival, ClosedClient, ConstantArrival,
                  LSMSimulator, OpenClient, SimConfig)
from .blsm import BLSMSimulator
from .twophase import (EngineSystem, TwoPhaseResult, TwoPhaseSystem,
                       run_two_phase)
from .backend import (ExecBackend, compiled_supported, load_calibration,
                      merge_kway_host, write_calibration)
from .engine import (BackgroundDriver, IndexSpec, LSMEngine, StorageGroup)
from .fleet import (FleetBackgroundDriver, FleetSystem, GlobalBudgetArbiter,
                    LSMFleet)
from .memtable import MemTable, TOMBSTONE, drop_tombstones
from .sstable import SSTable
from .wal import RecoverySession, WriteAheadLog, recover_engine
from .iostack import (CorruptionError, IOFaultError, IOStack,
                      RetryPolicy, StorageFull,
                      UnrepairableCorruptionError, data_crc32)
from .scrub import Scrubber
from .faults import (CRASH_POINTS, FaultInjector, IO_POINTS,
                     SimulatedCrash, WorkloadLog, apply_entries,
                     apply_torn_tail, assert_reads_equal, flip_bit)

__all__ = [
    "Component", "FlushOp", "LSMTree", "MergeOp", "MergeState", "fresh_id",
    "ComponentConstraint", "GlobalConstraint", "L0Constraint",
    "LocalConstraint", "NoConstraint", "LatencyRecorder", "Trace",
    "WriteTraceRecorder", "rollup_stats", "amplification_stats",
    "apportion_largest_remainder",
    "LevelingPolicy", "MergePolicy", "PartitionedLevelingPolicy", "POLICIES",
    "SizeTieredPolicy", "TieringPolicy",
    "FairScheduler", "GreedyScheduler", "MergeScheduler", "SCHEDULERS",
    "SingleThreadedScheduler", "make_scheduler",
    "ArrivalProcess", "BurstyArrival", "ClosedClient", "ConstantArrival",
    "LSMSimulator", "OpenClient", "SimConfig",
    "BLSMSimulator", "EngineSystem", "TwoPhaseResult", "TwoPhaseSystem",
    "run_two_phase",
    "BackgroundDriver", "IndexSpec", "LSMEngine", "StorageGroup",
    "MemTable", "SSTable",
    "ExecBackend", "compiled_supported", "load_calibration",
    "write_calibration",
    "merge_kway_host", "LSMFleet", "GlobalBudgetArbiter",
    "FleetBackgroundDriver", "FleetSystem",
    "TOMBSTONE", "drop_tombstones", "WriteAheadLog", "RecoverySession",
    "recover_engine", "CRASH_POINTS", "FaultInjector", "SimulatedCrash",
    "WorkloadLog", "apply_entries", "apply_torn_tail",
    "assert_reads_equal", "flip_bit",
    "CorruptionError", "IOFaultError", "IOStack", "IO_POINTS",
    "RetryPolicy", "StorageFull", "UnrepairableCorruptionError",
    "data_crc32", "Scrubber",
]
