"""Core LSM data structures shared by the simulator and the real engine.

Sizes are tracked in *entries* (the paper uses 1 KB entries, so bytes =
entries * entry_size).  Key ranges are modelled on the unit interval [0, 1)
— the real engine maps uint64 keys onto it, the simulator uses it directly
for partitioned-merge overlap computation.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Optional

_next_id = itertools.count()


def fresh_id() -> int:
    return next(_next_id)


@dataclass
class Component:
    """An immutable on-disk LSM component (or a range-partitioned file)."""

    size: float                      # entries
    level: int = 0                   # level hint (policies may ignore)
    key_lo: float = 0.0              # [key_lo, key_hi) in unit key space
    key_hi: float = 1.0
    created_at: float = 0.0          # simulation / wall time of creation
    stamp: float = 0.0               # data age (NOT creation time): the
                                     # real engine mirrors its flush/merge
                                     # data stamps here so policies can
                                     # make age-aware choices; the fluid
                                     # simulator leaves it 0
    cid: int = field(default_factory=fresh_id)
    merging: bool = False            # currently an input of an active merge

    def overlaps(self, other: "Component") -> bool:
        return self.key_lo < other.key_hi and other.key_lo < self.key_hi

    def __repr__(self) -> str:  # compact, for traces
        return (f"C{self.cid}(L{self.level},{self.size:.0f}e,"
                f"[{self.key_lo:.2f},{self.key_hi:.2f}))")


class MergeState(Enum):
    PENDING = "pending"
    RUNNING = "running"
    PAUSED = "paused"
    DONE = "done"


@dataclass
class MergeOp:
    """A merge operation created by a merge policy.

    ``output_size`` is the number of entries the merge will *write* — the
    paper throttles the SSD **write** bandwidth of flushes and merges
    (Section 3.1), so a merge's I/O demand is its output size.  The greedy
    scheduler ranks operations by *remaining input pages* (Figure 7 line
    12), which we track via ``remaining_input``.
    """

    inputs: list[Component]
    output_level: int
    output_size: float               # entries to write
    output_ranges: list[tuple[float, float]] = field(default_factory=list)
    op_id: int = field(default_factory=fresh_id)
    state: MergeState = MergeState.PENDING
    written: float = 0.0             # entries written so far
    created_at: float = 0.0

    def __post_init__(self) -> None:
        for c in self.inputs:
            c.merging = True
        if not self.output_ranges:
            lo = min(c.key_lo for c in self.inputs)
            hi = max(c.key_hi for c in self.inputs)
            self.output_ranges = [(lo, hi)]

    @property
    def total_input(self) -> float:
        return sum(c.size for c in self.inputs)

    @property
    def remaining_output(self) -> float:
        return max(0.0, self.output_size - self.written)

    @property
    def remaining_input(self) -> float:
        """Remaining input entries to consume (greedy's ranking key)."""
        if self.output_size <= 0:
            return 0.0
        frac = min(1.0, self.written / self.output_size)
        return self.total_input * (1.0 - frac)

    @property
    def done(self) -> bool:
        return self.remaining_output <= 1e-9


@dataclass
class FlushOp:
    """A flush of a sealed memory component to a new Level-0 component."""

    size: float                      # entries to write
    written: float = 0.0
    op_id: int = field(default_factory=fresh_id)

    @property
    def remaining(self) -> float:
        return max(0.0, self.size - self.written)


class LSMTree:
    """Scheduling-plane view of an LSM-tree: component metadata per level.

    ``levels[i]`` is ordered oldest → newest for unpartitioned levels and by
    key range for partitioned levels.  The same structure backs both the
    discrete-event simulator and the real engine, so policies and
    schedulers are exercised identically in both.
    """

    def __init__(self, unique_keys: float, entry_size: int = 1024):
        self.levels: dict[int, list[Component]] = {}
        self.unique_keys = float(unique_keys)
        self.entry_size = entry_size

    # -- structural helpers ------------------------------------------------
    def level(self, i: int) -> list[Component]:
        return self.levels.setdefault(i, [])

    def add(self, comp: Component) -> None:
        self.level(comp.level).append(comp)

    def remove(self, comp: Component) -> None:
        self.level(comp.level).remove(comp)

    def all_components(self) -> list[Component]:
        return [c for lvl in self.levels.values() for c in lvl]

    def num_components(self) -> int:
        return sum(len(lvl) for lvl in self.levels.values())

    def num_at(self, i: int) -> int:
        return len(self.levels.get(i, []))

    def level_size(self, i: int) -> float:
        return sum(c.size for c in self.levels.get(i, []))

    def total_size(self) -> float:
        return sum(c.size for c in self.all_components())

    def max_level(self) -> int:
        occupied = [i for i, lvl in self.levels.items() if lvl]
        return max(occupied) if occupied else 0

    # -- merge output size model -------------------------------------------
    def merged_size(self, sizes: list[float], key_fraction: float = 1.0) -> float:
        """Expected output entries when merging components with ``sizes``.

        Uniform-update model: each input holds distinct keys drawn uniformly
        from the ``key_fraction`` slice of the ``unique_keys`` key space, so
        the union follows the inclusion–exclusion expectation
        ``U * (1 - prod(1 - s_i / U))``.  This is what bounds the largest
        level at ~``unique_keys`` entries and what lets merges reclaim
        obsolete versions, exactly the dynamics the paper relies on.
        """
        u = self.unique_keys * key_fraction
        if u <= 0:
            return float(sum(sizes))
        prod = 1.0
        for s in sizes:
            prod *= max(0.0, 1.0 - min(s, u) / u)
        return u * (1.0 - prod)
