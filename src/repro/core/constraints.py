"""Component constraints (Section 5.1.1).

A constraint is the condition under which in-memory writes must be stalled
(or slowed) because too many disk components have accumulated.  The paper
argues for *global* constraints sized at ~2x the expected component count.
"""
from __future__ import annotations

from abc import ABC, abstractmethod

from .component import LSMTree


class ComponentConstraint(ABC):
    @abstractmethod
    def violated(self, tree: LSMTree) -> bool:
        ...

    def describe(self) -> str:
        return type(self).__name__


class NoConstraint(ComponentConstraint):
    def violated(self, tree: LSMTree) -> bool:
        return False


class GlobalConstraint(ComponentConstraint):
    """Stall when the total number of disk components exceeds ``max_total``."""

    def __init__(self, max_total: int):
        self.max_total = max_total

    def violated(self, tree: LSMTree) -> bool:
        return tree.num_components() > self.max_total

    def describe(self) -> str:
        return f"global(<= {self.max_total})"


class LocalConstraint(ComponentConstraint):
    """Stall when any level holds more than ``max_per_level`` components.

    bLSM-style (at most two components per level); evaluated in Figure 12.
    Partitioned levels (disjoint files) are exempt — the per-level limit is
    about *overlapping* components a query must reconcile.
    """

    def __init__(self, max_per_level: int, partitioned_levels_exempt: bool = True):
        self.max_per_level = max_per_level
        self.exempt = partitioned_levels_exempt

    def violated(self, tree: LSMTree) -> bool:
        for lvl, comps in tree.levels.items():
            if self.exempt and lvl >= 1 and _is_partitioned(comps):
                continue
            if len(comps) > self.max_per_level:
                return True
        return False

    def describe(self) -> str:
        return f"local(<= {self.max_per_level}/level)"


class L0Constraint(ComponentConstraint):
    """LevelDB-style: stop writes when Level 0 holds >= ``stop`` runs."""

    def __init__(self, stop: int = 12):
        self.stop = stop

    def violated(self, tree: LSMTree) -> bool:
        return tree.num_at(0) >= self.stop

    def describe(self) -> str:
        return f"l0(< {self.stop})"


def _is_partitioned(comps) -> bool:
    if len(comps) <= 1:
        return False
    return any(c.key_hi - c.key_lo < 1.0 for c in comps)
