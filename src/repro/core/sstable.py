"""Immutable sorted-run component for the real engine.

The data plane is JAX: Bloom filters are built/probed by the Pallas bloom
kernel pair, point lookups are vectorized sorted searches, and merges
(in engine.py) run through the Pallas merge-path kernel.  One SSTable
corresponds to one scheduling-plane ``Component`` so the paper's
policies/schedulers drive real bytes.

``interpret`` selects the Pallas execution mode for this table's probe
kernel (interpret=True for CPU tests, False for compiled TPU runs); the
engine plumbs it down from its own constructor flag.  ``keys_np``/
``vals_np`` are host-side mirrors of the run so the batched read plane
can ``np.searchsorted`` without a device sync per lookup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
from .component import Component
from .memtable import scan_window, sorted_lookup


@dataclass
class SSTable:
    keys: jnp.ndarray                  # (n,) uint32, sorted ascending, unique
    vals: jnp.ndarray                  # (n,) int32
    bloom: jnp.ndarray = None          # uint32 words
    n_bits: int = 0
    k_hashes: int = 0
    component: Optional[Component] = None
    data_stamp: int = 0                # data age: strictly increasing at
                                       # flush; max over inputs at merge
    interpret: bool = True             # Pallas mode for probe kernels
    keys_np: Optional[np.ndarray] = None   # host mirrors (lazy)
    vals_np: Optional[np.ndarray] = None
    bloom_np: Optional[np.ndarray] = None

    @classmethod
    def build(cls, keys, vals, level: int = 0, created_at: float = 0.0,
              fpr: float = 0.01, interpret: bool = True) -> "SSTable":
        keys = jnp.asarray(keys, jnp.uint32)
        vals = jnp.asarray(vals, jnp.int32)
        n = int(keys.shape[0])
        n_bits, k_hashes = filter_params(n, fpr)
        bloom = bloom_build(keys, n_bits, k_hashes)
        lo = float(keys[0]) / 2**32 if n else 0.0
        hi = (float(keys[-1]) + 1) / 2**32 if n else 1.0
        comp = Component(size=float(n), level=level, key_lo=lo, key_hi=hi,
                         created_at=created_at)
        return cls(keys=keys, vals=vals, bloom=bloom, n_bits=n_bits,
                   k_hashes=k_hashes, component=comp, interpret=interpret)

    def __len__(self) -> int:
        return int(self.keys.shape[0])

    def _host(self) -> tuple[np.ndarray, np.ndarray]:
        """Host-side (keys, vals) mirrors, materialized once."""
        if self.keys_np is None:
            self.keys_np = np.asarray(self.keys)
            self.vals_np = np.asarray(self.vals)
        return self.keys_np, self.vals_np

    def bloom_host(self) -> np.ndarray:
        """Host-side filter words, materialized once (the engine's read
        view restacks filters on every flush/merge — without this cache
        each rebuild would re-sync every table's filter from device)."""
        if self.bloom_np is None:
            self.bloom_np = np.asarray(self.bloom)
        return self.bloom_np

    # -- queries --------------------------------------------------------------
    def maybe_contains(self, keys) -> np.ndarray:
        """Bloom-filter screen (vectorized, Pallas probe kernel)."""
        keys = jnp.asarray(keys, jnp.uint32)
        return np.asarray(bloom_probe(self.bloom, keys, self.n_bits,
                                      self.k_hashes,
                                      interpret=self.interpret))

    def search(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Sorted-search lookup WITHOUT the bloom screen: (found mask,
        values).  The engine's batch plane calls this only for keys the
        fused multi-table probe said may be present."""
        keys = np.asarray(keys, np.uint32)
        n = len(self)
        if n == 0 or len(keys) == 0:
            return np.zeros(len(keys), bool), np.zeros(len(keys), np.int32)
        sk, sv = self._host()
        return sorted_lookup(sk, sv, keys)

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """(found mask, values) for a key batch; bloom screen + sorted
        search only for survivors."""
        keys = np.asarray(keys, np.uint32)
        maybe = self.maybe_contains(keys)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int32)
        if maybe.any():
            f, v = self.search(keys[maybe])
            idx = np.flatnonzero(maybe)
            found[idx] = f
            vals[idx[f]] = v[f]
        return found, vals

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """All (key, value) with lo <= key < hi — a zero-copy
        ``scan_window`` over the host mirrors; this is the per-table
        slice the engine's k-way range merge consumes (no Bloom screen:
        range scans probe the run directly)."""
        sk, sv = self._host()
        return scan_window(sk, sv, lo, hi)
