"""Immutable sorted-run component for the real engine.

The data plane is JAX: Bloom filters are built/probed by the Pallas bloom
kernel pair, point lookups are vectorized sorted searches, and merges
(in engine.py) run through the Pallas merge-path kernel.  One SSTable
corresponds to one scheduling-plane ``Component`` so the paper's
policies/schedulers drive real bytes.

``interpret`` selects the Pallas execution mode for this table's probe
kernel (interpret=True for CPU tests, False for compiled TPU runs); the
engine plumbs it down from its own constructor flag.  ``keys_np``/
``vals_np`` are host-side mirrors of the run so the batched read plane
can ``np.searchsorted`` without a device sync per lookup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
from .component import Component
from .memtable import scan_window, sorted_lookup


@dataclass
class SSTable:
    keys: jnp.ndarray                  # (n,) uint32, sorted ascending, unique
    vals: jnp.ndarray                  # (n,) int32
    bloom: jnp.ndarray = None          # uint32 words, built LAZILY on the
                                       # first probe/stack sync — never on
                                       # the background (flush/merge) path,
                                       # whose quanta must stay O(quantum)
    n_bits: int = 0
    k_hashes: int = 0
    component: Optional[Component] = None
    data_stamp: int = 0                # data age: strictly increasing at
                                       # flush; max over inputs at merge
    stack_slot: int = -1               # row in the engine's persistent
                                       # filter stack (set by its sync)
    interpret: bool = True             # Pallas mode for probe kernels
    keys_np: Optional[np.ndarray] = None   # host mirrors: seeded by
                                           # ``build``; lazy fallback for
                                           # hand-constructed tables
    vals_np: Optional[np.ndarray] = None
    bloom_np: Optional[np.ndarray] = None

    @classmethod
    def build(cls, keys, vals, level: int = 0, created_at: float = 0.0,
              fpr: float = 0.01, interpret: bool = True) -> "SSTable":
        # Host-first: the flush/merge call sites already hold numpy
        # arrays (``MemTable.seal`` output / merge-output concatenation),
        # so component bounds come from the host copy and the read
        # plane's ``keys_np``/``vals_np`` mirrors are seeded for free —
        # the seed's ``float(keys[0])``/``float(keys[-1])`` round-tripped
        # the device once per flush just to compute bounds.
        keys_np = np.asarray(keys, np.uint32)
        vals_np = np.asarray(vals, np.int32)
        keys = jnp.asarray(keys_np)
        vals = jnp.asarray(vals_np)
        n = int(keys_np.shape[0])
        n_bits, k_hashes = filter_params(n, fpr)
        # the Bloom filter itself is NOT built here: flush/merge
        # completions run under the engine lock in scheduler quanta, and
        # an O(n) filter build there is exactly the compute cliff the
        # bounded background plane forbids.  ``_ensure_bloom`` builds it
        # on the first probe (point-read paths only — scans never pay).
        lo = float(keys_np[0]) / 2**32 if n else 0.0
        hi = (float(keys_np[-1]) + 1) / 2**32 if n else 1.0
        comp = Component(size=float(n), level=level, key_lo=lo, key_hi=hi,
                         created_at=created_at)
        return cls(keys=keys, vals=vals, n_bits=n_bits,
                   k_hashes=k_hashes, component=comp, interpret=interpret,
                   keys_np=keys_np, vals_np=vals_np)

    def __len__(self) -> int:
        return int(self.keys.shape[0])

    def _host(self) -> tuple[np.ndarray, np.ndarray]:
        """Host-side (keys, vals) mirrors, materialized once."""
        if self.keys_np is None:
            self.keys_np = np.asarray(self.keys)
            self.vals_np = np.asarray(self.vals)
        return self.keys_np, self.vals_np

    def _ensure_bloom(self) -> jnp.ndarray:
        """Build the filter on first use (never on the background path)."""
        if self.bloom is None:
            self.bloom = bloom_build(jnp.asarray(self.keys, jnp.uint32),
                                     self.n_bits, self.k_hashes)
        return self.bloom

    def bloom_host(self) -> np.ndarray:
        """Host-side filter words, built/materialized once on first use
        (the engine's incremental filter stack syncs new tables' words
        from here — one O(filter) cost on the first point read after a
        flush/merge, zero on the background quanta themselves)."""
        if self.bloom_np is None:
            self.bloom_np = np.asarray(self._ensure_bloom())
        return self.bloom_np

    # -- queries --------------------------------------------------------------
    def maybe_contains(self, keys) -> np.ndarray:
        """Bloom-filter screen (vectorized, Pallas probe kernel)."""
        keys = jnp.asarray(keys, jnp.uint32)
        return np.asarray(bloom_probe(self._ensure_bloom(), keys,
                                      self.n_bits, self.k_hashes,
                                      interpret=self.interpret))

    def search(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Sorted-search lookup WITHOUT the bloom screen: (found mask,
        values).  The engine's batch plane calls this only for keys the
        fused multi-table probe said may be present."""
        keys = np.asarray(keys, np.uint32)
        n = len(self)
        if n == 0 or len(keys) == 0:
            return np.zeros(len(keys), bool), np.zeros(len(keys), np.int32)
        sk, sv = self._host()
        return sorted_lookup(sk, sv, keys)

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """(found mask, values) for a key batch; bloom screen + sorted
        search only for survivors."""
        keys = np.asarray(keys, np.uint32)
        maybe = self.maybe_contains(keys)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int32)
        if maybe.any():
            f, v = self.search(keys[maybe])
            idx = np.flatnonzero(maybe)
            found[idx] = f
            vals[idx[f]] = v[f]
        return found, vals

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """All (key, value) with lo <= key < hi — a zero-copy
        ``scan_window`` over the host mirrors; this is the per-table
        slice the engine's k-way range merge consumes (no Bloom screen:
        range scans probe the run directly)."""
        sk, sv = self._host()
        return scan_window(sk, sv, lo, hi)
