"""Immutable sorted-run component for the real engine.

The data plane is JAX: Bloom filters are built/probed by the Pallas bloom
kernel pair, point lookups are vectorized sorted searches, and merges
(in engine.py) run through the Pallas merge-path kernel.  One SSTable
corresponds to one scheduling-plane ``Component`` so the paper's
policies/schedulers drive real bytes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
from .component import Component


@dataclass
class SSTable:
    keys: jnp.ndarray                  # (n,) uint32, sorted ascending, unique
    vals: jnp.ndarray                  # (n,) int32
    bloom: jnp.ndarray = None          # uint32 words
    n_bits: int = 0
    k_hashes: int = 0
    component: Optional[Component] = None
    data_stamp: int = 0                # data age: strictly increasing at
                                       # flush; max over inputs at merge

    @classmethod
    def build(cls, keys, vals, level: int = 0, created_at: float = 0.0,
              fpr: float = 0.01) -> "SSTable":
        keys = jnp.asarray(keys, jnp.uint32)
        vals = jnp.asarray(vals, jnp.int32)
        n = int(keys.shape[0])
        n_bits, k_hashes = filter_params(n, fpr)
        bloom = bloom_build(keys, n_bits, k_hashes)
        lo = float(keys[0]) / 2**32 if n else 0.0
        hi = (float(keys[-1]) + 1) / 2**32 if n else 1.0
        comp = Component(size=float(n), level=level, key_lo=lo, key_hi=hi,
                         created_at=created_at)
        return cls(keys=keys, vals=vals, bloom=bloom, n_bits=n_bits,
                   k_hashes=k_hashes, component=comp)

    def __len__(self) -> int:
        return int(self.keys.shape[0])

    # -- queries --------------------------------------------------------------
    def maybe_contains(self, keys) -> np.ndarray:
        """Bloom-filter screen (vectorized, Pallas probe kernel)."""
        keys = jnp.asarray(keys, jnp.uint32)
        return np.asarray(bloom_probe(self.bloom, keys, self.n_bits,
                                      self.k_hashes))

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """(found mask, values) for a key batch; bloom screen + sorted
        search only for survivors."""
        keys = np.asarray(keys, np.uint32)
        maybe = self.maybe_contains(keys)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int32)
        if maybe.any():
            sub = jnp.asarray(keys[maybe])
            pos = jnp.searchsorted(self.keys, sub)
            pos = jnp.clip(pos, 0, max(len(self) - 1, 0))
            hit = np.asarray(self.keys[pos] == sub) if len(self) else \
                np.zeros(sub.shape, bool)
            v = np.asarray(self.vals[pos])
            found[maybe] = hit
            vals[np.flatnonzero(maybe)[hit]] = v[hit]
        return found, vals

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """All (key, value) with lo <= key < hi."""
        i = int(jnp.searchsorted(self.keys, jnp.uint32(lo)))
        j = int(jnp.searchsorted(self.keys, jnp.uint32(hi)))
        return np.asarray(self.keys[i:j]), np.asarray(self.vals[i:j])
