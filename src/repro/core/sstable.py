"""Immutable sorted-run component for the real engine.

The data plane is JAX: Bloom filters are built/probed by the Pallas bloom
kernel pair, point lookups are vectorized sorted searches, and merges
(in engine.py) run through the execution backend (``core/backend.py``).
One SSTable corresponds to one scheduling-plane ``Component`` so the
paper's policies/schedulers drive real bytes.

Residency contract: the HOST mirrors (``keys_np``/``vals_np``) are the
authoritative storage — the read plane ``np.searchsorted``s them without
a device sync per lookup, and ``build`` never copies them.  The DEVICE
arrays (``keys``/``vals`` properties) materialize LAZILY on first kernel
use, or are adopted directly when the caller already holds
device-resident output (the engine's streaming merge passes its
accumulated device buffer via ``dev=``), so a table built from a
device-side merge is never re-uploaded and a table only ever touched by
host-path ops never pays for a device copy at all.

``interpret`` selects the Pallas execution mode for this table's probe
kernel (interpret=True for CPU tests, False for compiled runs); the
engine plumbs it down from its backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom.ops import bloom_build, bloom_probe, filter_params
from .component import Component
from .iostack import data_crc32
from .memtable import scan_window, sorted_lookup


@dataclass
class SSTable:
    keys_np: np.ndarray                # (n,) uint32, sorted asc, unique —
    vals_np: np.ndarray                # authoritative host mirrors
    bloom: jnp.ndarray = None          # uint32 words, built LAZILY on the
                                       # first probe/stack sync — never on
                                       # the background (flush/merge) path,
                                       # whose quanta must stay O(quantum)
    n_bits: int = 0
    k_hashes: int = 0
    component: Optional[Component] = None
    data_stamp: int = 0                # data age: strictly increasing at
                                       # flush; max over inputs at merge
    stack_slot: int = -1               # row in the engine's persistent
                                       # filter stack (set by its sync)
    interpret: bool = True             # Pallas mode for probe kernels
    crc32: Optional[int] = None        # content checksum sealed at bind
                                       # (``data_crc32``); the scrub pass
                                       # re-verifies it to catch bit-rot
    bloom_np: Optional[np.ndarray] = None
    _keys_dev: Optional[jnp.ndarray] = field(default=None, repr=False)
    _vals_dev: Optional[jnp.ndarray] = field(default=None, repr=False)

    @classmethod
    def build(cls, keys, vals, level: int = 0, created_at: float = 0.0,
              fpr: float = 0.01, interpret: bool = True,
              dev: Optional[tuple] = None) -> "SSTable":
        # Host-first: the flush/merge call sites already hold numpy
        # arrays (``MemTable.seal`` output / the streaming merge's
        # preallocated output buffer), so component bounds come from the
        # host copy and the read plane's mirrors are adopted for free.
        # No device upload happens here AT ALL: device arrays either
        # arrive via ``dev`` (output already living on device — the
        # engine's device-resident merge plane) or materialize lazily on
        # the first kernel launch that needs them.
        keys_np = np.asarray(keys, np.uint32)
        vals_np = np.asarray(vals, np.int32)
        n = int(keys_np.shape[0])
        n_bits, k_hashes = filter_params(n, fpr)
        # the Bloom filter itself is NOT built here: flush/merge
        # completions run under the engine lock in scheduler quanta, and
        # an O(n) filter build there is exactly the compute cliff the
        # bounded background plane forbids.  ``_ensure_bloom`` builds it
        # on the first probe (point-read paths only — scans never pay).
        lo = float(keys_np[0]) / 2**32 if n else 0.0
        hi = (float(keys_np[-1]) + 1) / 2**32 if n else 1.0
        comp = Component(size=float(n), level=level, key_lo=lo, key_hi=hi,
                         created_at=created_at)
        dk, dv = dev if dev is not None else (None, None)
        return cls(keys_np=keys_np, vals_np=vals_np, n_bits=n_bits,
                   k_hashes=k_hashes, component=comp, interpret=interpret,
                   _keys_dev=dk, _vals_dev=dv)

    def __len__(self) -> int:
        return int(self.keys_np.shape[0])

    # -- residency ------------------------------------------------------------
    @property
    def keys(self) -> jnp.ndarray:
        """Device-resident keys, materialized lazily from the host mirror
        (or adopted from a device-side merge output at build)."""
        if self._keys_dev is None:
            self._keys_dev = jnp.asarray(self.keys_np)
        return self._keys_dev

    @property
    def vals(self) -> jnp.ndarray:
        if self._vals_dev is None:
            self._vals_dev = jnp.asarray(self.vals_np)
        return self._vals_dev

    @property
    def device_resident(self) -> bool:
        """True when the device arrays already exist (no upload pending)."""
        return self._keys_dev is not None and self._vals_dev is not None

    def _host(self) -> tuple[np.ndarray, np.ndarray]:
        """Host-side (keys, vals) mirrors — the authoritative storage."""
        return self.keys_np, self.vals_np

    # -- integrity ------------------------------------------------------------
    def seal_checksum(self) -> int:
        """Seal the content CRC (called when the table binds into a
        read view — flush, merge completion, snapshot restore).  O(n),
        but so was producing the run; the scrub pass amortizes
        RE-verification across pump quanta instead."""
        self.crc32 = int(data_crc32(self.keys_np, self.vals_np))
        return self.crc32

    def verify_checksum(self) -> bool:
        """True when the host mirrors still match the sealed CRC (an
        unsealed table vacuously passes)."""
        if self.crc32 is None:
            return True
        return int(data_crc32(self.keys_np, self.vals_np)) == self.crc32

    def _ensure_bloom(self) -> jnp.ndarray:
        """Build the filter on first use (never on the background path)."""
        if self.bloom is None:
            self.bloom = bloom_build(jnp.asarray(self.keys, jnp.uint32),
                                     self.n_bits, self.k_hashes)
        return self.bloom

    def bloom_host(self) -> np.ndarray:
        """Host-side filter words, built/materialized once on first use
        (the engine's incremental filter stack syncs new tables' words
        from here — one O(filter) cost on the first point read after a
        flush/merge, zero on the background quanta themselves)."""
        if self.bloom_np is None:
            self.bloom_np = np.asarray(self._ensure_bloom())
        return self.bloom_np

    # -- queries --------------------------------------------------------------
    def maybe_contains(self, keys) -> np.ndarray:
        """Bloom-filter screen (vectorized, Pallas probe kernel)."""
        keys = jnp.asarray(keys, jnp.uint32)
        return np.asarray(bloom_probe(self._ensure_bloom(), keys,
                                      self.n_bits, self.k_hashes,
                                      interpret=self.interpret))

    def search(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Sorted-search lookup WITHOUT the bloom screen: (found mask,
        values).  The engine's batch plane calls this only for keys the
        fused multi-table probe said may be present."""
        keys = np.asarray(keys, np.uint32)
        n = len(self)
        if n == 0 or len(keys) == 0:
            return np.zeros(len(keys), bool), np.zeros(len(keys), np.int32)
        sk, sv = self._host()
        return sorted_lookup(sk, sv, keys)

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """(found mask, values) for a key batch; bloom screen + sorted
        search only for survivors."""
        keys = np.asarray(keys, np.uint32)
        maybe = self.maybe_contains(keys)
        found = np.zeros(len(keys), bool)
        vals = np.zeros(len(keys), np.int32)
        if maybe.any():
            f, v = self.search(keys[maybe])
            idx = np.flatnonzero(maybe)
            found[idx] = f
            vals[idx[f]] = v[f]
        return found, vals

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """All (key, value) with lo <= key < hi — a zero-copy
        ``scan_window`` over the host mirrors; this is the per-table
        slice the engine's k-way range merge consumes (no Bloom screen:
        range scans probe the run directly)."""
        sk, sv = self._host()
        return scan_window(sk, sv, lo, hi)
