"""bLSM's spring-and-gear merge scheduler (Section 4.2), as a fluid model.

Structure (Figure 4): memory component C0, disk components C1 and C2, size
ratio r.  C0 is continuously rolling-merged into C1; when C1 reaches
r*|C0| it becomes C1' and is merged into C2 while a fresh C1 fills.  The
gear couples progress: in_i (formation of the new C_i) tracks out_i (merge
of C'_i into C_{i+1}); the spring smooths the induced write-rate cap.

Fluid derivation (entries/s, B = write-bandwidth budget):
  * migrating one entry from C0 into a C1 of size S1 rewrites
    (S1 + M0)/M0 entries  ->  b0 = w * (S1 + M0)/M0
  * the gear ties C1 fill rate to the C1'->C2 merge (job J entries,
    bandwidth b1):   w ~= dS1/dt = r*M0 * b1 / J
  * with b0 + b1 = B:     w(S1) = r*M0*B / (J + r*(S1 + M0))
The write-rate cap therefore peaks right after a C1 swap and decays as C1
grows — the periodic throughput peaks of Figure 6a — while bounding
per-write processing latency at 1/w (the graceful slowdown bLSM trades
queuing delay for, exposed by Figure 6c).
"""
from __future__ import annotations

from .metrics import Trace
from .sim import ClosedClient, OpenClient

EPS = 1e-9


class BLSMSimulator:
    """Fixed-structure three-component bLSM under spring-and-gear control."""

    def __init__(self,
                 bandwidth: float = 102_400.0,     # entries/s (100 MB/s @1KB)
                 memory_entries: float = 1_048_576.0,  # 1 GB memory component
                 size_ratio: int = 10,
                 unique_keys: float = 100e6,
                 step: float = 1.0):
        self.B = float(bandwidth)
        self.M0 = float(memory_entries)
        self.r = int(size_ratio)
        self.U = float(unique_keys)
        self.step = float(step)
        self.cfg = type("cfg", (), {"mem_write_rate": 250_000.0})()

    @property
    def write_capacity(self) -> float:
        """Backend-agnostic system protocol (see ``twophase.py``)."""
        return self.cfg.mem_write_rate

    def _wcap(self, s1: float, job: float) -> float:
        return self.r * self.M0 * self.B / (job + self.r * (s1 + self.M0))

    def run(self, client, duration: float) -> Trace:
        tr = Trace(duration=duration, closed_system=client.closed,
                   n_clients=getattr(client, "n_threads", 1))
        t, arrived, served, queue = 0.0, 0.0, 0.0, 0.0
        s1 = 0.0
        c1_cap = self.r * self.M0
        # C1'->C2 job: rewrite of the (nearly full) last level
        job = self.U
        tr.record_components(0.0, 3)
        while t < duration - EPS:
            dt = min(self.step, duration - t)
            wcap = self._wcap(s1, job)
            if client.closed:
                mu = service = wcap
            else:
                mu = client.arrivals.rate(t)
                service = wcap if queue > EPS else min(mu, wcap)
                queue = max(0.0, queue + (mu - service) * dt)
            arrived += mu * dt
            served += service * dt
            s1 += service * dt
            if s1 >= c1_cap:           # C1 full: swap, gear guarantees the
                s1 -= c1_cap           # C1'->C2 merge completed in lockstep
                tr.merges_completed += 1
                tr.merge_sizes.append(job)
                tr.merge_arity.append(2)
            t += dt
            tr.record_arrival(t, arrived)
            tr.record_service(t, served)
            tr.record_capacity(t, wcap)
        tr.record_components(duration, 3)
        return tr
