"""LSM merge policies (Sections 2.1, 5.3, 6.1).

A policy decides *which* components to merge; the scheduler (scheduler.py)
decides how to execute the resulting operations.  Policies operate purely
on the scheduling-plane ``LSMTree`` metadata so they can drive both the
fluid simulator and the real engine.

Implemented policies:
  * ``TieringPolicy``              — T components per level, merged together.
  * ``LevelingPolicy``             — one component per level (+ optional
                                      dynamic-level-size adjustment [31]).
  * ``SizeTieredPolicy``           — the HBase/BigTable practical variant
                                      (size ratio + min/max mergeable), with
                                      the paper's ``force_min`` fix.
  * ``PartitionedLevelingPolicy``  — the LevelDB variant (L0 runs + fixed
                                      size files, score-based selection,
                                      round-robin / choose-best), with the
                                      paper's exact-T0 testing fix.
"""
from __future__ import annotations

import math
from abc import ABC, abstractmethod
from typing import Optional

from .component import Component, LSMTree, MergeOp


class MergePolicy(ABC):
    """Base class. ``collect_merges`` is invoked by the runtime after every
    flush/merge completion and returns newly created merge operations (whose
    inputs it marks as ``merging``)."""

    def __init__(self, memtable_entries: float, unique_keys: float):
        self.memtable_entries = float(memtable_entries)
        self.unique_keys = float(unique_keys)

    # -- policy interface ---------------------------------------------------
    @abstractmethod
    def collect_merges(self, tree: LSMTree, now: float) -> list[MergeOp]:
        ...

    @abstractmethod
    def expected_components(self) -> int:
        """Expected steady-state #disk components (constraint is ~2x this)."""

    @abstractmethod
    def initial_tree(self, tree: LSMTree) -> None:
        """Populate ``tree`` as if freshly loaded with ``unique_keys``."""

    def flush_target_level(self) -> int:
        return 0

    def complete_merge(self, tree: LSMTree, op: MergeOp, now: float) -> list[Component]:
        """Default completion: replace inputs with one output component."""
        for c in op.inputs:
            tree.remove(c)
        out = Component(size=op.output_size, level=op.output_level,
                        key_lo=op.output_ranges[0][0], key_hi=op.output_ranges[0][1],
                        created_at=now)
        tree.add(out)
        return [out]

    # -- shared helpers -----------------------------------------------------
    def num_levels(self, size_ratio: float) -> int:
        return max(1, math.ceil(math.log(max(self.unique_keys / self.memtable_entries, size_ratio), size_ratio)))


# ---------------------------------------------------------------------------
class TieringPolicy(MergePolicy):
    """Tiering (Figure 2b): when a level accumulates T components they are
    merged into one component at the next level."""

    def __init__(self, size_ratio: int, memtable_entries: float, unique_keys: float):
        super().__init__(memtable_entries, unique_keys)
        self.T = int(size_ratio)

    def collect_merges(self, tree: LSMTree, now: float) -> list[MergeOp]:
        ops: list[MergeOp] = []
        for lvl in sorted(tree.levels):
            comps = tree.level(lvl)
            if any(c.merging for c in comps):
                continue  # at most one active merge per level (S 5.1.3)
            if len(comps) >= self.T:
                inputs = comps[: self.T]  # oldest T
                out_size = tree.merged_size([c.size for c in inputs])
                ops.append(MergeOp(inputs=list(inputs), output_level=lvl + 1,
                                   output_size=out_size, created_at=now))
        return ops

    def expected_components(self) -> int:
        return self.T * self.num_levels(self.T)

    def initial_tree(self, tree: LSMTree) -> None:
        # Last level holds the data; intermediate levels hold (T-1)/2
        # components on average.  The testing phase's excluded 20-minute
        # warm-up (Section 3.2) converges this to steady state.
        L = self.num_levels(self.T)
        remaining = self.unique_keys
        for lvl in range(L - 1, 0, -1):
            csize = self.memtable_entries * (self.T ** lvl)
            n = max(0, (self.T - 1) // 2)
            for _ in range(int(n)):
                if remaining <= csize:
                    break
                tree.add(Component(size=csize, level=lvl))
                remaining -= csize
        if remaining > 0:
            tree.add(Component(size=remaining, level=L))


# ---------------------------------------------------------------------------
class LevelingPolicy(MergePolicy):
    """Leveling (Figure 2a): one component per level; level i is merged with
    incoming data from level i-1 until it reaches capacity M*T^i, then it is
    merged into level i+1.

    ``dynamic_level_size`` applies the RocksDB dynamic-level-size
    optimization [31]: capacities are derived top-down from the data size so
    the largest level stays nearly full (used in the Figure 11 sweep).
    """

    def __init__(self, size_ratio: int, memtable_entries: float, unique_keys: float,
                 dynamic_level_size: bool = False):
        super().__init__(memtable_entries, unique_keys)
        self.T = int(size_ratio)
        self.dynamic = dynamic_level_size
        self.L = self.num_levels(self.T)
        self._caps = self._capacities()

    def _capacities(self) -> dict[int, float]:
        caps: dict[int, float] = {}
        if self.dynamic:
            cap = self.unique_keys
            for lvl in range(self.L, 0, -1):
                caps[lvl] = cap
                cap /= self.T
        else:
            for lvl in range(1, self.L + 1):
                caps[lvl] = self.memtable_entries * (self.T ** lvl)
        return caps

    def capacity(self, lvl: int) -> float:
        if lvl in self._caps:
            return self._caps[lvl]
        return self.memtable_entries * (self.T ** lvl)

    def collect_merges(self, tree: LSMTree, now: float) -> list[MergeOp]:
        """bLSM-style swap semantics (the concurrency model Section 5.1.3
        assumes): when a level-i component fills it freezes and drains
        into level i+1 while a FRESH level-i component keeps accepting
        merges from level i-1 — up to one merge per level runs
        concurrently instead of the whole tree serializing."""
        ops: list[MergeOp] = []
        comps = tree.all_components()

        # Age-adjacency guard.  Swap semantics can transiently leave
        # several runs on a level, and tree-list order is insertion
        # order, not data-age order — merging incoming data with an OLD
        # resident while a fresher run sits elsewhere in the tree yields
        # an output whose data stamp (max over inputs) claims recency the
        # skipped run violates, so stamp-ordered newest-wins reads in the
        # real engine return stale values.  Invariant: live runs
        # partition the flush-age axis into contiguous intervals, so an
        # incoming run may only merge with the GLOBALLY next-older live
        # run; when that run is not an eligible candidate (busy, or on a
        # different level), the incoming run is emitted solo instead —
        # always sound, since a solo run skips nothing.  The engine
        # mirrors data stamps onto components; the fluid simulator leaves
        # every stamp 0, where the rule degrades to the seed's
        # last-inserted pick (identical fluid dynamics).
        def target_for(x_stamp: float, cands: list[Component]):
            if not cands:
                return []
            if x_stamp <= 0:            # fluid sim: no data stamps
                return [cands[-1]]
            older = [c for c in comps if c.stamp < x_stamp]
            if not older:
                return []
            nxt = max(older, key=lambda c: c.stamp)
            return [nxt] if nxt in cands else []

        # L0 (flushed components) -> the growing (non-frozen) L1
        l0 = tree.level(0)
        if l0 and not any(c.merging for c in l0):
            l1_grow = [c for c in tree.level(1)
                       if not c.merging and c.size < self.capacity(1)]
            inputs = list(l0) + target_for(min(c.stamp for c in l0),
                                           l1_grow)
            out = tree.merged_size([c.size for c in inputs])
            ops.append(MergeOp(inputs=inputs, output_level=1,
                               output_size=out, created_at=now))
        # full Li -> growing Li+1 (oldest data first, so a newer frozen
        # run can never leapfrog an older sibling's drain)
        for lvl in range(1, tree.max_level() + 1):
            if lvl >= self.L:
                continue
            full = sorted((c for c in tree.level(lvl)
                           if not c.merging and
                           c.size >= self.capacity(lvl)),
                          key=lambda c: c.stamp)
            for comp in full:
                nxt_grow = [c for c in tree.level(lvl + 1)
                            if not c.merging and
                            (lvl + 1 == self.L or
                             c.size < self.capacity(lvl + 1))]
                inputs = [comp] + target_for(comp.stamp, nxt_grow)
                out = tree.merged_size([c.size for c in inputs])
                ops.append(MergeOp(inputs=inputs, output_level=lvl + 1,
                                   output_size=out, created_at=now))
        return ops

    def expected_components(self) -> int:
        return self.L

    def initial_tree(self, tree: LSMTree) -> None:
        remaining = self.unique_keys
        for lvl in range(self.L, 0, -1):
            cap = self.capacity(lvl)
            size = min(remaining, cap if lvl == self.L else cap / 2.0)
            if size <= 0:
                continue
            tree.add(Component(size=size, level=lvl))
            remaining -= size


# ---------------------------------------------------------------------------
class SizeTieredPolicy(MergePolicy):
    """The size-tiered policy used by HBase/BigTable (Section 5.3).

    Components form one age-ordered sequence (held at level 0 of the tree,
    oldest first).  A merge window [i..j] (oldest index i) is eligible when
      sizes[i] <= T * sum(sizes[i+1..j])   and   min <= j-i+1 <= max,
    matching the Figure 18 example.  Each policy execution examines the
    longest suffix of components newer than any merging component (the
    HBase prefix rule) and schedules the oldest eligible window, maximizing
    the window length (or exactly ``min`` under ``force_min`` — the paper's
    fix for measuring a *sustainable* lower-bound throughput).
    """

    def __init__(self, size_ratio: float, memtable_entries: float, unique_keys: float,
                 min_merge: int = 2, max_merge: int = 10, force_min: bool = False):
        super().__init__(memtable_entries, unique_keys)
        self.T = float(size_ratio)
        self.min_merge = int(min_merge)
        self.max_merge = int(max_merge)
        self.force_min = bool(force_min)

    def collect_merges(self, tree: LSMTree, now: float) -> list[MergeOp]:
        ops: list[MergeOp] = []
        while True:
            seq = tree.level(0)  # oldest -> newest
            start = 0
            for idx in range(len(seq) - 1, -1, -1):
                if seq[idx].merging:
                    start = idx + 1
                    break
            window = self._find_window(seq, start)
            if window is None:
                return ops
            i, j = window
            inputs = seq[i: j + 1]
            out = tree.merged_size([c.size for c in inputs])
            ops.append(MergeOp(inputs=list(inputs), output_level=0,
                               output_size=out, created_at=now))

    def _find_window(self, seq: list[Component], start: int) -> Optional[tuple[int, int]]:
        n = len(seq)
        limit = self.min_merge if self.force_min else self.max_merge
        for i in range(start, n - self.min_merge + 1):
            younger = 0.0
            for j in range(i + 1, min(n, i + limit)):
                younger += seq[j].size
                if (j - i + 1) >= self.min_merge and seq[i].size <= self.T * younger:
                    # extend j as far as the eligibility and limit allow
                    jj = j
                    while (jj + 1 < n and (jj + 1 - i + 1) <= limit):
                        jj += 1
                        younger += seq[jj].size
                    return (i, jj)
        return None

    def complete_merge(self, tree: LSMTree, op: MergeOp, now: float) -> list[Component]:
        seq = tree.level(0)
        pos = min(seq.index(c) for c in op.inputs)
        for c in op.inputs:
            seq.remove(c)
        out = Component(size=op.output_size, level=0,
                        created_at=min(c.created_at for c in op.inputs))
        seq.insert(pos, out)  # output keeps the age position of its inputs
        return [out]

    def expected_components(self) -> int:
        # ln(U/M)/ln(1+1/T)-ish; the paper simply configures 50.
        return 50

    def initial_tree(self, tree: LSMTree) -> None:
        tree.add(Component(size=self.unique_keys, level=0, created_at=-1e9))


# ---------------------------------------------------------------------------
class PartitionedLevelingPolicy(MergePolicy):
    """LevelDB-style partitioned leveling (Section 6).

    Level 0 holds whole-range flushed runs; levels >= 1 hold fixed-size
    files with disjoint key ranges.  Scores: L0 = #runs / l0_min_merge;
    level i >= 1 = level_size / capacity(i).  The highest score >= 1 is
    merged.  ``l0_merge_all`` reproduces LevelDB's merge-as-many-as-possible
    behaviour (unsustainable, Figure 21); setting it False merges exactly
    ``l0_min_merge`` runs — the paper's fix (Figure 23).
    """

    def __init__(self, size_ratio: int, memtable_entries: float, unique_keys: float,
                 file_entries: float = 65536.0,       # 64 MB / 1 KB
                 l1_capacity: float = 1310720.0,      # 1280 MB
                 l0_min_merge: int = 4,
                 selection: str = "round_robin",      # or "choose_best"
                 l0_merge_all: bool = True,
                 max_concurrent: int = 1):
        super().__init__(memtable_entries, unique_keys)
        self.T = int(size_ratio)
        self.file_entries = float(file_entries)
        self.l1_capacity = float(l1_capacity)
        self.l0_min_merge = int(l0_min_merge)
        self.selection = selection
        self.l0_merge_all = bool(l0_merge_all)
        self.max_concurrent = int(max_concurrent)
        self._cursor: dict[int, float] = {}
        nl = 1
        cap = self.l1_capacity
        while cap < self.unique_keys:
            cap *= self.T
            nl += 1
        self.num_partitioned_levels = nl

    def capacity(self, lvl: int) -> float:
        return self.l1_capacity * (self.T ** (lvl - 1))

    # -- selection ----------------------------------------------------------
    def _age_safe(self, tree: LSMTree, lvl: int, f: Component) -> bool:
        """Stamp-laundering audit (the partitioned analogue of
        ``LevelingPolicy``'s age-adjacency guard).  Merging ``f`` with its
        level-(lvl+1) overlaps produces an output stamped ``max`` over the
        inputs; any key range the output covers BEYOND ``f``'s own span
        carries data older than that stamp.  If a live component at a
        shallower level overlaps the output range with a SMALLER stamp, it
        holds newer versions (the level invariant) that the output would
        outrank under stamp-ordered newest-wins reads — so the merge must
        wait until that component has drained past.  Stamp 0 means the
        fluid simulator (no data stamps): every merge is safe, degrading
        to the seed's selection exactly."""
        inputs = [f] + [o for o in tree.level(lvl + 1) if f.overlaps(o)]
        s_star = max(c.stamp for c in inputs)
        if s_star <= 0:
            return True
        lo = min(c.key_lo for c in inputs)
        hi = max(c.key_hi for c in inputs)
        in_ids = {c.cid for c in inputs}
        for g_lvl in range(1, lvl + 1):
            for g in tree.level(g_lvl):
                if g.cid not in in_ids and g.key_lo < hi \
                        and g.key_hi > lo and g.stamp < s_star:
                    return False
        return True

    def _pick_file(self, tree: LSMTree, lvl: int) -> Optional[Component]:
        files = [c for c in tree.level(lvl) if not c.merging]
        files = [c for c in files
                 if not any(o.merging and c.overlaps(o) for o in tree.level(lvl + 1))]
        files = [c for c in files if self._age_safe(tree, lvl, c)]
        if not files:
            return None
        if self.selection == "choose_best":
            nxt = tree.level(lvl + 1)
            return min(files, key=lambda f: (sum(1 for o in nxt if f.overlaps(o)), f.key_lo))
        cur = self._cursor.get(lvl, 0.0)
        files.sort(key=lambda f: f.key_lo)
        for f in files:
            if f.key_lo >= cur:
                self._cursor[lvl] = f.key_hi
                return f
        self._cursor[lvl] = files[0].key_hi
        return files[0]

    def collect_merges(self, tree: LSMTree, now: float) -> list[MergeOp]:
        ops: list[MergeOp] = []
        active = sum(1 for c in tree.all_components() if c.merging)
        while len(ops) + (1 if active else 0) <= self.max_concurrent:
            op = self._next_merge(tree, now)
            if op is None:
                return ops
            ops.append(op)
            active = 0 if not active else active
        return ops

    def _next_merge(self, tree: LSMTree, now: float) -> Optional[MergeOp]:
        scores: list[tuple[float, int]] = []
        l0_free = [c for c in tree.level(0) if not c.merging]
        if not any(c.merging for c in tree.level(0)):
            scores.append((len(l0_free) / self.l0_min_merge, 0))
        for lvl in range(1, self.num_partitioned_levels):
            scores.append((tree.level_size(lvl) / self.capacity(lvl), lvl))
        scores.sort(reverse=True)
        for score, lvl in scores:
            if score < 1.0:
                return None
            if lvl == 0:
                if any(c.merging for c in tree.level(1)):
                    continue
                k = len(l0_free) if self.l0_merge_all else self.l0_min_merge
                # oldest-k by DATA age, not created_at: flushes completing
                # in the same pump share created_at, and merging a newer
                # run while skipping an older tied sibling launders the
                # skipped run's L1 shadow above its stamp (newest-wins
                # inversion).  Stamps are unique in the real engine; the
                # cid tiebreak keeps the fluid sim (all stamps 0) on the
                # seed's flush order.
                inputs = sorted(l0_free,
                                key=lambda c: (c.stamp, c.created_at,
                                               c.cid))[:k]
                inputs += list(tree.level(1))
                out = tree.merged_size([c.size for c in inputs])
                return MergeOp(inputs=inputs, output_level=1, output_size=out,
                               output_ranges=[(0.0, 1.0)], created_at=now)
            f = self._pick_file(tree, lvl)
            if f is None:
                continue
            overlapping = [o for o in tree.level(lvl + 1)
                           if f.overlaps(o) and not o.merging]
            inputs = [f] + overlapping
            lo = min(c.key_lo for c in inputs)
            hi = max(c.key_hi for c in inputs)
            frac = max(hi - lo, 1e-12)
            out = tree.merged_size([c.size for c in inputs], key_fraction=frac)
            return MergeOp(inputs=inputs, output_level=lvl + 1, output_size=out,
                           output_ranges=[(lo, hi)], created_at=now)
        return None

    def complete_merge(self, tree: LSMTree, op: MergeOp, now: float) -> list[Component]:
        for c in op.inputs:
            tree.remove(c)
        lo, hi = op.output_ranges[0]
        n_files = max(1, int(math.ceil(op.output_size / self.file_entries)))
        width = (hi - lo) / n_files
        outs: list[Component] = []
        per = op.output_size / n_files
        for k in range(n_files):
            outs.append(Component(size=per, level=op.output_level,
                                  key_lo=lo + k * width, key_hi=lo + (k + 1) * width,
                                  created_at=now))
        for c in outs:
            tree.add(c)
        tree.level(op.output_level).sort(key=lambda c: c.key_lo)
        return outs

    def expected_components(self) -> int:
        total_files = int(self.unique_keys / self.file_entries)
        return total_files + self.l0_min_merge

    def initial_tree(self, tree: LSMTree) -> None:
        remaining = self.unique_keys
        for lvl in range(self.num_partitioned_levels, 0, -1):
            cap = self.capacity(lvl)
            size = min(remaining, cap if lvl == self.num_partitioned_levels else cap / 2.0)
            if size <= 0:
                continue
            n_files = max(1, int(math.ceil(size / self.file_entries)))
            per, width = size / n_files, 1.0 / n_files
            for k in range(n_files):
                tree.add(Component(size=per, level=lvl, key_lo=k * width,
                                   key_hi=(k + 1) * width))
            remaining -= size


POLICIES = {
    "tiering": TieringPolicy,
    "leveling": LevelingPolicy,
    "size_tiered": SizeTieredPolicy,
    "partitioned_leveling": PartitionedLevelingPolicy,
}
