"""Unified execution-backend layer: every kernel-vs-host decision in one
place, measured instead of guessed.

The engine's data plane has three ways to run each launch:

* ``host``      — vectorized numpy (the packed-sort k-way merge, the
  bit-twiddling Bloom probe over the host filter-stack mirror).  The CPU
  fast path: no dispatch overhead, no interpreter.
* ``interpret`` — the Pallas kernels on the Pallas interpreter.  A
  correctness harness (bit-identical to compiled lowering by
  construction), never a fast path.
* ``compiled``  — the Pallas kernels compiled for the local XLA backend.
  Unavailable on CPU XLA builds that only support interpret mode;
  ``compiled_supported()`` probes once per process.

Historically the choice was a "CPU-means-host" guess spread across three
engine booleans (``use_kernels``, ``interpret``, ``scan_use_kernels``)
re-interpreted at every call site.  ``ExecBackend`` owns the decision:
it exposes the four data-plane entry points (``probe_multi``,
``merge_kway``, ``merge_kway_window``, ``scan_merge``), carries the
interpret/compiled mode, and — in ``auto`` mode — picks host vs kernel
*per op per size class* from a MEASURED crossover table: the
``benchmarks/kernels_bench.py`` sweep times every available mode at a
grid of sizes and persists the fastest per (op, size) to
``artifacts/bench/backend_calibration.json``, which engines load at
construction.  With no calibration artifact the built-in default applies
(compiled when supported, else host — the interpreter never wins a
performance decision).

The three legacy engine booleans survive as thin deprecated overrides:
``ExecBackend.from_legacy`` maps them to FORCED per-op modes that
reproduce the historical dispatch bit-for-bit, so every existing
construction site behaves unchanged.

All three modes are pinned bit-identical on merge/probe/scan results by
``tests/test_backend.py`` (compiled skipped where unsupported).
"""
from __future__ import annotations

import functools
import json
import os
from bisect import bisect_right
from pathlib import Path
from typing import Callable, Optional, Sequence

import numpy as np

try:  # jax is present everywhere the engine runs; guard for doc tooling
    import jax.numpy as jnp
    from repro.kernels.bloom.ops import (bloom_probe_multi,
                                         bloom_probe_multi_host)
    from repro.kernels.merge.ops import (merge_dedup_kway,
                                         merge_dedup_kway_window)
    _KERNELS = True
except Exception:  # pragma: no cover - kernels unavailable
    jnp = None
    bloom_probe_multi = bloom_probe_multi_host = None
    merge_dedup_kway = merge_dedup_kway_window = None
    _KERNELS = False

from .memtable import drop_tombstones

HOST, INTERPRET, COMPILED = "host", "interpret", "compiled"
MODES = (HOST, INTERPRET, COMPILED)
#: ops the backend dispatches; ``merge_kway_window`` shares
#: ``merge_kway``'s calibration entry when it has none of its own.
OPS = ("probe_multi", "merge_kway", "merge_kway_window", "scan_merge")
_OP_ALIAS = {"merge_kway_window": "merge_kway"}

#: default calibration artifact (written by ``benchmarks/kernels_bench``)
CALIBRATION_PATH = Path(__file__).resolve().parents[3] / "artifacts" / \
    "bench" / "backend_calibration.json"


@functools.lru_cache(maxsize=1)
def compiled_supported() -> bool:
    """Can this process lower a Pallas kernel for real (interpret=False)?

    Probed ONCE with a trivial kernel: CPU XLA builds of jax that only
    support the interpreter raise, TPU/GPU (and future CPU lowering)
    succeed.  ``REPRO_FORCE_COMPILED=0`` force-disables (CI determinism);
    there is deliberately no force-ENABLE — claiming compiled support the
    backend cannot deliver would turn every kernel launch into an error.
    """
    if os.environ.get("REPRO_FORCE_COMPILED") == "0":
        return False
    if not _KERNELS:
        return False
    try:
        import jax
        from jax.experimental import pallas as pl

        def _copy(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        x = jnp.zeros((8,), jnp.uint32)
        out = pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct((8,), jnp.uint32),
            interpret=False)(x)
        return bool(np.asarray(out).shape == (8,))
    except Exception:
        return False


def merge_kway_host(runs) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized host k-way newest-wins merge: pack each entry as
    ``key << 32 | global_index`` (runs concatenated newest-first, so a
    lower index means a newer version), one uint64 sort, then keep the
    first entry of each equal-key group and gather only the surviving
    values.  No per-entry Python — this is the CPU fast path the
    interpret-mode Pallas tournament cannot be."""
    ks = np.concatenate([np.asarray(r[0]) for r in runs])
    n = len(ks)
    comp = (ks.astype(np.uint64) << np.uint64(32)) \
        | np.arange(n, dtype=np.uint64)
    comp.sort()
    sk = (comp >> np.uint64(32)).astype(np.uint32)
    first = np.ones(n, bool)
    first[1:] = sk[1:] != sk[:-1]
    idx = (comp[first] & np.uint64(0xFFFFFFFF)).astype(np.int64)
    vs = np.concatenate([np.asarray(r[1]) for r in runs])
    return sk[first], vs[idx]


# ----------------------------------------------------------- calibration
def write_calibration(table: dict, path: Path | str | None = None) -> Path:
    """Persist a crossover table (the ``kernels_bench`` sweep's output).

    ``table`` must carry ``{"ops": {op: {"sizes": [...], "best": [...],
    "ms": {mode: [...]}}}}``; metadata keys ride along verbatim."""
    path = Path(path) if path is not None else CALIBRATION_PATH
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = dict(table)
    payload.setdefault("version", 1)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True))
    return path


def load_calibration(path: Path | str | None = None) -> Optional[dict]:
    """Load the crossover table; None when absent or unreadable (the
    backend then falls back to its built-in default — a missing artifact
    must never fail engine construction)."""
    path = Path(path) if path is not None else CALIBRATION_PATH
    try:
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if not isinstance(data, dict) or "ops" not in data:
        return None
    return data


class ExecBackend:
    """One object owning every kernel-vs-host decision the engine makes.

    ``mode`` selects the dispatch discipline:

    * ``"auto"``      — per op per size class from the measured crossover
      table (``calibration``; loaded from the committed artifact when not
      given), with a sane built-in default when no table exists.
    * ``"host"`` / ``"interpret"`` / ``"compiled"`` — force every op to
      one mode (differential tests and the calibration sweep use this).

    ``from_legacy`` maps the engine's three historical booleans
    (``use_kernels``, ``interpret``, ``scan_use_kernels``) to forced
    per-op modes reproducing the old dispatch exactly — the deprecated
    compatibility surface.

    Entry points return host numpy arrays plus, for kernel modes, the
    device-resident result pair — the engine's streaming merge
    accumulates those into its preallocated device output buffer so the
    finished table needs no re-upload.
    """

    def __init__(self, mode: str = "auto",
                 calibration: dict | Path | str | None = None,
                 merge_block: int = 256, interpret: bool = True,
                 forced: Optional[dict] = None):
        if mode not in ("auto",) + MODES:
            raise ValueError(f"unknown backend mode {mode!r}")
        if mode == COMPILED and not compiled_supported():
            raise ValueError("compiled Pallas is not supported by this "
                             "XLA backend (compiled_supported() is False)")
        self.mode = mode
        self.merge_block = int(merge_block)
        #: Pallas execution mode hint for per-table probes
        #: (``SSTable.interpret``): kernels interpret unless compiled.
        self.interpret = bool(interpret) and mode != COMPILED
        self._forced: dict[str, str] = dict(forced or {})
        if mode in MODES:
            for op in OPS:
                self._forced.setdefault(op, mode)
        if isinstance(calibration, (str, Path)):
            calibration = load_calibration(calibration)
        elif calibration is None and mode == "auto" and not self._forced:
            calibration = load_calibration()
        self.calibration = calibration
        # legacy-compat reporting flags (engine properties read these)
        self.legacy_use_kernels: Optional[bool] = None
        self.legacy_scan_use_kernels: Optional[bool] = None

    # ------------------------------------------------------------- legacy
    @classmethod
    def from_legacy(cls, use_kernels: bool = True, interpret: bool = True,
                    scan_use_kernels: Optional[bool] = None,
                    merge_block: int = 256) -> "ExecBackend":
        """DEPRECATED mapping of the three historical engine booleans to
        forced per-op modes, bit-for-bit equal to the old dispatch:

        * merges: kernel iff ``use_kernels`` (interpret per flag);
        * probe: always the fused kernel, interpret per flag;
        * scans: ``scan_use_kernels`` — None (auto) means kernel only
          when compiled (``use_kernels and not interpret``), True/False
          force a side.
        """
        use_kernels = bool(use_kernels) and merge_dedup_kway is not None
        kmode = INTERPRET if interpret else COMPILED
        if scan_use_kernels is None:
            scan_kernel = use_kernels and not interpret
        else:
            scan_kernel = bool(scan_use_kernels) and \
                merge_dedup_kway is not None
        forced = {
            "probe_multi": kmode,
            "merge_kway": kmode if use_kernels else HOST,
            "merge_kway_window": kmode if use_kernels else HOST,
            "scan_merge": kmode if scan_kernel else HOST,
        }
        be = cls(mode="auto", merge_block=merge_block, interpret=interpret,
                 forced=forced)
        be.legacy_use_kernels = use_kernels
        be.legacy_scan_use_kernels = scan_kernel
        return be

    # ------------------------------------------------------------ decision
    def _default_mode(self) -> str:
        return COMPILED if compiled_supported() else HOST

    def decide(self, op: str, size: int) -> str:
        """The dispatch decision for one launch: which mode runs ``op``
        over ``size`` elements.  Forced modes (legacy booleans, forced
        backend) win; otherwise the measured crossover table's best mode
        for the nearest size class at or below ``size``; otherwise the
        built-in default.  A ``compiled`` verdict degrades to the next
        measured-best (or the default) when this process cannot lower
        compiled Pallas."""
        mode = self._forced.get(op)
        if mode is None:
            mode = self._lookup(op, size)
        if mode == COMPILED and not compiled_supported():
            mode = self._lookup(op, size, exclude=COMPILED) \
                if self._forced.get(op) is None else INTERPRET
        return mode

    def _lookup(self, op: str, size: int,
                exclude: Optional[str] = None) -> str:
        cal = self.calibration
        tab = None
        if cal is not None:
            ops = cal.get("ops", {})
            tab = ops.get(op) or ops.get(_OP_ALIAS.get(op, op))
        if not tab:
            return HOST if exclude == COMPILED else self._default_mode()
        sizes = tab.get("sizes") or []
        best = tab.get("best") or []
        if not sizes or len(best) != len(sizes):
            return HOST if exclude == COMPILED else self._default_mode()
        i = max(0, min(bisect_right(sizes, int(size)) - 1, len(sizes) - 1))
        mode = best[i]
        if mode == exclude or (mode == COMPILED
                               and not compiled_supported()):
            ms = tab.get("ms", {})
            live = [(ms[m][i], m) for m in (HOST, INTERPRET)
                    if m in ms and ms[m] is not None
                    and ms[m][i] is not None]
            mode = min(live)[1] if live else HOST
        return mode if mode in MODES else HOST

    def _interp(self, mode: str) -> bool:
        return mode != COMPILED

    # -------------------------------------------------------- entry points
    def probe_multi(self, filts, meta, keys,
                    filts_host: Optional[np.ndarray] = None) -> np.ndarray:
        """Fused multi-table Bloom probe: (tables, keys) maybe-present
        matrix.  Host mode runs the vectorized numpy probe over
        ``filts_host`` (the filter stack's host mirror); kernel modes
        launch the Pallas probe over the device stack."""
        n_rows = int(filts.shape[0]) if filts is not None \
            else int(filts_host.shape[0])
        mode = self.decide("probe_multi", n_rows * len(keys))
        if mode == HOST and filts_host is not None:
            return bloom_probe_multi_host(filts_host, np.asarray(meta),
                                          np.asarray(keys, np.uint32))
        return np.asarray(bloom_probe_multi(
            filts, meta, keys, interpret=self._interp(mode)))

    def merge_kway(self, runs, drop_value: Optional[int] = None,
                   runs_dev=None):
        """One-shot k-way newest-wins merge (newest run first).  Returns
        ``(keys_np, vals_np, dev)`` — ``dev`` is the device-resident
        ``(keys, vals)`` pair when a kernel produced it, else None."""
        size = sum(len(k) for k, _ in runs)
        mode = self.decide("merge_kway", size)
        if mode == HOST:
            mk, mv = merge_kway_host(runs)
            if drop_value is not None:
                mk, mv = drop_tombstones(mk, mv)
            return mk, mv, None
        dev_runs = runs_dev() if callable(runs_dev) else (runs_dev or runs)
        dk, dv = merge_dedup_kway(dev_runs, block=self.merge_block,
                                  interpret=self._interp(mode),
                                  drop_value=drop_value)
        return np.asarray(dk), np.asarray(dv), (dk, dv)

    def merge_kway_window(self, runs, starts, stops,
                          drop_value: Optional[int] = None, runs_dev=None):
        """Streaming-quantum window merge: merge only the
        ``[starts[i], stops[i])`` slice of each run (the engine cuts at a
        global key boundary, so windows compose bit-identically).
        ``runs`` are host mirrors; ``runs_dev`` (list or thunk) supplies
        the device-resident arrays for kernel modes.  Returns
        ``(keys_np, vals_np, dev)`` like ``merge_kway``."""
        size = int(sum(e - s for s, e in zip(starts, stops)))
        mode = self.decide("merge_kway_window", size)
        if mode == HOST:
            windows = [(k[s:e], v[s:e])
                       for (k, v), s, e in zip(runs, starts, stops)
                       if e > s]
            if not windows:
                return (np.empty(0, np.uint32), np.empty(0, np.int32),
                        None)
            if len(windows) == 1:
                wk, wv = windows[0]
            else:
                wk, wv = merge_kway_host(windows)
            if drop_value is not None:
                wk, wv = drop_tombstones(wk, wv)
            return np.ascontiguousarray(wk), np.ascontiguousarray(wv), None
        dev_runs = runs_dev() if callable(runs_dev) else (runs_dev or runs)
        dk, dv = merge_dedup_kway_window(
            dev_runs, list(starts), list(stops), block=self.merge_block,
            interpret=self._interp(mode), drop_value=drop_value)
        return np.asarray(dk), np.asarray(dv), (dk, dv)

    def scan_merge(self, runs,
                   drop_value: Optional[int] = None) -> tuple[np.ndarray,
                                                              np.ndarray]:
        """The read plane's k-way merge (range scans / fleet gathers):
        newest-wins merge with tombstone filtering fused, host results."""
        size = sum(len(k) for k, _ in runs)
        mode = self.decide("scan_merge", size)
        if mode == HOST:
            mk, mv = merge_kway_host(runs)
            if drop_value is not None:
                mk, mv = drop_tombstones(mk, mv)
            return mk, mv
        dk, dv = merge_dedup_kway(runs, block=self.merge_block,
                                  interpret=self._interp(mode),
                                  drop_value=drop_value)
        return np.asarray(dk), np.asarray(dv)

    # ------------------------------------------------------------- info
    def describe(self) -> dict:
        """Introspection for tests/benchmarks: forced modes, calibration
        presence, compiled availability."""
        return {
            "mode": self.mode,
            "forced": dict(self._forced),
            "calibrated": self.calibration is not None,
            "compiled_supported": compiled_supported(),
            "merge_block": self.merge_block,
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ExecBackend({self.describe()!r})"
