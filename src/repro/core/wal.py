"""Write-ahead log + crash recovery for the real engine (the durability
plane).

The WAL is a sequence of fixed-size SEGMENT files of CRC-framed record
batches, shared by every tree of a ``StorageGroup`` (the single-tree
``LSMEngine`` is the 1-tree case).  One ``append`` call writes one frame
— the group-commit unit: the group appends each admitted chunk (primary
write, or the index-maintenance entries it induces) as one frame BEFORE
the memtable admits it, so every acknowledged write is in the OS file
buffer, and is durable once ``sync`` (fsync) runs.  Group commit is the
group's knob (``group_commit_entries``): syncs happen when enough
entries accumulate, and unconditionally at every ``pump`` epoch — the
fsync-epoch boundary — with the synced bytes charged against the
scheduler's I/O budget, so WAL traffic competes with flushes and merges
for the same bandwidth (the paper's single-SSD write-budget model).

Frame layout (little-endian)::

    u32 magic | u32 n_entries | u32 tree | u64 base_lsn | u32 crc32(payload)
    payload: n_entries * (u32 key, i32 val)

``tree`` is the owning tree's id within the group (0 = the primary;
secondary-index trees get 1..N).  LSNs are GLOBAL across trees: they
number logical entries in group admission order, monotonically, across
truncations — so one log totally orders the interleaved multi-tree
history, which is what makes multi-tree recovery a PREFIX property.
Tombstones need no flag: a record whose value is the reserved
``TOMBSTONE`` sentinel IS the delete (the same encoding the
memtable/SSTable/merge planes carry).

Segmentation: frames append to the TAIL segment; once a segment holds
``segment_entries`` logical entries it is fsynced and sealed, and a new
tail opens (``<path>`` is segment 0, rotated segments are
``<path>.NNNNNN``).  Because rotation fsyncs, unsynced bytes only ever
live in the tail — so a torn tail (crash mid-write) can only damage the
LAST segment, and the scan-on-open truncation never touches sealed
segments.  ``truncate_upto`` drops whole sealed segment files whose
entries all precede the cutoff — an O(1) unlink per segment, never a
rewrite of the log — and keeps a straddling segment whole (replay skips
its already-flushed prefix), so ``start_lsn <= flushed_lsn`` after a
snapshot rather than exact equality.

Crash semantics: on open, segments are scanned in order frame-by-frame;
the first frame with a bad magic, an impossible length, a CRC mismatch,
or a non-contiguous ``base_lsn`` ends the valid prefix — that file is
truncated there and every later segment file is deleted.  Everything
fsynced before the crash is always inside the valid prefix;
unsynced-but-buffered frames may or may not survive (page-cache
reality, modeled by ``faults.apply_torn_tail``, which only ever cuts
the tail segment).

Archival: with ``archive_dir`` set, ``truncate_upto`` MOVES sealed
segments into the archive directory (an atomic rename under the I/O
stack) instead of unlinking them, under the canonical rotated name
``<name>.NNNNNN`` (segment 0 included).  Archived segments stay
replayable: ``frames_since`` transparently prepends the contiguous
archived suffix when asked for LSNs older than the live log's
``start_lsn``, and recovery clamps its origin to ``oldest_lsn`` (the
archive's first LSN) rather than the live log's.  Archival is I/O like
any other: ``truncate_upto`` returns the entries moved so the caller
(``StorageGroup.snapshot``) can charge them to the shared budget.

Every file operation — append writes, fsyncs, open-scan reads,
truncation, unlink, archival rename — routes through one ``IOStack``
(``core/iostack.py``): injected transient EIO retries under capped
exponential backoff with a deadline (then surfaces as a typed
``IOFaultError``), injected ENOSPC raises ``StorageFull`` for the
engine's stall path, and injected latency spikes sleep and are
counted.  A failed append mutates NO log state (the frame write is a
single guarded call that fires before any byte lands), so a stalled
write can simply be retried once space returns.

Recovery (``RecoverySession``) restores the snapshot's per-tree
SSTables (see ``checkpoint.store.EngineSnapshotStore``), then replays
the WAL suffix from the minimum per-tree ``flushed_lsn`` in GLOBAL LSN
order, routing each frame to its tree id and skipping, inside a frame,
the prefix already captured by that tree's snapshot.  Replay is
BUDGETED: each replayed entry charges one entry of read I/O and
replay-induced flushes/merges run through ``group.pump`` on the same
budget (apportioned across trees by background debt), so a starved
bandwidth budget slows recovery measurably (``benchmarks/recovery.py``
pins this).  The recovered group's read view is bit-identical to the
pre-crash durable state, tree by tree.

ONLINE recovery (``RecoverySession(..., online=True)``) opens the
group for traffic immediately instead of replaying first:

* The session becomes the group's replay stream (``group._recovery``)
  and the group clock jumps to the LIVE frontier (``wal.end_lsn``), so
  new writes are numbered after the entire crashed history.
* The WAL tail is rotated before the first live write: replayed and
  live frames never share a segment (the fresh-segment rule), so a
  second crash mid-recovery still tears only live bytes.
* The REPLAY WATERMARK (``session.watermark``) is the durable-prefix
  frontier: every LSN below it has been re-admitted.  Reads observe
  ``log.prefix(watermark) + live writes`` — a consistent prefix plus
  everything acknowledged since reopen.  Live writes win over
  unreplayed history: each tree tracks the keys written since reopen
  and the replay step drops staged entries for those keys (the
  memtable is newest-wins by insertion order, so un-dropped old
  entries would clobber newer live ones).
* Replay is driven from ``pump``: the session's remaining entries are
  one more background-debt stream, apportioned against flush and
  merge debt by the same largest-remainder split — so a starved
  budget slows full recovery but never time-to-first-read, and the
  fleet arbiter (``fleet.recover(serve_during_recovery=True)``)
  trades recovery speed against serving tails with no new mechanism.
* While recovering, the group's ``flushed_lsn`` is capped by the
  watermark: snapshot truncation can never drop un-replayed WAL.
"""
from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import numpy as np

from .iostack import IOStack
from .memtable import TOMBSTONE  # noqa: F401  (re-export: the WAL's delete encoding)

WAL_MAGIC = 0x57414C32            # "WAL2" (v1 had no tree id)
_HEADER = struct.Struct("<IIIQI")  # magic, n_entries, tree, base_lsn, crc32
REC_DTYPE = np.dtype([("key", "<u4"), ("val", "<i4")])


@dataclass
class _Segment:
    """One on-disk log file: a contiguous LSN range of whole frames."""
    path: Path
    seq: int
    entries: int = 0              # logical entries across its frames
    nbytes: int = 0               # valid bytes on disk
    end_lsn: int = 0              # first LSN after this segment


class WriteAheadLog:
    """Append-only CRC-framed record log, split into rotation segments,
    with an explicit durability boundary.

    ``append`` writes one frame into the OS file (flushed, not fsynced);
    ``sync`` fsyncs the tail and advances the durable boundary
    (``synced_bytes``/``synced_lsn``) — sealed segments are fsynced at
    rotation, so they are always durable.  Opening an existing path
    scans and validates the segment chain, truncates any torn tail
    (deleting segments past a corrupt one), and positions appends after
    the last valid frame; everything on disk at open is treated as
    durable (it survived the crash by definition)."""

    def __init__(self, path: str | os.PathLike,
                 segment_entries: int = 1 << 14,
                 io: Optional[IOStack] = None,
                 archive_dir: str | os.PathLike | None = None):
        self.path = Path(path)
        self.segment_entries = max(1, int(segment_entries))
        self.io = io if io is not None else IOStack()
        self.archive_dir = Path(archive_dir) if archive_dir else None
        self._frames: list[tuple[int, int, np.ndarray]] = []
        #            (base_lsn, tree, recs) — global LSN order
        self._segs: list[_Segment] = []
        self._archived: list[tuple[int, int, np.ndarray]] = []
        #            archived frames, same shape, all LSNs < start_lsn
        self.archived_segments = 0
        self.archived_entries = 0
        self.archived_bytes = 0
        self.start_lsn = 0            # first LSN still present in the log
        self.end_lsn = 0              # next LSN to be appended
        self._next_seq = 0
        self._scan_all()
        if not self._segs:            # fresh log: segment 0 is ``path``
            self._segs = [_Segment(self.path, 0, end_lsn=self.end_lsn)]
            self._next_seq = 1
        self._scan_archive()
        self._f = open(self._segs[-1].path, "ab")
        self.written_bytes = sum(s.nbytes for s in self._segs)
        self.synced_bytes = self.written_bytes  # on disk at open == durable
        self.synced_lsn = self.end_lsn
        self.syncs = 0

    # ------------------------------------------------------------- layout
    def _seg_path(self, seq: int) -> Path:
        return self.path if seq == 0 else \
            self.path.with_name(f"{self.path.name}.{seq:06d}")

    def _discover(self) -> list[tuple[int, Path]]:
        """Existing segment files, ordered by rotation sequence."""
        found: list[tuple[int, Path]] = []
        if self.path.exists():
            found.append((0, self.path))
        if self.path.parent.exists():
            for p in self.path.parent.glob(self.path.name + ".*"):
                suffix = p.name[len(self.path.name) + 1:]
                if suffix.isdigit():
                    found.append((int(suffix), p))
        return sorted(found)

    # ------------------------------------------------------------- scanning
    def _scan_all(self) -> None:
        """Validate the segment chain from the start; populate
        ``_frames``/``_segs`` and the LSN bounds.  The first invalid
        frame ends the valid prefix: its file is truncated there and
        every later segment file is deleted (unsynced bytes only ever
        live in the tail, so sealed segments can only be cut by real
        corruption — which still ends the prefix, never correctness)."""
        found = self._discover()
        if found:
            self._next_seq = found[-1][0] + 1
        lsn: Optional[int] = None
        cut_at: Optional[int] = None
        for i, (seq, p) in enumerate(found):
            data = self.io.read_bytes(p)
            off = 0
            n_in_seg = 0
            seg_frames: list[tuple[int, int, np.ndarray]] = []
            while off + _HEADER.size <= len(data):
                magic, n, tree, base, crc = _HEADER.unpack_from(data, off)
                end = off + _HEADER.size + n * REC_DTYPE.itemsize
                if magic != WAL_MAGIC or n == 0 or end > len(data):
                    break
                payload = data[off + _HEADER.size:end]
                if zlib.crc32(payload) != crc:
                    break
                if lsn is None:
                    self.start_lsn = base
                elif base != lsn:                      # non-contiguous
                    break
                lsn = base + n
                seg_frames.append((base, tree,
                                   np.frombuffer(payload, REC_DTYPE)))
                n_in_seg += n
                off = end
            if off > 0:
                self._frames.extend(seg_frames)
                self._segs.append(_Segment(p, seq, n_in_seg, off, lsn or 0))
            if off < len(data) or len(data) == 0:
                if off < len(data):
                    self.io.truncate(p, off)           # drop the torn tail
                elif off == 0:
                    self.io.unlink(p)                  # crashed-rotation husk
                cut_at = i
                break
        if cut_at is not None:
            for seq, p in found[cut_at + 1:]:
                self.io.unlink(p)
        self.end_lsn = lsn if lsn is not None else 0
        if lsn is None:
            self.start_lsn = 0

    def _scan_archive(self) -> None:
        """Load replayable frames from the archive directory: archived
        segments are sealed (whole, CRC-valid, fully durable), so the
        scan only validates and never repairs.  Only the CONTIGUOUS run
        ending exactly at the live log's ``start_lsn`` is kept — a gap
        would make replay skip history, so a mismatched archive is
        ignored rather than trusted.  A fresh live log (nothing on
        disk) positions itself at the archive's end so appended LSNs
        continue the archived history."""
        if self.archive_dir is None or not self.archive_dir.exists():
            return
        found: list[tuple[int, Path]] = []
        for p in self.archive_dir.glob(self.path.name + ".*"):
            suffix = p.name[len(self.path.name) + 1:]
            if suffix.isdigit():
                found.append((int(suffix), p))
        frames: list[tuple[int, int, np.ndarray]] = []
        nbytes = 0
        lsn: Optional[int] = None
        for seq, p in sorted(found):
            data = self.io.read_bytes(p)
            off = 0
            while off + _HEADER.size <= len(data):
                magic, n, tree, base, crc = _HEADER.unpack_from(data, off)
                end = off + _HEADER.size + n * REC_DTYPE.itemsize
                if magic != WAL_MAGIC or n == 0 or end > len(data):
                    break
                payload = data[off + _HEADER.size:end]
                if zlib.crc32(payload) != crc:
                    break
                if lsn is not None and base != lsn:
                    break                              # non-contiguous
                lsn = base + n
                frames.append((base, tree,
                               np.frombuffer(payload, REC_DTYPE)))
                nbytes += end - off
                off = end
        if not frames:
            return
        live_empty = self.end_lsn == 0 and len(self._segs) == 1 \
            and self._segs[0].nbytes == 0
        if live_empty:
            # continue the archived history from a clean slate
            self.start_lsn = self.end_lsn = lsn
            self._segs[0].end_lsn = lsn
        elif lsn != self.start_lsn:
            return                                     # gap: unusable
        self._archived = frames
        self.archived_segments = len(found)
        self.archived_entries = sum(len(r) for _, _, r in frames)
        self.archived_bytes = nbytes

    @property
    def oldest_lsn(self) -> int:
        """First LSN still replayable — through the archive when one is
        attached and contiguous, else the live log's ``start_lsn``."""
        return self._archived[0][0] if self._archived else self.start_lsn

    # ------------------------------------------------------------- writing
    def append(self, keys, vals, tree: int = 0) -> int:
        """Write one frame (the group-commit unit) for ``tree`` into the
        OS file buffer; returns the frame's base LSN.  NOT yet durable —
        durable after the next ``sync``.  Rotates the tail segment once
        it holds ``segment_entries`` logical entries."""
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        n = len(keys)
        if n == 0:
            return self.end_lsn
        recs = np.empty(n, REC_DTYPE)
        recs["key"] = keys
        recs["val"] = vals
        payload = recs.tobytes()
        base = self.end_lsn
        hdr = _HEADER.pack(WAL_MAGIC, n, int(tree), base,
                           zlib.crc32(payload))
        # ONE guarded call; an injected fault fires before any byte
        # lands, so a failed append leaves the log state untouched and
        # the caller can stall + retry (ENOSPC) or surface the error.
        self.io.write(self._f, hdr + payload)  # flushed to the OS, not disk
        self._frames.append((base, int(tree), recs))
        self.end_lsn = base + n
        tail = self._segs[-1]
        tail.entries += n
        tail.nbytes += len(hdr) + len(payload)
        tail.end_lsn = self.end_lsn
        self.written_bytes += len(hdr) + len(payload)
        if tail.entries >= self.segment_entries:
            self._rotate()
        return base

    def _rotate(self) -> None:
        """Seal the tail segment (fsync — after this, unsynced bytes can
        only live in the NEW tail) and open the next one."""
        self.sync()
        self._f.close()
        seq = self._next_seq
        self._next_seq += 1
        seg = _Segment(self._seg_path(seq), seq, end_lsn=self.end_lsn)
        self.io.unlink(seg.path)               # stale crashed-rotation file
        self._segs.append(seg)
        self._f = open(seg.path, "ab")

    def rotate(self) -> None:
        """Seal the tail NOW regardless of fill (online recovery's
        fresh-segment rule: live frames open a new segment so they
        never share a file with the replayed history).  No-op on an
        empty tail."""
        if self._segs[-1].nbytes > 0:
            self._rotate()

    def sync(self) -> int:
        """fsync the tail: advance the durability boundary over
        everything appended so far (sealed segments were fsynced at
        rotation).  Returns the bytes made durable by this call (0 when
        already clean)."""
        delta = self.written_bytes - self.synced_bytes
        if delta > 0:
            self._f.flush()
            self.io.fsync(self._f)
            self.synced_bytes = self.written_bytes
            self.synced_lsn = self.end_lsn
            self.syncs += 1
        return delta

    @property
    def unsynced_entries(self) -> int:
        return self.end_lsn - self.synced_lsn

    @property
    def entries(self) -> int:
        """Logical entries currently in the log (post-truncation)."""
        return self.end_lsn - self.start_lsn

    @property
    def segments(self) -> int:
        """Live segment files (the tail included)."""
        return len(self._segs)

    # -- tail introspection (the fault harness's torn-tail model only
    # ever cuts the tail segment: rotation fsyncs, so nothing unsynced
    # exists anywhere else) ---------------------------------------------
    @property
    def tail_path(self) -> Path:
        return self._segs[-1].path

    @property
    def tail_written_bytes(self) -> int:
        return self._segs[-1].nbytes

    @property
    def tail_synced_bytes(self) -> int:
        return self._segs[-1].nbytes - (self.written_bytes
                                        - self.synced_bytes)

    # ------------------------------------------------------------- reading
    def entries_since(self, lsn: int) -> tuple[np.ndarray, np.ndarray]:
        """All (keys, vals) with LSN >= ``lsn``, concatenated in LSN
        order regardless of tree — the single-tree replay suffix (and
        the flat view tests/benchmarks inspect).  Like ``frames_since``,
        reads straight through an attached contiguous archive."""
        ks, vs = [], []
        frames = self._frames
        if lsn < self.start_lsn and self._archived:
            frames = self._archived + frames
        for base, _tree, recs in frames:
            if base + len(recs) <= lsn:
                continue
            sl = recs[max(0, lsn - base):]
            ks.append(sl["key"])
            vs.append(sl["val"])
        if not ks:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        return (np.concatenate(ks).astype(np.uint32),
                np.concatenate(vs).astype(np.int32))

    def frames_since(self, lsn: int) -> list[tuple[int, int, np.ndarray,
                                                   np.ndarray]]:
        """Tree-attributed replay suffix: ``(tree, base_lsn, keys,
        vals)`` per surviving frame in global LSN order, with frames
        straddling ``lsn`` sliced to their suffix (``base_lsn`` is the
        slice's first LSN).  Multi-tree recovery routes each frame to
        its tree.  When ``lsn`` predates the live log's ``start_lsn``
        and a contiguous archive is attached, archived frames are
        included — replay reads straight through cold storage."""
        out = []
        frames = self._frames
        if lsn < self.start_lsn and self._archived:
            frames = self._archived + frames
        for base, tree, recs in frames:
            if base + len(recs) <= lsn:
                continue
            sl = recs[max(0, lsn - base):]
            out.append((tree, max(base, lsn),
                        sl["key"].astype(np.uint32),
                        sl["val"].astype(np.int32)))
        return out

    # ---------------------------------------------------------- truncation
    def truncate_upto(self, lsn: int) -> int:
        """Drop whole SEALED segments whose entries all precede ``lsn``
        (snapshot compaction: those entries are captured in durable
        SSTables).  Segment-granular and O(1) per segment — an unlink
        (or, with ``archive_dir`` set, an atomic rename into the
        archive under the canonical ``<name>.NNNNNN`` name), never a
        rewrite: a segment straddling ``lsn`` is kept whole and replay
        skips its already-flushed prefix (so ``start_lsn`` lands at or
        before ``lsn``, never past it).  Returns the logical entries
        ARCHIVED by this call (0 in unlink mode) so the caller can
        charge the copy-out to the I/O budget."""
        drop = 0
        for seg in self._segs[:-1]:            # the tail never drops
            if seg.end_lsn <= lsn:
                drop += 1
            else:
                break
        if drop == 0:
            return 0
        boundary = self._segs[drop - 1].end_lsn
        archived = 0
        for seg in self._segs[:drop]:
            self.written_bytes -= seg.nbytes
            self.synced_bytes -= seg.nbytes    # sealed == fully synced
            if self.archive_dir is not None:
                self.archive_dir.mkdir(parents=True, exist_ok=True)
                dst = self.archive_dir / f"{self.path.name}.{seg.seq:06d}"
                self.io.replace(seg.path, dst)
                archived += seg.entries
                self.archived_segments += 1
                self.archived_entries += seg.entries
                self.archived_bytes += seg.nbytes
            else:
                self.io.unlink(seg.path)
        moved = [(b, t, r) for b, t, r in self._frames if b < boundary]
        self._segs = self._segs[drop:]
        self._frames = [(b, t, r) for b, t, r in self._frames
                        if b >= boundary]
        if self.archive_dir is not None and moved:
            if self._archived:
                lb, _lt, lr = self._archived[-1]
                if lb + len(lr) != moved[0][0]:    # stale disjoint archive
                    self._archived = []
            self._archived.extend(moved)
        self.start_lsn = self._frames[0][0] if self._frames else self.end_lsn
        return archived

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Durable close: sync, then release the handle."""
        if not self._f.closed:
            self.sync()
            self._f.close()

    def abort(self) -> None:
        """Crash-style close: release the handle WITHOUT syncing (the
        fault harness uses this before applying a torn tail)."""
        if not self._f.closed:
            self._f.close()


class RecoverySession:
    """Budgeted crash recovery for a ``StorageGroup`` (the single-tree
    ``LSMEngine`` included): per-tree snapshot restore + global-LSN-order
    WAL replay.

    Construct with a FRESH group (same tree topology as the crashed one,
    its reopened ``WriteAheadLog`` attached).  Construction restores
    each snapshot tree section into its tree's read view, computes the
    global replay origin (the minimum per-tree ``flushed_lsn``, floored
    by ``wal.start_lsn``) and stages the tree-attributed WAL suffix;
    inside a frame, the prefix already captured by that tree's snapshot
    is skipped exactly.  ``advance(budget)`` then replays up to
    ``budget`` entries of I/O — each replayed entry charges one entry
    (the WAL read), and replay-induced flushes/merges run through
    ``group.pump`` against the same budget (apportioned across trees by
    background debt), so recovery speed is bandwidth-bound end to end.
    ``run(budget)`` loops to completion and returns the epoch count
    (the virtual recovery time at that bandwidth).

    With ``online=True`` the group opens for traffic IMMEDIATELY:
    construction restores the snapshot, rotates the WAL tail (the
    fresh-segment rule), jumps the group clock to the live frontier,
    and attaches this session as the group's replay stream — ``pump``
    then interleaves budgeted replay with serving (replay debt is one
    more background stream in the largest-remainder split), reads
    observe durable-prefix(``watermark``) + live writes, and live
    writes win over the unreplayed history via per-tree live-key
    tracking (see the module docstring's consistency contract).
    ``advance``/``run`` on an online session simply drive ``pump``."""

    def __init__(self, engine, store=None, online: bool = False):
        self.engine = engine
        self.online = bool(online)
        trees = engine.trees
        with engine.lock():
            snap = store.load() if store is not None else None
            base_by_tree = {t.tree_id: 0 for t in trees}
            if snap is not None:
                sections = snap.get("trees")
                if sections is None:           # legacy single-tree manifest
                    sections = [dict(snap, tree=0)]
                if len(sections) > len(trees):
                    raise ValueError(
                        f"snapshot has {len(sections)} trees but the "
                        f"group has {len(trees)}: topology mismatch")
                for sec in sections:
                    tid = int(sec.get("tree", 0))
                    base_by_tree[tid] = trees[tid].restore_tables(
                        store.load_tree_tables(sec), sec)
                engine.now = max(engine.now, float(snap.get("now", 0.0)))
            base = min(base_by_tree.values()) if base_by_tree else 0
            if engine.wal is not None:
                base = max(base, engine.wal.oldest_lsn)
                frames = engine.wal.frames_since(base)
            else:
                frames = []
            engine.begin_replay(base)
            for t in trees:
                t.active.start_lsn = max(base, base_by_tree[t.tree_id])
            # stage per-frame replay chunks, skipping each tree's
            # already-flushed prefix (LSNs below its snapshot origin)
            self._chunks: list[tuple[int, np.ndarray, np.ndarray, int]] = []
            self.total = 0
            self.replay_end = base      # first LSN after the staged history
            for tree, fbase, ks, vs in frames:
                skip = max(0, base_by_tree.get(tree, 0) - fbase)
                self.replay_end = max(self.replay_end, fbase + len(ks))
                if skip >= len(ks):
                    continue
                self._chunks.append((tree, ks[skip:], vs[skip:],
                                     fbase + skip))
                self.total += len(ks) - skip
            self.watermark = base       # durable-prefix frontier replayed
            if self.online:
                self._open_online(base)
        self._ci = 0          # current chunk index
        self.pos = 0          # replayed entries (all chunks)
        self._cpos = 0        # position within the current chunk
        self.epochs = 0

    def _open_online(self, base: int) -> None:
        """Attach as the group's live replay stream (engine lock held):
        fresh WAL segment for live frames, group clock at the live
        frontier, live-key tracking on, watermark mirrored."""
        eng = self.engine
        live_frontier = self.replay_end
        if eng.wal is not None:
            eng.wal.rotate()               # the fresh-segment rule
            live_frontier = max(live_frontier, eng.wal.end_lsn)
        eng._lsn = live_frontier           # new writes number after history
        for t in eng.trees:
            t._live_keys = set()
        eng._replay_watermark = self.watermark
        eng._recovery = self
        if self.total == 0:                # nothing to replay: already done
            self._finish_online()

    def _finish_online(self) -> None:
        """Replay drained (engine lock held): detach from the group and
        stop filtering — the group is fully recovered and live."""
        eng = self.engine
        self.watermark = self.replay_end
        if eng._recovery is self:
            eng._recovery = None
            eng._replay_watermark = None
            for t in eng.trees:
                t._live_keys = None

    @property
    def remaining(self) -> int:
        return self.total - self.pos

    @property
    def done(self) -> bool:
        return self.pos >= self.total

    def _replay_step(self, budget_entries: int) -> int:
        """Online replay quantum, called from ``StorageGroup`` inside
        ``pump`` with the engine lock HELD (never recurses into pump:
        when a tree's memtables are all full the step yields and the
        flush debt it just created drains in the same epoch's tree
        apportionment).  Charges one entry of budget per staged entry
        read — including entries dropped by the live-key filter (the
        WAL read happened either way) — and advances the watermark."""
        eng = self.engine
        spent = 0
        while spent < int(budget_entries) and self._ci < len(self._chunks):
            tid, ks, vs, lsn0 = self._chunks[self._ci]
            if self._cpos >= len(ks):
                self._ci += 1
                self._cpos = 0
                continue
            tree = eng.trees[tid]
            if tree.active.full:
                if len(tree.sealed) >= tree.num_memtables - 1:
                    break           # all memtables full: flush debt's turn
                tree.seal_active()
            room = tree.active.capacity - len(tree.active)
            take = min(room, int(budget_entries) - spent,
                       len(ks) - self._cpos)
            if take <= 0:
                break
            sk = ks[self._cpos:self._cpos + take]
            sv = vs[self._cpos:self._cpos + take]
            live = tree._live_keys
            if live:
                # live writes win: drop history for keys written since
                # reopen (the memtable is newest-wins by insertion
                # order, so admitting old entries later would clobber)
                keep = np.array([int(k) not in live for k in sk], bool)
                sk, sv = sk[keep], sv[keep]
            if len(sk):
                tree.replay_admit(sk, sv)
            self._cpos += take
            self.pos += take
            spent += take
            self.watermark = lsn0 + self._cpos
            eng._replay_watermark = self.watermark
        if self.done:
            self._finish_online()
        return spent

    def advance(self, budget_entries: int) -> int:
        """One recovery epoch: replay/pump up to ``budget_entries`` of
        I/O.  Returns entries of budget actually spent.  On an ONLINE
        session this simply drives ``pump`` (replay is one of the
        group's background-debt streams), so existing epoch-loop
        drivers recover-while-serving unchanged."""
        eng = self.engine
        self.epochs += 1
        if self.online:
            return eng.pump(int(budget_entries))
        spent = 0
        with eng.lock():
            while spent < int(budget_entries) and self._ci < len(self._chunks):
                tid, ks, vs, lsn0 = self._chunks[self._ci]
                if self._cpos >= len(ks):
                    self._ci += 1
                    self._cpos = 0
                    continue
                tree = eng.trees[tid]
                if tree.active.full and \
                        len(tree.sealed) >= tree.num_memtables - 1:
                    done = eng.pump(int(budget_entries) - spent)
                    spent += done
                    if done <= 0:       # budget too small to flush: stop
                        break
                    continue
                if tree.active.full:
                    tree.seal_active()
                room = tree.active.capacity - len(tree.active)
                take = min(room, int(budget_entries) - spent,
                           len(ks) - self._cpos)
                if take <= 0:
                    break
                tree.replay_admit(ks[self._cpos:self._cpos + take],
                                  vs[self._cpos:self._cpos + take])
                self._cpos += take
                self.pos += take
                spent += take
                # frames are replayed in global LSN order, so the group
                # clock is the consumed chunk's frontier
                eng._lsn = lsn0 + self._cpos
        return spent

    def run(self, budget_per_epoch: int, max_epochs: int = 1_000_000) -> int:
        """Replay to completion at a fixed per-epoch budget; returns the
        number of epochs taken (recovery time in budget quanta)."""
        for _ in range(max_epochs):
            if self.done:
                return self.epochs
            if self.advance(budget_per_epoch) <= 0 and not self.done:
                raise RuntimeError("recovery stalled: budget too small "
                                   "to make progress")
        raise RuntimeError("recovery exceeded max_epochs")


def recover_engine(engine, store=None,
                   budget_per_epoch: int = 1 << 30) -> int:
    """One-call recovery: replay the group's WAL (plus ``store``'s
    snapshot, when given) to completion.  Returns the epoch count."""
    return RecoverySession(engine, store).run(budget_per_epoch)
