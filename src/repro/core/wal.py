"""Write-ahead log + crash recovery for the real engine (the durability
plane).

The WAL is a single append-only file of CRC-framed record batches.  One
``append`` call writes one frame — the group-commit unit: the engine
appends each admitted ``put_batch`` chunk as one frame BEFORE the
memtable admits it, so every acknowledged write is in the OS file
buffer, and is durable once ``sync`` (fsync) runs.  Group commit is the
engine's knob (``group_commit_entries``): syncs happen when enough
entries accumulate, and unconditionally at every ``pump`` epoch — the
fsync-epoch boundary — with the synced bytes charged against the
scheduler's I/O budget, so WAL traffic competes with flushes and merges
for the same bandwidth (the paper's single-SSD write-budget model;
commit-path batching trades durability latency against that budget,
exactly the interaction Luo & Carey's ingestion study measures).

Frame layout (little-endian)::

    u32 magic | u32 n_entries | u64 base_lsn | u32 crc32(payload)
    payload: n_entries * (u32 key, i32 val)

LSNs number logical entries from the log's creation, monotonically,
across truncations.  Tombstones need no flag: a record whose value is
the reserved ``TOMBSTONE`` sentinel IS the delete (the same encoding
the memtable/SSTable/merge planes carry).

Crash semantics: on open, the file is scanned frame-by-frame; the first
frame with a bad magic, an impossible length, a CRC mismatch, or a
non-contiguous ``base_lsn`` ends the valid prefix, and the file is
truncated there — a torn tail (a crash mid-write, or the fault
harness's deliberate mid-frame cut) silently costs the entries past the
last complete frame, never correctness.  Everything fsynced before the
crash is always inside the valid prefix; unsynced-but-buffered frames
may or may not survive (page-cache reality, modeled by
``faults.apply_torn_tail``).

Recovery (``RecoverySession``) restores the snapshot's SSTables (see
``checkpoint.store.EngineSnapshotStore``), then replays the WAL suffix
from the snapshot's ``flushed_lsn`` into fresh memtables in LSN order —
admission without re-logging and without constraint stalls.  Replay is
BUDGETED: each replayed entry charges one entry of read I/O and
replay-induced flushes/merges run through ``engine.pump`` on the same
budget, so a starved bandwidth budget slows recovery measurably
(``benchmarks/recovery.py`` pins this).  The recovered engine's read
view is bit-identical to the pre-crash durable state: ``_order`` is
rebuilt at its ``(-data_stamp, level)`` ranks and the Bloom filter
stack rebuilds lazily on the first probe.
"""
from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path
from typing import Optional

import numpy as np

from .memtable import TOMBSTONE  # noqa: F401  (re-export: the WAL's delete encoding)

WAL_MAGIC = 0x57414C31            # "WAL1"
_HEADER = struct.Struct("<IIQI")  # magic, n_entries, base_lsn, crc32
REC_DTYPE = np.dtype([("key", "<u4"), ("val", "<i4")])


class WriteAheadLog:
    """Append-only CRC-framed record log with an explicit durability
    boundary.

    ``append`` writes one frame into the OS file (flushed, not fsynced);
    ``sync`` fsyncs and advances the durable boundary
    (``synced_bytes``/``synced_lsn``).  Opening an existing path scans
    and validates the frames, truncates any torn tail, and positions
    appends after the last valid frame; everything on disk at open is
    treated as durable (it survived the crash by definition)."""

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self._frames: list[tuple[int, np.ndarray]] = []  # (base_lsn, recs)
        self.start_lsn = 0            # first LSN still present in the file
        self.end_lsn = 0              # next LSN to be appended
        valid = 0
        if self.path.exists():
            valid = self._scan()
            if self.path.stat().st_size > valid:
                os.truncate(self.path, valid)       # drop the torn tail
        self._f = open(self.path, "ab")
        self.written_bytes = valid    # bytes in the OS file
        self.synced_bytes = valid     # bytes known durable (fsynced)
        self.synced_lsn = self.end_lsn
        self.syncs = 0

    # ------------------------------------------------------------- scanning
    def _scan(self) -> int:
        """Validate frames from the start; populate ``_frames`` and the
        LSN bounds.  Returns the byte length of the valid prefix."""
        data = self.path.read_bytes()
        off = 0
        first = True
        while off + _HEADER.size <= len(data):
            magic, n, base, crc = _HEADER.unpack_from(data, off)
            end = off + _HEADER.size + n * REC_DTYPE.itemsize
            if magic != WAL_MAGIC or n == 0 or end > len(data):
                break
            payload = data[off + _HEADER.size:end]
            if zlib.crc32(payload) != crc:
                break
            if first:
                self.start_lsn = base
                self.end_lsn = base
                first = False
            elif base != self.end_lsn:
                break                                  # non-contiguous
            recs = np.frombuffer(payload, REC_DTYPE)
            self._frames.append((base, recs))
            self.end_lsn = base + n
            off = end
        if first:
            self.start_lsn = self.end_lsn = 0
        return off

    # ------------------------------------------------------------- writing
    def append(self, keys, vals) -> int:
        """Write one frame (the group-commit unit) into the OS file
        buffer; returns the frame's base LSN.  NOT yet durable — durable
        after the next ``sync``."""
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        n = len(keys)
        if n == 0:
            return self.end_lsn
        recs = np.empty(n, REC_DTYPE)
        recs["key"] = keys
        recs["val"] = vals
        payload = recs.tobytes()
        base = self.end_lsn
        self._f.write(_HEADER.pack(WAL_MAGIC, n, base, zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()                       # to the OS, not to disk
        self._frames.append((base, recs))
        self.end_lsn = base + n
        self.written_bytes += _HEADER.size + len(payload)
        return base

    def sync(self) -> int:
        """fsync: advance the durability boundary over everything
        appended so far.  Returns the bytes made durable by this call
        (0 when already clean)."""
        delta = self.written_bytes - self.synced_bytes
        if delta > 0:
            self._f.flush()
            os.fsync(self._f.fileno())
            self.synced_bytes = self.written_bytes
            self.synced_lsn = self.end_lsn
            self.syncs += 1
        return delta

    @property
    def unsynced_entries(self) -> int:
        return self.end_lsn - self.synced_lsn

    @property
    def entries(self) -> int:
        """Logical entries currently in the log (post-truncation)."""
        return self.end_lsn - self.start_lsn

    # ------------------------------------------------------------- reading
    def entries_since(self, lsn: int) -> tuple[np.ndarray, np.ndarray]:
        """All (keys, vals) with LSN >= ``lsn``, concatenated in LSN
        order — the replay suffix recovery feeds back through the
        memtable plane."""
        ks, vs = [], []
        for base, recs in self._frames:
            if base + len(recs) <= lsn:
                continue
            sl = recs[max(0, lsn - base):]
            ks.append(sl["key"])
            vs.append(sl["val"])
        if not ks:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        return (np.concatenate(ks).astype(np.uint32),
                np.concatenate(vs).astype(np.int32))

    # ---------------------------------------------------------- truncation
    def truncate_upto(self, lsn: int) -> None:
        """Drop whole frames whose entries all precede ``lsn`` (snapshot
        compaction: those entries are captured in durable SSTables).
        Frame-granular: a frame straddling ``lsn`` is kept whole and
        replay skips its already-flushed prefix.  Atomic: the survivors
        are rewritten to a temp file that replaces the log."""
        keep = [(b, r) for b, r in self._frames if b + len(r) > lsn]
        if len(keep) == len(self._frames):
            return
        tmp = self.path.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            for base, recs in keep:
                payload = recs.tobytes()
                f.write(_HEADER.pack(WAL_MAGIC, len(recs), base,
                                     zlib.crc32(payload)))
                f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")
        self._frames = keep
        self.start_lsn = keep[0][0] if keep else self.end_lsn
        self.written_bytes = self.path.stat().st_size
        self.synced_bytes = self.written_bytes
        self.synced_lsn = self.end_lsn

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Durable close: sync, then release the handle."""
        if not self._f.closed:
            self.sync()
            self._f.close()

    def abort(self) -> None:
        """Crash-style close: release the handle WITHOUT syncing (the
        fault harness uses this before applying a torn tail)."""
        if not self._f.closed:
            self._f.close()


class RecoverySession:
    """Budgeted crash recovery: snapshot restore + WAL replay.

    Construct with a FRESH engine (same configuration as the crashed
    one, its reopened ``WriteAheadLog`` attached).  Construction
    restores the snapshot's SSTables into the read view and stages the
    WAL suffix from the snapshot's ``flushed_lsn``; ``advance(budget)``
    then replays up to ``budget`` entries of I/O — each replayed entry
    charges one entry (the WAL read), and replay-induced flushes/merges
    run through ``engine.pump`` against the same budget, so recovery
    speed is bandwidth-bound end to end.  ``run(budget)`` loops to
    completion and returns the epoch count (the virtual recovery time
    at that bandwidth)."""

    def __init__(self, engine, store=None):
        self.engine = engine
        base = 0
        with engine.lock():
            snap = store.load() if store is not None else None
            if snap is not None:
                base = engine.restore_tables(store.load_tables(snap), snap)
            if engine.wal is not None:
                base = max(base, engine.wal.start_lsn)
                self.keys, self.vals = engine.wal.entries_since(base)
            else:
                self.keys = np.empty(0, np.uint32)
                self.vals = np.empty(0, np.int32)
            engine.begin_replay(base)
        self.pos = 0
        self.epochs = 0

    @property
    def remaining(self) -> int:
        return len(self.keys) - self.pos

    @property
    def done(self) -> bool:
        return self.pos >= len(self.keys)

    def advance(self, budget_entries: int) -> int:
        """One recovery epoch: replay/pump up to ``budget_entries`` of
        I/O.  Returns entries of budget actually spent."""
        eng = self.engine
        spent = 0
        self.epochs += 1
        with eng.lock():
            while spent < int(budget_entries) and self.pos < len(self.keys):
                if eng.active.full and \
                        len(eng.sealed) >= eng.num_memtables - 1:
                    done = eng.pump(int(budget_entries) - spent)
                    spent += done
                    if done <= 0:       # budget too small to flush: stop
                        break
                    continue
                if eng.active.full:
                    eng.seal_active()
                room = eng.active.capacity - len(eng.active)
                take = min(room, int(budget_entries) - spent,
                           len(self.keys) - self.pos)
                if take <= 0:
                    break
                eng.replay_admit(self.keys[self.pos:self.pos + take],
                                 self.vals[self.pos:self.pos + take])
                self.pos += take
                spent += take
        return spent

    def run(self, budget_per_epoch: int, max_epochs: int = 1_000_000) -> int:
        """Replay to completion at a fixed per-epoch budget; returns the
        number of epochs taken (recovery time in budget quanta)."""
        for _ in range(max_epochs):
            if self.done:
                return self.epochs
            if self.advance(budget_per_epoch) <= 0 and not self.done:
                raise RuntimeError("recovery stalled: budget too small "
                                   "to make progress")
        raise RuntimeError("recovery exceeded max_epochs")


def recover_engine(engine, store=None,
                   budget_per_epoch: int = 1 << 30) -> int:
    """One-call recovery: replay the engine's WAL (plus ``store``'s
    snapshot, when given) to completion.  Returns the epoch count."""
    return RecoverySession(engine, store).run(budget_per_epoch)
