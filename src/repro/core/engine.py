"""The real LSM storage plane: a multi-tree ``StorageGroup`` of
``LSMTree``s sharing one I/O plane, with ``LSMEngine`` as the 1-tree
instantiation.

Ownership split
===============

``LSMTree`` (per tree — one primary tree, plus one sibling tree per
secondary index) owns everything whose state is a single LSM tree:

* the memtable plane (``active``/``sealed``) and its flush queue;
* the run levels (``tables``/``_order``) and the scheduling-plane
  metadata (``meta``, a ``component.LSMTree``) the merge POLICY reads;
* the cached read view + Bloom filter stack (the fused-probe operand);
* the merge policy, per-tree merge SCHEDULER, write constraint, and the
  streaming-merge cursor state of its running merges;
* per-tree stats and flush-quantum debt.

``StorageGroup`` owns everything cross-cutting EXACTLY ONCE:

* the ``ExecBackend`` (every kernel-vs-host decision, all trees);
* the group-committed ``WriteAheadLog`` — ONE log whose frames carry a
  tree id, with GLOBAL LSNs numbering entries in group admission order
  (primary writes and the index maintenance they induce interleave in
  one total order, which is what makes multi-tree recovery a prefix
  property);
* the I/O budget: each ``pump(budget)`` epoch first syncs/repays WAL
  traffic, then splits the remainder ACROSS TREES by background debt
  via ``apportion_largest_remainder`` (the same largest-remainder
  apportionment the per-tree scheduler and the fleet arbiter use), so
  primary compaction, index compaction and durability all draw from the
  paper's single-disk write budget;
* the reentrant lock, the virtual clock ``now``, snapshots
  (``checkpoint.EngineSnapshotStore`` saves every tree's runs + a
  per-tree ``flushed_lsn``), and recovery (``wal.RecoverySession``
  replays the WAL suffix over N trees, routing frames by tree id).

``LSMEngine`` subclasses ``StorageGroup`` with no secondary indexes:
the single-tree engine every existing caller (fleet, twophase, faults,
benchmarks) keeps using.  The group mirrors the legacy engine surface —
``active``/``sealed``/``tables``/``stats``/``seal_active``/
``_read_view``/… delegate to the primary tree — so 1-tree behavior is
bit-identical to the pre-split engine.

Secondary indexes
=================

An index (``IndexSpec``) is a sibling LSM tree mapping a uint32
ATTRIBUTE (``extract(value)``; default = the value's low 32 bits) to
the primary key (stored as the index tree's int32 value, so primary
keys must stay below 2**31 in indexed groups).  Newest-wins dedup makes
it a unique index: one primary key per attribute.  Both maintenance
strategies from the paper (fig25-27) are real:

* **eager** — on every put/delete the group resolves the OLD value
  first (real point lookups through the fused probe, batched per
  admitted chunk with intra-chunk occurrences resolved in-memory),
  deletes the stale index entry (tombstone) and inserts the new one.
  The index tree is exact at all times, so ``index_scan`` is a COVERING
  scan and ``index_lookup`` is one probe of the index tree.
* **lazy** — puts append ``attr -> pk`` blindly (no lookup, no stale
  deletion; deletes touch the index not at all), and every index READ
  validates candidates against the primary: an entry counts only if the
  primary's current value still maps to that attribute.  Ingestion is
  cheaper; reads pay the validation probe.

Index maintenance entries are WAL-framed under the index tree's id
BEFORE admission (crash point ``post-primary-pre-index`` sits between
the primary admit and the index admit), admitted stall-free
(``force_admit`` — the primary's gate already paced the batch), and
flushed/merged by the index tree under the shared budget.

Execution model (per tree, unchanged from the pre-split engine)
===============================================================

Deterministic cooperative quanta: flushes take strict priority, then
merges per the tree scheduler's allocation.  All background work is
STREAMED so one quantum costs O(quantum): a merge keeps per-run
cursors, cuts each window at a global key boundary (the merge-path
pivot — no equal-key group straddles windows, so concatenated windows
are bit-identical to the one-shot merge), and accumulates output into
preallocated host + device buffers that ``_finish_merge`` binds as O(1)
views.  Flushes larger than the remaining quantum carry their overshoot
as per-tree debt repaid before new work.

Read view contract (per tree): point reads and scans go through a
cached ``_ReadView`` over the disk tables, NEWEST-FIRST by
``(-data_stamp, level)``, maintained INCREMENTALLY — a flush prepends
one table, a merge completion bisect-inserts its outputs; no re-sort.
The Bloom stack (``_FilterStack``) is event-driven: background events
journal adds/removes in O(1) and the first point lookup after an event
applies the journal (donated device row writes, host mirror in
lockstep).  ``get_batch`` walks the view newest-first with early exit
behind ONE fused multi-table probe; ``scan_range`` resolves every run's
window in one k-way newest-wins merge (tombstones filtered in-merge).

Backend / dispatch: every launch routes through the group's ONE
``ExecBackend`` (host-vs-kernel per op per size class from the measured
calibration artifact; the three legacy booleans map to forced modes via
``ExecBackend.from_legacy`` and are exposed read-only).

Thread safety: every foreground entry point and the background plane
take the GROUP's reentrant lock internally; ``lock()`` exposes it for
compound atomicity.  ``scan_range`` releases it for the merge itself
(run windows are immutable snapshots).

Durability contract (group-owned; ``core/wal.py``)
==================================================

* With no WAL the group is volatile (the seed's behavior).
* With a WAL, every admitted chunk — primary puts/deletes AND the index
  maintenance entries they induce — is appended as one tree-tagged
  frame BEFORE its memtable admits it, so the admitted history and the
  log agree entry-for-entry (global LSN == group admission index).
  fsyncs happen at ``group_commit_entries`` and at every pump epoch;
  synced traffic is charged to the group's WAL debt and repaid from the
  budget ahead of all trees.
* ``flushed_lsn`` is per tree (everything below the oldest unflushed
  memtable's ``start_lsn`` is in that tree's SSTables); the group's is
  their MINIMUM — the snapshot's WAL-truncation point (segment-granular:
  the log drops whole sealed segments below it, so ``wal.start_lsn``
  may land before it and replay skips the overlap per tree).
* Recovery: restore each tree's snapshot section, then replay
  ``wal.frames_since`` in global LSN order, routing frames by tree id
  and skipping, inside a frame, the prefix below that tree's snapshot
  origin.  The recovered group answers every read bit-identically to an
  uncrashed group fed the same durable prefix — per tree
  (``tests/test_durability.py`` pins this, crash points x policies, and
  the multi-tree crash between primary admit and eager index
  maintenance).
* Tombstones: deletes admit the reserved ``TOMBSTONE`` value through
  the ordinary write path (WAL, memtable, flush, merge carry it as
  data); the read plane hides it; merges nothing-older overlaps drop
  them (``compact_all`` reclaims space-amp to ~1).  Eager index
  maintenance writes the same tombstones into index trees to kill stale
  entries.

Online recovery and the fault-tolerance plane
=============================================

A ``RecoverySession(online=True)`` reopens the group FOR TRAFFIC before
replay finishes.  The consistency contract:

* **Watermark**: ``_replay_watermark`` is the durable replay frontier —
  every LSN below it has been re-admitted.  Reads observe exactly
  ``durable prefix up to the watermark + live writes``; the watermark
  only advances.
* **Fresh-segment rule**: the session rotates the WAL tail at open, so
  frames written by live traffic never interleave with the frames being
  replayed; the group LSN jumps to the live frontier (max of the log's
  end and the replay end) before the first live write.
* **Live writes win**: per-tree ``_live_keys`` records keys written
  since the reopen; the replay step drops those keys' history (the
  memtable is newest-wins by insertion order, so un-filtered replay
  would resurrect stale values).
* Replay itself is a pump-driven debt stream: ``_pump_locked``
  arbitrates it against flush/merge/WAL debt via the same
  largest-remainder split, so a starved budget slows FULL recovery but
  never time-to-first-read.  ``seal_active`` and the group
  ``flushed_lsn`` cap their LSN claims at the watermark — snapshot
  truncation can never drop un-replayed WAL.

Transient I/O faults (``core/iostack.py``) retry with capped
exponential backoff; ENOSPC surfaces as ``StorageFull`` and is absorbed
as a constraint stall (writes refuse work, drain when space returns) —
never data loss.  A background ``Scrubber`` (``enable_scrub``) streams
CRC verification over live tables from the pump budget; a corrupt table
is quarantined (out of the read view immediately), repaired from the
snapshot store or by whole-tree WAL rebuild, and only when no durable
copy survives does the tree turn ``corrupt`` — after which reads raise
``UnrepairableCorruptionError``, a typed error instead of a wrong
answer.  ``health()`` exposes the fault-plane counters.
"""
from __future__ import annotations

import bisect
import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .backend import ExecBackend, merge_kway_host  # noqa: F401 (re-export:
                                                   # the fleet's scan gather
                                                   # shares the host merge)
from .component import Component, MergeOp
from .component import LSMTree as ComponentTree
from .constraints import ComponentConstraint, NoConstraint
from .iostack import StorageFull, UnrepairableCorruptionError
from .memtable import (MemTable, SENTINEL_KEY, TOMBSTONE,
                       drop_tombstones)
from .policies import MergePolicy
from .scheduler import (FairScheduler, MergeScheduler,
                        apportion_largest_remainder)
from .sstable import SSTable

try:  # the kernels need jax; engine tests always have it
    from repro.kernels.bloom.ops import set_stack_row
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    set_stack_row = None
    jax = jnp = None


ENTRY_BYTES = 1024  # paper's 1 KB records: 1 entry == 1 KB of I/O budget


def _next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(n - 1, 0).bit_length()


if jax is not None:
    @functools.partial(jax.jit, donate_argnums=(0,))
    def _write_window(buf, win, start):
        """Fold one merge window into the device accumulation buffer.
        The buffer is DONATED so backends with input-output aliasing
        update it in place (O(window), no O(buffer) copy); windows are
        pow2-padded by the caller so the jit cache holds O(log cap)
        shapes per merge instead of one entry per distinct window."""
        return jax.lax.dynamic_update_slice(buf, win, (start,))
else:  # pragma: no cover - kernels unavailable
    _write_window = None


@dataclass
class _ReadView:
    """Cached snapshot of ONE tree's disk tables for the read plane.

    ``tables`` is newest-first by ``(-data_stamp, level)`` — an O(tables)
    tuple snapshot of the tree's insertion-maintained ``_order`` list.
    ``filts``/``meta`` stay ``None`` until the first point lookup applies
    the persistent ``_FilterStack``'s pending journal
    (``LSMTree._view_filters``): ``filts`` is the stack's DEVICE array
    (capacity rows, only live slots meaningful), ``meta`` the host-side
    per-row (n_bits, k) geometry; each table's probe row is its own
    ``stack_slot``.  Scan-only workloads never populate them.
    """
    tables: tuple
    filts: Optional["jnp.ndarray"] = None
    meta: Optional[np.ndarray] = None


class _FilterStack:
    """Persistent device-side Bloom filter stack with slot reuse — the
    fused multi-table probe's operand, maintained incrementally and
    EVENT-DRIVEN (one stack per tree).

    The tree notes every table add/remove as it happens
    (``note_add``/``note_remove``, O(1) bookkeeping, NO device work — so
    background quanta and scan-only workloads never touch the stack).
    ``sync(tables)``, called on the first point lookup after a view
    rebuild, applies the pending journal: removed tables free their
    rows; each added table takes a free row via ONE donated device row
    write (``set_stack_row``, O(filter width)) and records the row in
    ``SSTable.stack_slot`` so the probe path needs no per-view gather.
    An add whose table is merged away before any read CANCELS against
    its remove — its filter row (and, with lazy Bloom construction, the
    filter itself) is never built at all.

    The stack is rebuilt from scratch only when capacity or row width
    must grow or occupancy falls below 1/4 of capacity — geometric
    sizing, amortized O(rows changed) per background event instead of
    the O(tables * filter-bytes) restack-and-reupload of the per-view
    ``stack_filters`` path this replaces.  Free rows keep
    (n_bits=128, k=1) metadata so they never inflate the probe's static
    ``k_max``; their stale word content is only reachable through a
    stale (raced, uncached) view's ``stack_slot``.
    """

    def __init__(self):
        self.filts: Optional["jnp.ndarray"] = None   # (cap, width) uint32
        self.filts_np: Optional[np.ndarray] = None   # host mirror of the
                                                     # stack — the backend's
                                                     # HOST probe operand
        self.meta = np.zeros((0, 2), np.uint32)      # host (cap, 2)
        self.slots: dict[int, int] = {}              # component cid -> row
        self.free: list[int] = []
        self._add: dict[int, SSTable] = {}           # pending, cid-keyed
        self._remove: list[int] = []                 # pending, cids

    @property
    def cap(self) -> int:
        return 0 if self.filts is None else int(self.filts.shape[0])

    @property
    def width(self) -> int:
        return 0 if self.filts is None else int(self.filts.shape[1])

    def note_add(self, table: SSTable) -> None:
        self._add[table.component.cid] = table

    def note_remove(self, cid: int) -> None:
        if self._add.pop(cid, None) is not None:
            return                       # never materialized: cancelled
        if cid in self.slots:
            self._remove.append(cid)

    def _rebuild(self, tables) -> None:
        cap = max(4, 2 * len(tables))
        width = max(max((t.bloom_host().shape[0] for t in tables),
                        default=1), 1)
        stk = np.zeros((cap, width), np.uint32)
        self.meta = np.zeros((cap, 2), np.uint32)
        self.meta[:, 0] = 128
        self.meta[:, 1] = 1
        self.slots = {}
        for i, t in enumerate(tables):
            w = t.bloom_host()
            stk[i, :w.shape[0]] = w
            self.meta[i] = (t.n_bits, t.k_hashes)
            self.slots[t.component.cid] = i
            t.stack_slot = i
        self.free = list(range(len(tables), cap))
        self.filts_np = stk
        self.filts = jnp.array(stk)      # independent device copy: row
                                         # writes donate the device buffer
                                         # and must never alias the mirror
        self._add.clear()
        self._remove.clear()

    def sync(self, tables) -> tuple["jnp.ndarray", np.ndarray]:
        """Apply the pending add/remove journal; returns
        ``(filts, meta)`` (probe rows come from each table's
        ``stack_slot``).  The previous device array is donated by row
        writes — every external reference must be replaced by the
        returned one."""
        if self.filts is None:
            self._rebuild(tables)
            return self.filts, self.meta
        for cid in self._remove:
            row = self.slots.pop(cid, None)
            if row is not None:
                self.free.append(row)
                self.meta[row] = (128, 1)
        self._remove.clear()
        if self._add:
            adds = list(self._add.values())
            need_w = max(t.bloom_host().shape[0] for t in adds)
            n_live = len(self.slots) + len(adds)
            if need_w > self.width or len(adds) > len(self.free) \
                    or (self.cap > 8 and 4 * n_live < self.cap):
                self._rebuild(tables)
                return self.filts, self.meta
            for t in adds:
                row = self.free.pop()
                words = t.bloom_host()
                if words.shape[0] != self.width:
                    padded = np.zeros(self.width, np.uint32)
                    padded[:words.shape[0]] = words
                    words = padded
                self.filts = set_stack_row(self.filts, words, row)
                self.filts_np[row] = words        # keep the host mirror
                                                  # (HOST probe operand)
                                                  # in lockstep
                self.meta[row] = (t.n_bits, t.k_hashes)
                self.slots[t.component.cid] = row
                t.stack_slot = row
            self._add.clear()
        elif self.cap > 8 and 4 * len(self.slots) < self.cap:
            self._rebuild(tables)
        return self.filts, self.meta


@dataclass
class _RunningMerge:
    op: MergeOp
    inputs: list[SSTable]
    drop: bool = False         # reclaim tombstones (bottom-level merge)
    # -- streaming cursor state (opened lazily by ``_open_merge``) -----
    tables: Optional[list] = None          # inputs sorted newest-first
    run_keys: Optional[list] = None        # per-run host key mirrors
    run_vals: Optional[list] = None
    cursors: Optional[np.ndarray] = None   # per-run consumed prefix
    lens: Optional[np.ndarray] = None
    # merged-but-unreleased output: windows are written incrementally
    # into PREALLOCATED host buffers (capacity = sum of input lens,
    # allocated once at ``_open_merge``) so ``_finish_merge`` binds the
    # finished table as O(1) views — no O(merge-size) concatenate
    buf_keys: Optional[np.ndarray] = None
    buf_vals: Optional[np.ndarray] = None
    # device accumulation (kernel windows only): the window outputs are
    # folded into a donated device buffer so the finished table adopts
    # device-resident arrays without a re-upload.  ``dev_ok`` drops to
    # False permanently once any window ran on the host path.
    dev_keys: Optional["jnp.ndarray"] = field(default=None, repr=False)
    dev_vals: Optional["jnp.ndarray"] = field(default=None, repr=False)
    dev_ok: bool = True
    emitted: int = 0           # post-dedup entries emitted so far
    tombs_in: int = 0          # input tombstones seen in consumed windows
                               # (counted per quantum: O(consumed), so the
                               # finish step never scans the inputs)
    # -- legacy one-shot state (``streaming_merge=False`` baseline) ----
    cursor: int = 0            # entries of the merged stream already emitted
    merged_keys: Optional[np.ndarray] = None
    merged_vals: Optional[np.ndarray] = None
    # owning tree (None on hand-built cursors: the group defaults to the
    # primary) — lets the GROUP dispatch advance/finish per merge, so
    # instance-level instrumentation on the engine sees every tree's
    # merges
    tree: Optional["LSMTree"] = field(default=None, repr=False)


def _identity_attr(vals: np.ndarray) -> np.ndarray:
    """Default index attribute: the value's low 32 bits as uint32."""
    return (np.asarray(vals).astype(np.int64)
            & 0xFFFFFFFF).astype(np.uint32)


@dataclass(frozen=True)
class IndexSpec:
    """Declaration of one secondary index (a sibling LSM tree).

    ``mode`` picks the maintenance strategy (``"eager"`` exact-at-all-
    times vs ``"lazy"`` blind-append + read validation — see the module
    docstring).  ``extract`` maps a value array (int32) to uint32
    attributes; ``None`` = the value's low 32 bits.  Tree knobs default
    to the primary's (``policy`` is shared — policies are stateless
    config — but each index tree gets its OWN ``FairScheduler`` unless
    one is given: schedulers may carry state)."""
    name: str
    mode: str = "eager"
    extract: Optional[Callable[[np.ndarray], np.ndarray]] = None
    policy: Optional[MergePolicy] = None
    scheduler: Optional[MergeScheduler] = None
    constraint: Optional[ComponentConstraint] = None
    memtable_entries: Optional[int] = None
    num_memtables: Optional[int] = None


@dataclass
class _IndexState:
    """Resolved runtime state of one index."""
    name: str
    mode: str
    extract: Callable[[np.ndarray], np.ndarray]
    tree_id: int


class LSMTree:
    """One LSM tree of a ``StorageGroup``: memtable plane, run levels,
    read view + filter stack, merge policy/scheduler/constraint, and the
    streaming-merge state of its running merges.  Cross-cutting concerns
    (backend, WAL, budget, lock, clock, faults) live on ``self.group``
    and are owned exactly once — see the module docstring."""

    def __init__(self, group: "StorageGroup", tree_id: int, name: str,
                 policy: MergePolicy, scheduler: MergeScheduler,
                 constraint: ComponentConstraint, memtable_entries: int,
                 num_memtables: int, unique_keys: float,
                 streaming_merge: bool):
        self.group = group
        self.tree_id = int(tree_id)
        self.name = name
        self.policy = policy
        self.scheduler = scheduler
        self.constraint = constraint
        self.memtable_entries = int(memtable_entries)
        self.num_memtables = int(num_memtables)
        self.unique_keys = unique_keys
        self.streaming_merge = bool(streaming_merge)
        self.meta = ComponentTree(unique_keys=unique_keys)  # scheduling-
                                                # plane model (policy input)
        self.active = MemTable(self.memtable_entries)
        self.active.start_lsn = group._lsn
        self.sealed: list[MemTable] = []
        self.tables: dict[int, SSTable] = {}     # component id -> SSTable
        self._order: list[SSTable] = []          # newest-first (see module
                                                 # docstring: insertion-
                                                 # maintained, no re-sort)
        self._fstack = _FilterStack()            # lazy device filter stack
        self._view: Optional[_ReadView] = None   # cached read view
        self._view_epoch = 0                     # bumped on invalidation
        self.running: dict[int, _RunningMerge] = {}
        self.pending_flush: list[tuple[np.ndarray, np.ndarray]] = []
        self._stamp = 0
        self.stalled = False
        self._flush_debt = 0             # flush-quantum overshoot owed
        self._live_keys: Optional[set] = None   # keys written since an
                                         # online-recovery reopen (the
                                         # replay step drops history for
                                         # them — live writes win)
        self.corrupt = False             # unrepairable corruption: reads
                                         # raise, never answer wrong
        self.stats = {"puts": 0, "stall_events": 0, "flushes": 0,
                      "merges": 0, "merge_bytes": 0, "merge_touched": 0,
                      "lookups": 0, "bloom_skips": 0,
                      "deletes": 0, "replayed": 0, "tombstones_dropped": 0,
                      "flush_bytes": 0, "logical_bytes": 0}

    # ------------------------------------------------------------ memtables
    def seal_active(self, next_start_lsn: Optional[int] = None) -> None:
        """Seal the active memtable (it becomes a flush candidate) and
        open a fresh one whose ``start_lsn`` is the group's current WAL
        position — the bookkeeping behind ``flushed_lsn``.  Group-
        internal admission paths that seal MID-chunk (``force_admit``)
        pass the LSN of the chunk's next entry instead, since the chunk
        was WAL-framed before any of it was admitted.

        During ONLINE recovery the new memtable's origin is capped by
        the replay watermark: the active memtable mixes live writes
        (LSN >= the live frontier) with replayed history (LSN < the
        watermark), so the only safe ``flushed_lsn`` claim is the
        watermark — snapshot truncation must never drop un-replayed
        WAL."""
        self.sealed.append(self.active)
        self.active = MemTable(self.memtable_entries)
        lsn = self.group._lsn if next_start_lsn is None \
            else int(next_start_lsn)
        wm = self.group._replay_watermark
        if wm is not None:
            lsn = min(lsn, wm)
        self.active.start_lsn = lsn

    def _refresh_stall(self):
        self.stalled = self.constraint.violated(self.meta)

    def force_admit(self, keys, vals, base_lsn: int) -> None:
        """Stall-free admission for group-internal writes (index
        maintenance): seals past ``num_memtables`` freely — the
        primary's admission gate already paced the batch, and the extra
        sealed memtables are background debt the next pump epochs repay.
        ``base_lsn`` is the chunk's WAL frame base (already logged), so
        a mid-chunk seal stamps the new memtable at the exact LSN of the
        first entry it will hold."""
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        n = len(keys)
        pos = 0
        while pos < n:
            if self.active.full:
                self.seal_active(next_start_lsn=base_lsn + pos)
            take = min(n - pos, self.active.capacity - len(self.active))
            took = self.active.put_batch(keys[pos:pos + take],
                                         vals[pos:pos + take])
            assert took == take, "memtable admitted less than its room"
            pos += take

    def replay_admit(self, keys, vals) -> int:
        """Recovery-only admission: entries already durable in the WAL
        re-enter the memtable plane WITHOUT re-logging and WITHOUT
        constraint stalls.  Callers size chunks to the active memtable's
        room and maintain the group's LSN clock (``RecoverySession``
        does both)."""
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        if self.active.full:
            self.seal_active()
        took = self.active.put_batch(keys, vals)
        assert took == len(keys), "replay chunk exceeded memtable room"
        self.stats["replayed"] += took
        return took

    # ------------------------------------------------------------------ read
    def _read_view(self) -> _ReadView:
        """The cached read view (see module docstring for the contract):
        an O(tables) snapshot of the insertion-maintained ``_order`` list
        — no sorting, no filter work (filters sync lazily in
        ``_view_filters``).  Epoch-guarded against the wall-clock driver:
        if a flush/merge invalidates mid-build, the snapshot serves this
        call but is NOT cached, so a stale view can never become
        sticky."""
        view = self._view
        if view is None:
            epoch = self._view_epoch
            view = _ReadView(tuple(self._order))
            if epoch == self._view_epoch:
                self._view = view
        return view

    def _view_filters(self, view: _ReadView):
        """Lazily apply the filter stack's pending add/remove journal
        (first point lookup after a background event pays O(rows
        changed); scans never call this).  Returns ``(filts, meta)`` —
        ``None``s when the bloom kernels are unavailable."""
        if view.filts is None and view.tables and set_stack_row is not None:
            view.filts, view.meta = self._fstack.sync(self._order)
        return view.filts, view.meta

    def _invalidate_view(self):
        self._view_epoch += 1
        self._view = None

    @staticmethod
    def _order_key(t: SSTable):
        """Newest-first rank of a table in the read view / merge order."""
        return (-t.data_stamp, t.component.level if t.component else 0)

    def get_batch_locked(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized newest-wins lookup over THIS tree (group lock
        held): memtables newest-first, then ONE fused Bloom probe across
        all disk tables, then sorted searches only for surviving
        (table, key) pairs with early exit.  Returns (found, values);
        tombstone hits resolve the key but report "not found"."""
        if self.corrupt:
            raise UnrepairableCorruptionError(
                f"tree {self.name!r} has unrepairable corruption — "
                "refusing to serve reads")
        q = len(keys)
        self.stats["lookups"] += q
        resolved = np.zeros(q, bool)
        vals = np.zeros(q, np.int32)
        for mt in (self.active, *reversed(self.sealed)):
            if resolved.all():
                break
            f, v = mt.get_batch(keys)
            new = f & ~resolved
            vals[new] = v[new]
            resolved |= new
        if not resolved.all():
            view = self._read_view()
            if view.tables:
                filts, meta = self._view_filters(view)
                if filts is not None:
                    # probe the full stack (capacity rows, <= 2x live
                    # tables); each table's row is its own stack_slot —
                    # no gather.  The backend picks host vs kernel; the
                    # host path probes the stack's host mirror.
                    probed = self.group.backend.probe_multi(
                        filts, meta, keys,
                        filts_host=self._fstack.filts_np)
                else:  # pragma: no cover - kernels unavailable
                    probed = None
                for table in view.tables:
                    pend = ~resolved
                    if not pend.any():
                        break
                    maybe_t = probed[table.stack_slot] \
                        if probed is not None else np.ones(q, bool)
                    cand = pend & maybe_t
                    self.stats["bloom_skips"] += int((pend & ~maybe_t).sum())
                    if not cand.any():
                        continue
                    idx = np.flatnonzero(cand)
                    f, v = table.search(keys[idx])
                    hit = idx[f]
                    vals[hit] = v[f]
                    resolved[hit] = True
        found = resolved & (vals != TOMBSTONE)
        vals = np.where(found, vals, 0).astype(np.int32)
        return found, vals

    def _scan_runs(self, lo: int, hi: int) -> list[tuple[np.ndarray,
                                                         np.ndarray]]:
        """Per-run ``[lo, hi)`` windows, NEWEST first (active memtable,
        sealed memtables newest-first, then the read view's tables) —
        the age order the k-way merge dedups by.  Empty windows are
        dropped."""
        if self.corrupt:
            raise UnrepairableCorruptionError(
                f"tree {self.name!r} has unrepairable corruption — "
                "refusing to serve scans")
        runs: list[tuple[np.ndarray, np.ndarray]] = []
        for mt in (self.active, *reversed(self.sealed)):
            ks, vs = mt.scan_range(lo, hi)
            if len(ks):
                runs.append((ks, vs))
        for table in self._read_view().tables:
            ks, vs = table.scan_range(lo, hi)
            if len(ks):
                runs.append((ks, vs))
        return runs

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """Newest-wins range scan over THIS tree: sorted (keys, values)
        for ``lo <= key < hi``, resolved across all live runs in one
        k-way merge.  The run-window snapshot runs under the group lock;
        the merge itself runs OUTSIDE it (the captured windows are
        immutable snapshots), so a large scan never extends the pump's
        lock-hold tail."""
        with self.group._rlock:
            runs = self._scan_runs(lo, hi)
        if not runs:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        if len(runs) == 1:
            # copy: the windows are views into live run storage (sealed
            # caches / host mirrors), which callers must not alias.
            # Tombstones are filtered like any other scan result.
            ks, vs = drop_tombstones(runs[0][0], runs[0][1])
            return ks.copy(), vs.copy()
        # the backend fuses tombstone filtering into its merge (kernel:
        # the compaction mask; host: drop_tombstones on the merged run)
        return self.group.backend.scan_merge(runs,
                                             drop_value=int(TOMBSTONE))

    # ------------------------------------------------------- background I/O
    def pump_tree(self, budget_entries: int) -> int:
        """Advance THIS tree's background work by its quantum of the
        group epoch (group lock held): repay flush-overshoot debt, then
        flushes at strict priority, then merges per the tree scheduler's
        allocation (largest-remainder apportionment, never exceeding the
        quantum).  Returns entries actually charged."""
        g = self.group
        spent = 0
        repay = min(self._flush_debt, budget_entries)
        self._flush_debt -= repay
        spent += repay
        while self.sealed and spent < budget_entries:
            g._fault("pre-flush")
            mt = self.sealed.pop(0)
            keys, vals = mt.seal()
            table = SSTable.build(keys, vals,
                                  level=self.policy.flush_target_level(),
                                  created_at=g.now,
                                  interpret=g.interpret)
            self._bind_table(table)
            self.stats["flushes"] += 1
            self.stats["flush_bytes"] += len(keys) * ENTRY_BYTES
            cost = len(keys)
            avail = budget_entries - spent
            if cost > avail:
                # atomic flush overshoot carried as debt (see pump)
                self._flush_debt += cost - avail
                spent = budget_entries
            else:
                spent += cost
            self._collect_merges()
        if spent >= budget_entries:
            return spent
        self._collect_merges()
        ops = [rm.op for rm in self.running.values()]
        alloc = self.scheduler.allocate(ops) if ops else {}
        remaining = budget_entries - spent
        shares = sorted((op_id, frac) for op_id, frac in alloc.items()
                        if frac > 0)
        if shares and remaining > 0:
            quanta = apportion_largest_remainder(shares, remaining)
            for (op_id, _), quantum in zip(shares, quanta):
                if quantum > 0:
                    # dispatch through the GROUP so instance-level
                    # instrumentation (tests wrap eng._advance_merge)
                    # sees every tree's merges
                    spent += g._advance_merge(self.running[op_id],
                                              quantum)
            assert spent <= budget_entries, \
                "merge quanta exceeded the pump budget"
        return spent

    def _bind_table(self, table: SSTable) -> None:
        """Register a freshly built run as this tree's NEWEST table:
        stamp it, enter it into the scheduling plane and the read plane
        (prepend to ``_order`` — O(1) rank — and journal the filter-stack
        add).  The flush path binds through here; benchmarks use it to
        inject preloaded runs with flush-identical semantics."""
        self._stamp += 1
        table.data_stamp = self._stamp
        table.component.stamp = float(self._stamp)
        table.seal_checksum()
        self.meta.add(table.component)
        self.tables[table.component.cid] = table
        self._order.insert(0, table)
        self._fstack.note_add(table)
        self._invalidate_view()

    def _collect_merges(self):
        for op in self.policy.collect_merges(self.meta, self.group.now):
            inputs = [self.tables[c.cid] for c in op.inputs]
            self.running[op.op_id] = _RunningMerge(op=op, inputs=inputs,
                                                   tree=self)

    def pending_entries(self) -> int:
        """This tree's background I/O debt in entries (group lock held):
        flush-quantum debt, sealed memtables awaiting flush, and the
        unconsumed inputs of every running merge.  The group's pump
        epoch apportions its budget across trees by this number."""
        self._collect_merges()
        pending = self._flush_debt + sum(len(m) for m in self.sealed)
        for rm in self.running.values():
            if rm.lens is not None:       # streaming cursor open
                pending += int((rm.lens - rm.cursors).sum())
            elif rm.merged_keys is not None:   # one-shot materialized
                pending += len(rm.merged_keys) - rm.cursor
            else:
                # unopened cursor: the inputs are the upper bound; a
                # zero-input op (hand-built test cursors) still counts
                # as live work so the group routes it budget
                pending += max(sum(len(t) for t in rm.inputs), 1)
        return pending

    # -- merge execution (the paper's unit of schedulable I/O) -------------
    def _open_merge(self, rm: _RunningMerge):
        """Set up the streaming cursor: sort inputs newest-first (the
        k-way age order — data_stamp is the data-age order; on equal
        stamps the LOWER level holds the newer version) and zero the
        per-run cursors.  No merged output is computed here: each quantum
        merges only its own window."""
        rm.tables = sorted(rm.inputs, key=self._order_key)
        rm.drop = self._tombstone_drop_safe(rm)
        hosts = [t._host() for t in rm.tables]
        rm.run_keys = [h[0] for h in hosts]
        rm.run_vals = [h[1] for h in hosts]
        rm.lens = np.array([len(k) for k in rm.run_keys], np.int64)
        rm.cursors = np.zeros(len(rm.tables), np.int64)
        # preallocate the output ONCE (dedup can only shrink it): each
        # quantum writes its window into the next buffer slice, and
        # ``_finish_merge`` binds ``buf[:emitted]`` views — the finish
        # step never concatenates or copies the merged output
        cap = int(rm.lens.sum())
        rm.buf_keys = np.empty(cap, np.uint32)
        rm.buf_vals = np.empty(cap, np.int32)

    def _tombstone_drop_safe(self, rm: _RunningMerge) -> bool:
        """May this merge reclaim tombstones?  Safe iff NO live table
        OLDER than the merge's output overlaps its key range — then a
        tombstone winner shadows nothing, so dropping it (and the data
        versions it already shadowed via dedup) changes no read.  Checked
        once at merge open against the authoritative ``_order``; tables
        born later are NEWER than the output, so the decision cannot be
        invalidated mid-merge."""
        in_cids = {t.component.cid for t in rm.inputs}
        out_key = (-max(t.data_stamp for t in rm.inputs),
                   rm.op.output_level)
        lo = min(t.component.key_lo for t in rm.inputs)
        hi = max(t.component.key_hi for t in rm.inputs)
        for t in self._order:
            if t.component.cid in in_cids:
                continue
            if self._order_key(t) > out_key and \
                    t.component.key_lo < hi and lo < t.component.key_hi:
                return False
        return True

    def _merge_cut(self, rm: _RunningMerge,
                   target: int) -> tuple[np.ndarray, int]:
        """The merge-path pivot: the largest key-boundary cut whose
        remaining input entries number at most ``target`` (binary search
        for the pivot key over the uint32 key space; per-run window ends
        via ``searchsorted`` on the host mirrors, so only O(k log n)
        entries are touched).  Cutting at a key boundary means no
        equal-key group straddles windows — per-window newest-wins dedup
        composes to the one-shot result.  When even the first key group
        exceeds ``target`` (up to k duplicates of one key), that group is
        taken whole as forced minimal progress: it emits exactly one
        entry.  Returns ``(stops, consumed)``."""
        cur, lens, ks = rm.cursors, rm.lens, rm.run_keys
        rem = int((lens - cur).sum())
        if rem <= target:
            return lens.copy(), rem

        def below(p: int) -> int:
            c = 0
            for i, k in enumerate(ks):
                if cur[i] < lens[i]:
                    c += max(0, int(np.searchsorted(k, np.uint32(p)))
                             - int(cur[i]))
            return c

        lo, hi = 0, 0xFFFFFFFF      # sentinel key never stored: p covers all
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if below(mid) <= target:
                lo = mid
            else:
                hi = mid - 1
        stops = np.array(
            [min(int(lens[i]),
                 max(int(cur[i]), int(np.searchsorted(ks[i],
                                                      np.uint32(lo)))))
             for i in range(len(ks))], np.int64)
        consumed = int((stops - cur).sum())
        if consumed == 0:
            # forced progress: the whole first key group (<= k entries)
            nxt = min(int(ks[i][cur[i]]) for i in range(len(ks))
                      if cur[i] < lens[i])
            stops = np.array(
                [min(int(lens[i]),
                     max(int(cur[i]),
                         int(np.searchsorted(ks[i], np.uint32(nxt),
                                             side="right"))))
                 for i in range(len(ks))], np.int64)
            consumed = int((stops - cur).sum())
        return stops, consumed

    def _advance_merge(self, rm: _RunningMerge, quantum: int) -> int:
        """Advance one merge by ~``quantum`` output entries: cut the next
        window at a global key boundary and merge ONLY that window, so
        the work (and lock-hold time) under a live ``BackgroundDriver``
        is O(quantum + k), never O(total merge size).  Emitted entries
        (post-dedup) are what the budget is charged for, matching the
        paper's written-bytes accounting; heavy dedup therefore spends
        less than the allocated quantum rather than overshooting it."""
        self.group._fault("mid-merge-quantum")
        if not self.streaming_merge:
            return self._advance_merge_oneshot(rm, quantum)
        if rm.tables is None:
            self._open_merge(rm)
        if int((rm.lens - rm.cursors).sum()) == 0:
            self.group._finish_merge(rm)
            return 0
        starts = rm.cursors
        stops, consumed = self._merge_cut(rm, quantum)
        drop = int(TOMBSTONE) if rm.drop else None
        if rm.drop:
            # count reclaimed markers window-by-window (O(consumed)) so
            # ``_finish_merge`` never re-scans the full inputs
            rm.tombs_in += sum(
                int((rm.run_vals[i][starts[i]:stops[i]]
                     == TOMBSTONE).sum())
                for i in range(len(rm.tables)))
        wk, wv, dev = self.group.backend.merge_kway_window(
            list(zip(rm.run_keys, rm.run_vals)),
            starts.tolist(), stops.tolist(), drop_value=drop,
            runs_dev=lambda: [(t.keys, t.vals) for t in rm.tables])
        take = len(wk)
        assert take <= max(quantum, 1), "window emitted beyond its quantum"
        rm.cursors = stops
        rm.buf_keys[rm.emitted:rm.emitted + take] = wk
        rm.buf_vals[rm.emitted:rm.emitted + take] = wv
        self._accumulate_device(rm, dev, take)
        rm.emitted += take
        rm.op.written += take
        self.stats["merge_bytes"] += take * ENTRY_BYTES
        self.stats["merge_touched"] += consumed
        if int((rm.lens - rm.cursors).sum()) == 0:
            self.group._finish_merge(rm)
        return take

    def _accumulate_device(self, rm: _RunningMerge, dev, take: int) -> None:
        """Fold a kernel window's device-resident output into the merge's
        device accumulation buffer (allocated lazily at 2x output
        capacity so a pow2-padded window never clamps over earlier data;
        the pad tail is overwritten by the next window or sliced off at
        finish).  One host-mode window drops the buffer for good — the
        finished table then falls back to lazy upload on first kernel
        use, which is exactly what a host-merged table wants anyway."""
        if not rm.dev_ok:
            return
        if dev is None or _write_window is None:
            rm.dev_keys = rm.dev_vals = None
            rm.dev_ok = False
            return
        if take == 0:
            return
        if rm.dev_keys is None:
            cap = 2 * max(int(rm.lens.sum()), 1)
            rm.dev_keys = jnp.zeros(cap, jnp.uint32)
            rm.dev_vals = jnp.zeros(cap, jnp.int32)
        dk, dv = dev
        pad = _next_pow2(take) - take
        if pad:
            dk = jnp.pad(dk, (0, pad))
            dv = jnp.pad(dv, (0, pad))
        rm.dev_keys = _write_window(rm.dev_keys, dk, rm.emitted)
        rm.dev_vals = _write_window(rm.dev_vals, dv, rm.emitted)

    def _materialize_merge(self, rm: _RunningMerge):
        """LEGACY one-shot path (``streaming_merge=False``; kept as the
        measured baseline in ``benchmarks/latency_tail.py`` and the
        streaming differential tests): compute the full merged run at the
        first quantum — an unbounded compute spike under the engine lock,
        which is exactly the cliff the streaming cursor removes."""
        self.stats["merge_touched"] += sum(len(t) for t in rm.inputs)
        tables = sorted(rm.inputs, key=self._order_key)
        rm.drop = self._tombstone_drop_safe(rm)
        drop = int(TOMBSTONE) if rm.drop else None
        if rm.drop:
            rm.tombs_in = sum(int((t._host()[1] == TOMBSTONE).sum())
                              for t in rm.inputs)
        mk, mv, _ = self.group.backend.merge_kway(
            [t._host() for t in tables], drop_value=drop,
            runs_dev=lambda: [(t.keys, t.vals) for t in tables])
        rm.merged_keys, rm.merged_vals = mk, mv

    def _advance_merge_oneshot(self, rm: _RunningMerge, quantum: int) -> int:
        if rm.merged_keys is None:
            self._materialize_merge(rm)
        total = len(rm.merged_keys)
        take = min(quantum, total - rm.cursor)
        if take > 0:
            # the merged run is already materialized whole; the cursor
            # only paces budget charging — finish binds it directly
            rm.cursor += take
            rm.op.written += take
            self.stats["merge_bytes"] += take * ENTRY_BYTES
        if rm.cursor >= total:
            self.group._finish_merge(rm)
        return max(take, 0)

    def _finish_merge(self, rm: _RunningMerge):
        # O(1) output binding: the streaming path binds VIEWS into the
        # preallocated buffers (no concatenate, no copy — pinned in
        # tests/test_backend.py); the one-shot baseline binds its
        # materialized arrays directly.
        if rm.buf_keys is not None:
            keys = rm.buf_keys[:rm.emitted]
            vals = rm.buf_vals[:rm.emitted]
        elif rm.merged_keys is not None:
            keys, vals = rm.merged_keys, rm.merged_vals
        else:  # finished before any quantum ran (all-empty inputs)
            keys = np.empty(0, np.uint32)
            vals = np.empty(0, np.int32)
        dev_pair = None
        if rm.dev_ok and rm.dev_keys is not None:
            # ONE device slice binds the accumulated kernel output — the
            # finished table adopts it, so the merge→flush→probe plane
            # never re-uploads what a kernel already produced on device
            dev_pair = (rm.dev_keys[:rm.emitted],
                        rm.dev_vals[:rm.emitted])
        stamp = max(t.data_stamp for t in rm.inputs)
        if rm.drop:
            # every input tombstone died here: winners to the drop mask,
            # shadowed ones to dedup — the count was accumulated window-
            # by-window (O(consumed) per quantum, never an input re-scan)
            self.stats["tombstones_dropped"] += rm.tombs_in
        # keep the policy's metadata model in sync with the real output size
        rm.op.output_size = float(len(keys))
        rm.op.written = float(len(keys))
        in_cids = {c.cid for c in rm.op.inputs}
        for cid in in_cids:
            self.tables.pop(cid, None)
            self._fstack.note_remove(cid)
        self._order = [t for t in self._order
                       if t.component.cid not in in_cids]
        outs = self.policy.complete_merge(self.meta, rm.op, self.group.now)
        # partitioned policies may split the output into several files
        def _bind(comp, ks, vs, dev=None):
            table = SSTable.build(ks, vs, level=comp.level,
                                  created_at=self.group.now,
                                  interpret=self.group.interpret, dev=dev)
            table.component = comp
            table.data_stamp = stamp
            comp.stamp = float(stamp)
            # keep the scheduling-plane range metadata honest: the policy's
            # overlap selection must see the REAL key span, else adjacent-
            # level overlaps are missed and newest-wins breaks.  An empty
            # output file spans nothing — an empty range keeps its stale
            # stamp from shadowing future merges in the policy's
            # age-safety audit.
            if len(ks):
                comp.key_lo = float(ks[0]) / 2**32
                comp.key_hi = (float(ks[-1]) + 1) / 2**32
            else:
                comp.key_lo = comp.key_hi = 0.0
            table.seal_checksum()
            self.tables[comp.cid] = table

        if len(outs) == 1:
            _bind(outs[0], keys, vals, dev_pair)
        else:
            # contiguous slice VIEWS at np.array_split's boundaries (the
            # historical split), not index-gather copies; the device
            # accumulation (when live) splits at the same boundaries
            n = max(len(outs), 1)
            sizes = np.full(n, len(keys) // n, np.int64)
            sizes[:len(keys) % n] += 1
            bounds = np.concatenate([[0], np.cumsum(sizes)])
            for j, comp in enumerate(outs):
                lo, hi = int(bounds[j]), int(bounds[j + 1])
                dv = (dev_pair[0][lo:hi], dev_pair[1][lo:hi]) \
                    if dev_pair is not None else None
                _bind(comp, keys[lo:hi], vals[lo:hi], dv)
        # bisect-insert the outputs at their (-stamp, level) rank: all
        # outputs of one merge share the rank (same stamp, same level)
        # and hold disjoint key ranges, so inserting them adjacently
        # keeps the newest-first order without a full re-sort
        out_tables = [self.tables[c.cid] for c in outs]
        if out_tables:          # a policy may complete a merge to nothing
            pos = bisect.bisect_left(self._order,
                                     self._order_key(out_tables[0]),
                                     key=self._order_key)
            self._order[pos:pos] = out_tables
        for t in out_tables:
            self._fstack.note_add(t)
        self.running.pop(rm.op.op_id, None)
        self._invalidate_view()
        self.stats["merges"] += 1
        self._collect_merges()

    # ---------------------------------------------------- recovery / info
    @property
    def flushed_lsn(self) -> int:
        """First LSN NOT yet captured in THIS tree's on-disk SSTables.
        Memtables are flushed FIFO and filled in LSN order, so everything
        of this tree below the oldest unflushed memtable's ``start_lsn``
        lives in its SSTables (other trees' entries in that range are
        THEIR problem — the group's replay origin is the min over
        trees)."""
        return self.sealed[0].start_lsn if self.sealed \
            else self.active.start_lsn

    def restore_tables(self, tables, snap: dict) -> int:
        """Rebuild this tree's read view from its snapshot section (the
        recovery path): re-bind each saved run at its recorded
        (stamp, level) rank — ``_order`` re-sorts once, the filter stack
        rebuilds lazily on the first probe.  Returns the section's
        ``flushed_lsn`` (this tree's WAL replay origin)."""
        for keys, vals, tmeta in tables:
            t = SSTable.build(keys, vals, level=int(tmeta["level"]),
                              created_at=float(tmeta["created_at"]),
                              interpret=self.group.interpret)
            t.data_stamp = int(tmeta["stamp"])
            t.component.stamp = float(tmeta["stamp"])
            t.seal_checksum()
            self.meta.add(t.component)
            self.tables[t.component.cid] = t
            self._order.append(t)
        self._order.sort(key=self._order_key)
        self._stamp = max(self._stamp, int(snap.get("stamp", 0)),
                          max((t.data_stamp for t in self._order),
                              default=0))
        self._invalidate_view()
        return int(snap.get("flushed_lsn", 0))

    def start_full_merge(self) -> bool:
        """Queue ONE merge of every live table to the deepest level (the
        ``compact_all`` step; group lock held).  Returns False when there
        is nothing to compact (<= 1 run, no tombstones)."""
        live = list(self._order)
        if not live:
            return False
        if len(live) == 1 and \
                int((live[0]._host()[1] == TOMBSTONE).sum()) == 0:
            return False            # already one run with nothing to drop
        comps = [t.component for t in live]
        op = MergeOp(inputs=comps,
                     output_level=max(self.meta.max_level(),
                                      max(c.level for c in comps)),
                     output_size=float(sum(len(t) for t in live)))
        self.running[op.op_id] = _RunningMerge(op=op, inputs=live,
                                               tree=self)
        return True

    def total_entries(self) -> int:
        return sum(len(t) for t in self.tables.values()) + \
            sum(len(m) for m in self.sealed) + len(self.active)

    def num_components(self) -> int:
        return self.meta.num_components()

    def live_entries(self) -> int:
        """Distinct keys whose newest version is NOT a tombstone — this
        tree's logical data size behind ``space_amp`` (an O(n) full-range
        scan)."""
        return int(len(self.scan_range(0, 0xFFFFFFFF)[0]))

    _merge_kway_host = staticmethod(merge_kway_host)


# canonical stats key order (the legacy engine's dict order)
_STATS_ORDER = ("puts", "stall_events", "flushes", "merges", "merge_bytes",
                "merge_touched", "lookups", "bloom_skips", "deletes",
                "replayed", "tombstones_dropped", "wal_entries", "wal_bytes",
                "wal_syncs", "flush_bytes", "logical_bytes")


class StorageGroup:
    """N LSM trees (one primary + one per secondary index) sharing ONE
    I/O plane: backend, WAL, budget, lock, clock, snapshots, recovery
    (see the module docstring for the ownership split).  With no
    indexes this IS the legacy single-tree engine — every legacy
    attribute/method delegates to the primary tree bit-identically —
    and ``LSMEngine`` is exactly that instantiation."""

    def __init__(self, policy: MergePolicy, scheduler: MergeScheduler,
                 constraint: ComponentConstraint | None = None,
                 memtable_entries: int = 4096, num_memtables: int = 2,
                 unique_keys: float = 1e6, use_kernels: bool = True,
                 merge_block: int = 256, interpret: bool = True,
                 scan_use_kernels: Optional[bool] = None,
                 streaming_merge: bool = True,
                 wal=None, group_commit_entries: int = 512,
                 wal_sync_cost: int = 32, faults=None,
                 backend: "ExecBackend | str | None" = None,
                 indexes=()):
        # -- durability plane (group-owned) ----------------------------
        self.wal = wal                           # WriteAheadLog | None
        self.group_commit_entries = int(group_commit_entries)
        self.wal_sync_cost = int(wal_sync_cost)  # fixed fsync charge
                                                 # (entries of budget)
        self.faults = faults                     # FaultInjector | None
        self._lsn = wal.end_lsn if wal is not None else 0
        self._wal_debt = 0                       # synced-WAL budget owed
        self._wal_stats = {"wal_entries": 0, "wal_bytes": 0, "wal_syncs": 0}
        # -- fault-tolerance plane -------------------------------------
        self._recovery = None            # active ONLINE RecoverySession
        self._replay_watermark = None    # durable replay frontier while
                                         # recovering (None = steady state)
        self.scrubber = None             # background integrity scrub
                                         # (``enable_scrub``)
        self._health = {"enospc_stalls": 0}
        # -- execution backend (group-owned): every kernel-vs-host
        # decision lives here.  The three legacy booleans map to a
        # forced-dispatch backend reproducing the old behavior exactly.
        if backend is None:
            backend = ExecBackend.from_legacy(
                use_kernels=use_kernels, interpret=interpret,
                scan_use_kernels=scan_use_kernels,
                merge_block=merge_block)
        elif isinstance(backend, str):
            backend = ExecBackend(mode=backend, merge_block=merge_block,
                                  interpret=interpret)
        self.backend = backend
        self.merge_block = int(backend.merge_block)
        self.streaming_merge = bool(streaming_merge)
        self._rlock = threading.RLock()
        self.now = 0.0
        self._recorder = None            # optional WriteTraceRecorder
        self.trees: list[LSMTree] = [
            LSMTree(self, 0, "primary", policy, scheduler,
                    constraint or NoConstraint(), int(memtable_entries),
                    int(num_memtables), unique_keys,
                    self.streaming_merge)]
        self._indexes: dict[str, _IndexState] = {}
        self._eager = False
        for spec in indexes:
            self.add_index(spec)

    # ----------------------------------------------------------- indexes
    def add_index(self, spec: "IndexSpec | str") -> None:
        """Declare a secondary index as a sibling tree.  Must run before
        any write is admitted — indexes are not backfilled."""
        if isinstance(spec, str):
            spec = IndexSpec(spec)
        if spec.mode not in ("eager", "lazy"):
            raise ValueError(f"unknown index mode {spec.mode!r}")
        if spec.name in self._indexes:
            raise ValueError(f"duplicate index {spec.name!r}")
        primary = self.trees[0]
        if len(primary.active) or primary.sealed or primary.tables:
            raise ValueError("indexes must be declared before any write "
                             "(no backfill)")
        tree = LSMTree(
            self, len(self.trees), spec.name,
            spec.policy or primary.policy,
            spec.scheduler or FairScheduler(),
            spec.constraint or NoConstraint(),
            spec.memtable_entries or primary.memtable_entries,
            spec.num_memtables or primary.num_memtables,
            primary.unique_keys, self.streaming_merge)
        self.trees.append(tree)
        self._indexes[spec.name] = _IndexState(
            name=spec.name, mode=spec.mode,
            extract=spec.extract or _identity_attr,
            tree_id=tree.tree_id)
        self._eager = self._eager or spec.mode == "eager"

    @property
    def index_names(self) -> tuple:
        return tuple(self._indexes)

    # ----------------------------------------------------------- backend
    def set_backend(self, backend: "ExecBackend | str") -> None:
        """Swap the execution backend (the fleet plumbs ONE shared
        backend to every shard through here).  Takes an ``ExecBackend``
        or a mode string (``"auto"``/``"host"``/``"interpret"``/
        ``"compiled"``)."""
        if isinstance(backend, str):
            backend = ExecBackend(mode=backend,
                                  merge_block=self.merge_block)
        with self._rlock:
            self.backend = backend
            self.merge_block = int(backend.merge_block)

    # Legacy dispatch flags, now READ-ONLY views of the backend's
    # configuration (no engine code branches on them anymore; they are
    # kept for callers/tests that introspect the dispatch discipline).
    @property
    def use_kernels(self) -> bool:
        lk = self.backend.legacy_use_kernels
        if lk is not None:
            return lk
        return self.backend.decide("merge_kway", 1 << 20) != "host"

    @property
    def interpret(self) -> bool:
        return self.backend.interpret

    @property
    def scan_use_kernels(self) -> bool:
        lk = self.backend.legacy_scan_use_kernels
        if lk is not None:
            return lk
        return self.backend.decide("scan_merge", 1 << 20) != "host"

    # -------------------------------------------------------- fault hooks
    def _fault(self, point: str) -> None:
        """Hit a named crash point (no-op without an injector)."""
        if self.faults is not None:
            self.faults.hit(point)

    def attach_write_recorder(self, recorder) -> None:
        """Attach a ``metrics.WriteTraceRecorder`` (or None to detach).
        The write path then reports (admitted, offered) ONCE per
        ``put``/``put_batch`` call — per-batch timestamping, so tracing
        costs one branch and the hot path stays vectorized."""
        self._recorder = recorder

    # ------------------------------------------------------------------ write
    def put(self, key: int, value: int) -> bool:
        """Returns False when the write must stall (component constraint
        or no free primary memtable slot) — the caller decides to
        retry/queue."""
        if np.int32(value) == TOMBSTONE:
            raise ValueError("value -2**31 is reserved (delete tombstone)")
        with self._rlock:
            return self._put_batch_locked(np.array([key], np.uint32),
                                          np.array([value], np.int32)) == 1

    def put_batch(self, keys, values) -> int:
        """Bulk admission: admit entries in numpy-slice chunks sized to
        the primary memtable's room, computing the seal/stall boundary
        once per chunk.  Returns the count accepted before the first
        stall.  Each admitted chunk triggers index maintenance (eager:
        old-value probe + stale tombstone + insert; lazy: blind append)
        before the next chunk is considered."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        if (values == TOMBSTONE).any():
            raise ValueError("value -2**31 is reserved (delete tombstone)")
        with self._rlock:
            return self._put_batch_locked(keys, values)

    def delete(self, key: int) -> bool:
        """Blind delete: admit a TOMBSTONE for ``key`` through the
        ordinary write path (WAL-logged, stall-checked).  Returns False
        when the write must stall — True says the delete was ADMITTED,
        not that the key existed.  Eager indexes get the stale entry
        tombstoned (which makes the delete non-blind for them: the old
        value IS looked up); lazy indexes rely on read validation."""
        return self.delete_batch(np.array([key], np.uint32)) == 1

    def delete_batch(self, keys) -> int:
        """Bulk blind deletes: ``put_batch`` semantics (admit until the
        first stall, returns the admitted count), writing TOMBSTONE
        values."""
        keys = np.asarray(keys, np.uint32)
        vals = np.full(len(keys), TOMBSTONE, np.int32)
        with self._rlock:
            return self._put_batch_locked(keys, vals, deletes=True)

    def _put_batch_locked(self, keys, values, deletes: bool = False) -> int:
        primary = self.trees[0]
        n = len(keys)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        if self._indexes and n and int(keys.max()) >= 2 ** 31:
            raise ValueError("indexed groups require primary keys < 2**31 "
                             "(the key is stored as the index value, int32)")
        n_ok = 0
        while n_ok < n:
            if self._recovery is not None and self._indexes:
                # online recovery cannot maintain secondary indexes
                # consistently mid-replay (no live-key tracking for
                # lazily-validated index trees): stall until caught up
                primary.stats["stall_events"] += 1
                break
            primary._refresh_stall()
            if primary.stalled:
                # a constraint-induced rejection IS a stall event: the
                # paper's stall accounting charges the writer whenever
                # the write path refuses work, whichever side refused it
                primary.stats["stall_events"] += 1
                break
            if primary.active.full:
                if len(primary.sealed) >= primary.num_memtables - 1:
                    primary.stats["stall_events"] += 1
                    break
                primary.seal_active()
            # chunk size is known up front (memtable room), so the WAL
            # frame and the memtable admission carry identical entries —
            # the LSN == admission-index invariant recovery relies on
            take = min(n - n_ok,
                       primary.active.capacity - len(primary.active))
            chunk_k = keys[n_ok:n_ok + take]
            chunk_v = values[n_ok:n_ok + take]
            old_found = old_vals = None
            if self._eager:
                # resolve OLD values BEFORE the chunk lands: real point
                # lookups through the fused probe (charged to the
                # primary's lookup stats — eager maintenance pays reads)
                old_found, old_vals = self._chunk_old_values(
                    chunk_k, chunk_v, deletes)
            try:
                self._wal_log(0, chunk_k, chunk_v)
            except StorageFull:
                # out of space: the write path refuses work (a stall,
                # not data loss) until space returns and drains it
                primary.stats["stall_events"] += 1
                self._health["enospc_stalls"] += 1
                break
            took = primary.active.put_batch(chunk_k, chunk_v)
            assert took == take, "memtable admitted less than its room"
            n_ok += took
            if self._recovery is not None and \
                    primary._live_keys is not None:
                # live writes win: replay must drop these keys' history
                primary._live_keys.update(chunk_k.tolist())
            primary.stats["deletes" if deletes else "puts"] += took
            if self._indexes:
                self._fault("post-primary-pre-index")
                self._maintain_indexes(chunk_k, chunk_v, deletes,
                                       old_found, old_vals)
        primary.stats["logical_bytes"] += n_ok * ENTRY_BYTES
        if self._recorder is not None and n > 0:
            self._recorder.on_puts(n_ok, n)
        return n_ok

    def _chunk_old_values(self, ck, cv, deletes: bool):
        """Pre-admission old values for one chunk (eager maintenance):
        first occurrences of each key probe the primary (one batched
        fused-probe lookup); later intra-chunk occurrences take the
        previous occurrence's NEW value in chunk order — exactly what a
        per-entry sequential maintainer would have seen."""
        primary = self.trees[0]
        n = len(ck)
        order = np.argsort(ck, kind="stable")
        sk = ck[order]
        same = np.zeros(n, bool)
        if n > 1:
            same[1:] = sk[1:] == sk[:-1]
        dup_pos = np.flatnonzero(same)
        dup = np.zeros(n, bool)
        dup[order[dup_pos]] = True
        firsts = np.flatnonzero(~dup)
        old_found = np.zeros(n, bool)
        old_vals = np.zeros(n, np.int32)
        if len(firsts):
            pf, pv = primary.get_batch_locked(ck[firsts])
            old_found[firsts] = pf
            old_vals[firsts] = pv
        if len(dup_pos):
            src = order[dup_pos - 1]     # previous occurrence, chunk order
            dst = order[dup_pos]
            if deletes:
                old_found[dst] = False   # already deleted by the earlier
                                         # entry of this chunk
            else:
                old_found[dst] = True
                old_vals[dst] = cv[src]
        return old_found, old_vals

    @staticmethod
    def _check_attrs(attrs: np.ndarray, name: str) -> None:
        if (attrs == SENTINEL_KEY).any():
            raise ValueError(f"index {name!r}: attribute 2**32-1 is "
                             "reserved (pick an extract that avoids it)")

    def _maintain_indexes(self, ck, cv, deletes: bool,
                          old_found, old_vals) -> None:
        """Apply one admitted primary chunk to every index tree.  Eager:
        the NET index mutation of the chunk is computed sequentially
        (insertion-ordered stale-deletes and inserts, later entries
        overriding earlier ones exactly like a per-entry maintainer),
        then admitted as one tombstone frame + one insert frame — frame
        order makes newest-wins resolve del-then-add correctly.  Lazy:
        one blind ``attr -> pk`` frame per put chunk, nothing on
        deletes."""
        pks = ck.astype(np.int32)
        for st in self._indexes.values():
            tree = self.trees[st.tree_id]
            if st.mode == "lazy":
                if deletes:
                    continue
                attrs = np.asarray(st.extract(cv), np.uint32)
                self._check_attrs(attrs, st.name)
                base = self._wal_log(st.tree_id, attrs, pks)
                tree.force_admit(attrs, pks, base)
                tree.stats["puts"] += len(attrs)
                continue
            new_attrs = None
            if not deletes:
                new_attrs = np.asarray(st.extract(cv), np.uint32)
                self._check_attrs(new_attrs, st.name)
            old_attrs = np.asarray(st.extract(old_vals), np.uint32)
            dels: dict[int, None] = {}
            adds: dict[int, int] = {}
            for i in range(len(ck)):
                a_old = int(old_attrs[i]) if old_found[i] else None
                if deletes:
                    if a_old is not None:
                        adds.pop(a_old, None)
                        dels[a_old] = None
                else:
                    a_new = int(new_attrs[i])
                    if a_old is not None and a_old != a_new:
                        adds.pop(a_old, None)
                        dels[a_old] = None
                    adds[a_new] = int(pks[i])
            if dels:
                dk = np.fromiter(dels.keys(), np.uint32, len(dels))
                dv = np.full(len(dels), TOMBSTONE, np.int32)
                base = self._wal_log(st.tree_id, dk, dv)
                tree.force_admit(dk, dv, base)
                tree.stats["deletes"] += len(dels)
            if adds:
                ak = np.fromiter(adds.keys(), np.uint32, len(adds))
                av = np.fromiter(adds.values(), np.int64,
                                 len(adds)).astype(np.int32)
                base = self._wal_log(st.tree_id, ak, av)
                tree.force_admit(ak, av, base)
                tree.stats["puts"] += len(adds)

    # ------------------------------------------------------------- WAL
    def _wal_log(self, tree: int, keys, vals) -> int:
        """Append one admitted chunk as one tree-tagged WAL frame (the
        group-commit unit) BEFORE memtable admission, hit the
        ack-unknown crash point, and group-commit when enough entries
        accumulated.  Returns the frame's base LSN (the global clock
        advances even without a WAL)."""
        base = self._lsn
        if self.wal is None:
            self._lsn += len(keys)
            return base
        base = self.wal.append(keys, vals, tree=tree)
        self._lsn = self.wal.end_lsn
        self._wal_stats["wal_entries"] += len(keys)
        self._fault("post-wal-pre-memtable")
        if self.wal.unsynced_entries >= self.group_commit_entries:
            self._wal_sync()
        return base

    def _wal_sync(self) -> None:
        """fsync the WAL and charge the synced traffic (entries plus the
        fixed ``wal_sync_cost`` seek charge) to the group's WAL debt —
        repaid from pump budget before ANY tree's flushes/merges, so
        durability I/O competes with compaction for the configured
        bandwidth."""
        if self.wal is None:
            return
        n = self.wal.unsynced_entries
        if n <= 0:
            return
        self.wal.sync()
        self._wal_debt += n + self.wal_sync_cost
        self._wal_stats["wal_bytes"] += n * ENTRY_BYTES
        self._wal_stats["wal_syncs"] += 1

    # ------------------------------------------------------------------ read
    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Primary-tree point reads (see ``LSMTree.get_batch_locked``):
        one fused multi-table Bloom probe behind a newest-first walk with
        early exit.  Thread-safe under the group lock."""
        keys = np.asarray(keys, np.uint32)
        with self._rlock:
            return self.trees[0].get_batch_locked(keys)

    def _get_batch_locked(self, keys):
        return self.trees[0].get_batch_locked(keys)

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """Primary-tree newest-wins range scan (one k-way merge)."""
        return self.trees[0].scan_range(lo, hi)

    def scan_runs(self, lo: int, hi: int) -> list[tuple[np.ndarray,
                                                        np.ndarray]]:
        """Locked snapshot of the primary tree's per-run ``[lo, hi)``
        windows, newest first, merge NOT applied — the fleet router
        gathers these across shards into ONE flat k-way merge.  The
        returned windows may alias live storage: callers must not write
        through them."""
        with self._rlock:
            return self.trees[0]._scan_runs(lo, hi)

    def scan_range_dict(self, lo: int, hi: int) -> dict[int, int]:
        """Dict-compat wrapper over ``scan_range`` (the seed's contract)."""
        ks, vs = self.scan_range(lo, hi)
        return dict(zip(ks.tolist(), vs.tolist()))

    # --------------------------------------------------------- index reads
    def index_lookup(self, name: str,
                     attrs) -> tuple[np.ndarray, np.ndarray]:
        """Attribute -> primary-key lookup through the index tree.
        Returns ``(found, pks)`` (pks as uint32 keys).  Eager indexes
        answer from the index tree alone (it is exact); lazy indexes
        VALIDATE every candidate against the primary — the entry counts
        only if the primary's current value still maps to the queried
        attribute."""
        st = self._indexes[name]
        attrs = np.asarray(attrs, np.uint32)
        with self._rlock:
            tree = self.trees[st.tree_id]
            found, pk_vals = tree.get_batch_locked(attrs)
            found = found.copy()
            if st.mode == "lazy" and found.any():
                idx = np.flatnonzero(found)
                pf, pv = self.trees[0].get_batch_locked(
                    pk_vals[idx].astype(np.uint32))
                valid = pf & (np.asarray(st.extract(pv), np.uint32)
                              == attrs[idx])
                found[idx] = valid
            pks = np.where(found, pk_vals, 0).astype(np.int32)
        return found, pks.astype(np.uint32)

    def get_by_index(self, name: str,
                     attrs) -> tuple[np.ndarray, np.ndarray]:
        """Index-to-primary point read: resolve attributes to primary
        keys, then fetch the primary VALUES.  Returns ``(found,
        values)``."""
        with self._rlock:
            found, pks = self.index_lookup(name, attrs)
            vals = np.zeros(len(found), np.int32)
            idx = np.flatnonzero(found)
            if idx.size:
                pf, pv = self.trees[0].get_batch_locked(pks[idx])
                found = found.copy()
                found[idx] = pf
                vals[idx] = pv
        return found, vals

    def index_scan(self, name: str, lo: int,
                   hi: int) -> tuple[np.ndarray, np.ndarray]:
        """Attribute-range scan ``lo <= attr < hi`` over the index tree.
        Returns sorted ``(attrs, pks)``.  For an EAGER index this is a
        COVERING scan — one k-way merge over the index tree, no primary
        access.  A LAZY index validates every scanned entry against the
        primary (batched)."""
        st = self._indexes[name]
        tree = self.trees[st.tree_id]
        with self._rlock:
            attrs, pk_vals = tree.scan_range(lo, hi)
            if st.mode == "lazy" and len(attrs):
                pf, pv = self.trees[0].get_batch_locked(
                    pk_vals.astype(np.uint32))
                keep = pf & (np.asarray(st.extract(pv), np.uint32) == attrs)
                attrs, pk_vals = attrs[keep], pk_vals[keep]
        return attrs, pk_vals.astype(np.uint32)

    # ------------------------------------------------------- background I/O
    def pump(self, budget_entries: int) -> int:
        """Advance background work by ``budget_entries`` of write I/O —
        one group epoch: sync the WAL and repay its debt first, then
        split the remainder ACROSS TREES by background debt
        (largest-remainder apportionment); each tree spends its quantum
        on flushes (strict priority) then merges per its scheduler.
        Returns entries actually charged."""
        with self._rlock:
            return self._pump_locked(budget_entries)

    def _pump_locked(self, budget_entries: int) -> int:
        spent = 0
        self.now += 1.0
        # every pump is an fsync-epoch boundary: sync the WAL first so
        # its traffic lands in the group debt and is repaid below, ahead
        # of every tree — durability shares the bandwidth budget
        try:
            self._wal_sync()
        except StorageFull:
            self._health["enospc_stalls"] += 1
        repay = min(self._wal_debt, budget_entries)
        self._wal_debt -= repay
        spent += repay
        remaining = budget_entries - spent
        if remaining > 0 and self.scrubber is not None:
            spent += self.scrubber.step(
                min(remaining, self.scrubber.entries_per_epoch))
            remaining = budget_entries - spent
        if remaining > 0:
            rec = self._recovery
            debts = []
            if rec is not None and not rec.done:
                # replay debt competes with flush/merge debt for the
                # same budget — the arbiter sees it as one more stream
                debts.append((-1, rec.remaining))
            for t in self.trees:
                d = t.pending_entries()
                if d > 0:
                    debts.append((t.tree_id, d))
            if len(debts) == 1:
                tid = debts[0][0]
                spent += rec._replay_step(remaining) if tid == -1 \
                    else self.trees[tid].pump_tree(remaining)
            elif debts:
                total = float(sum(d for _, d in debts))
                quanta = apportion_largest_remainder(
                    [(tid, d / total) for tid, d in debts], remaining)
                for (tid, _), q in zip(debts, quanta):
                    if q <= 0:
                        continue
                    spent += rec._replay_step(q) if tid == -1 \
                        else self.trees[tid].pump_tree(q)
        for t in self.trees:
            t._refresh_stall()
        return spent

    def drain(self, budget_entries: int = 1 << 30, max_pumps: int = 10_000):
        """Pump until no background work remains on ANY tree
        (tests/shutdown)."""
        with self._rlock:
            for _ in range(max_pumps):
                busy = False
                for t in self.trees:
                    t._collect_merges()
                    busy = busy or t.sealed or t.running
                if not busy:
                    break
                self.pump(budget_entries)

    # --------------------------------------------- legacy engine surface
    # (the 1-tree API every existing caller uses: delegates to the
    # primary tree / sums over trees — bit-identical for one tree)
    @property
    def policy(self) -> MergePolicy:
        return self.trees[0].policy

    @policy.setter
    def policy(self, p: MergePolicy) -> None:
        self.trees[0].policy = p

    @property
    def scheduler(self) -> MergeScheduler:
        return self.trees[0].scheduler

    @scheduler.setter
    def scheduler(self, s: MergeScheduler) -> None:
        self.trees[0].scheduler = s

    @property
    def constraint(self) -> ComponentConstraint:
        return self.trees[0].constraint

    @constraint.setter
    def constraint(self, c: ComponentConstraint) -> None:
        self.trees[0].constraint = c

    @property
    def tree(self) -> ComponentTree:
        """The PRIMARY tree's scheduling-plane model (legacy name)."""
        return self.trees[0].meta

    @property
    def memtable_entries(self) -> int:
        return self.trees[0].memtable_entries

    @property
    def num_memtables(self) -> int:
        return self.trees[0].num_memtables

    @property
    def active(self) -> MemTable:
        return self.trees[0].active

    @property
    def sealed(self) -> list:
        return self.trees[0].sealed

    @property
    def tables(self) -> dict:
        return self.trees[0].tables

    @property
    def running(self) -> dict:
        return self.trees[0].running

    @property
    def pending_flush(self) -> list:
        return self.trees[0].pending_flush

    @property
    def stalled(self) -> bool:
        return self.trees[0].stalled

    @property
    def _order(self) -> list:
        return self.trees[0]._order

    @property
    def _fstack(self) -> _FilterStack:
        return self.trees[0]._fstack

    @property
    def _stamp(self) -> int:
        return self.trees[0]._stamp

    @property
    def _flush_debt(self) -> int:
        """Total budget debt: group WAL debt + every tree's flush debt
        (the legacy engine kept one combined pot)."""
        return self._wal_debt + sum(t._flush_debt for t in self.trees)

    @property
    def stats(self) -> dict:
        """Merged counters: sum over trees plus the group's WAL
        counters, in the legacy key order.  (A fresh dict per access —
        hold no live reference.)"""
        out = dict.fromkeys(_STATS_ORDER, 0)
        for t in self.trees:
            for k, v in t.stats.items():
                out[k] += v
        for k, v in self._wal_stats.items():
            out[k] += v
        return out

    def seal_active(self) -> None:
        self.trees[0].seal_active()

    _seal_active = seal_active        # compat alias (pre-PR7 name)

    def _refresh_stall(self) -> None:
        for t in self.trees:
            t._refresh_stall()

    def _read_view(self) -> _ReadView:
        return self.trees[0]._read_view()

    def _view_filters(self, view: _ReadView):
        return self.trees[0]._view_filters(view)

    def _invalidate_view(self) -> None:
        self.trees[0]._invalidate_view()

    def _bind_table(self, table: SSTable) -> None:
        self.trees[0]._bind_table(table)

    def _collect_merges(self) -> None:
        for t in self.trees:
            t._collect_merges()

    def _scan_runs(self, lo: int, hi: int):
        return self.trees[0]._scan_runs(lo, hi)

    # merge advance/finish dispatch per-merge via ``rm.tree`` (primary
    # for hand-built cursors): every tree's pump routes its merges
    # THROUGH these two entry points, so wrapping them on the engine
    # instance instruments the whole group
    def _open_merge(self, rm: _RunningMerge) -> None:
        (rm.tree or self.trees[0])._open_merge(rm)

    def _merge_cut(self, rm: _RunningMerge, target: int):
        return (rm.tree or self.trees[0])._merge_cut(rm, target)

    def _tombstone_drop_safe(self, rm: _RunningMerge) -> bool:
        return (rm.tree or self.trees[0])._tombstone_drop_safe(rm)

    def _advance_merge(self, rm: _RunningMerge, quantum: int) -> int:
        return (rm.tree or self.trees[0])._advance_merge(rm, quantum)

    def _finish_merge(self, rm: _RunningMerge) -> None:
        (rm.tree or self.trees[0])._finish_merge(rm)

    _order_key = staticmethod(LSMTree._order_key)
    _merge_kway_host = staticmethod(merge_kway_host)

    # ------------------------------------------------------------------ info
    def lock(self) -> threading.RLock:
        """The group's reentrant lock (see module docstring): the
        ``BackgroundDriver`` holds it around ``pump``; foreground callers
        sharing a group with a driver must hold it around every engine
        call (``with engine.lock(): ...``)."""
        return self._rlock

    def num_components(self) -> int:
        with self._rlock:
            return sum(t.num_components() for t in self.trees)

    def total_entries(self) -> int:
        with self._rlock:
            return sum(t.total_entries() for t in self.trees)

    def pending_background_entries(self) -> int:
        """Background I/O debt in entries across the WHOLE group: WAL
        debt plus every tree's flush debt, sealed memtables and
        unconsumed merge inputs.  This is the per-shard 'pending debt'
        the fleet's ``GlobalBudgetArbiter`` apportions the global budget
        by — and, within a group, what each pump epoch is split by."""
        with self._rlock:
            out = self._wal_debt + sum(t.pending_entries()
                                       for t in self.trees)
            if self._recovery is not None and not self._recovery.done:
                out += self._recovery.remaining
            return out

    # ----------------------------------------------- durability lifecycle
    @property
    def flushed_lsn(self) -> int:
        """First LSN NOT yet captured in on-disk SSTables, over ALL
        trees (the minimum of the per-tree origins) — the WAL
        truncation point a snapshot records.  During online recovery
        the claim is additionally capped by the replay watermark:
        un-replayed WAL history must never be truncated away."""
        lo = min(t.flushed_lsn for t in self.trees)
        if self._replay_watermark is not None:
            lo = min(lo, self._replay_watermark)
        return lo

    def snapshot(self, store) -> dict:
        """Persist the durable view: fsync the WAL, save every tree's
        live SSTables plus per-tree metadata atomically through
        ``store`` (``checkpoint.EngineSnapshotStore``), then drop whole
        WAL segments whose entries are all captured by the saved tables.
        Returns the manifest dict."""
        with self._rlock:
            self._wal_sync()
            manifest = store.save(self)
            if self.wal is not None:
                archived = self.wal.truncate_upto(self.flushed_lsn)
                if archived:
                    # archival is real I/O: charge the moved entries to
                    # the background budget like any other traffic
                    self._wal_debt += archived
            return manifest

    def restore_tables(self, tables, snap: dict) -> int:
        """Legacy single-tree restore (the multi-tree path goes through
        ``RecoverySession`` -> ``LSMTree.restore_tables`` per tree):
        rebuild the PRIMARY tree's read view and the group clock.
        Returns the snapshot's ``flushed_lsn``."""
        with self._rlock:
            out = self.trees[0].restore_tables(tables, snap)
            self.now = max(self.now, float(snap.get("now", 0.0)))
            return out

    def begin_replay(self, lsn: int) -> None:
        """Position the group at WAL offset ``lsn`` before replay: the
        next admitted entry is entry ``lsn`` of the admitted-write
        history.  (``RecoverySession`` then raises individual trees'
        memtable origins to their own snapshot frontiers.)"""
        with self._rlock:
            self._lsn = int(lsn)
            for t in self.trees:
                t.active.start_lsn = self._lsn

    def replay_admit(self, keys, vals) -> int:
        """Legacy recovery admission into the PRIMARY tree (no
        re-logging, no constraint stalls), advancing the group LSN.
        Multi-tree replay uses ``LSMTree.replay_admit`` per frame with
        session-managed LSNs instead."""
        with self._rlock:
            took = self.trees[0].replay_admit(keys, vals)
            self._lsn += took
            return took

    def compact_all(self, budget_per_pump: int = 1 << 30) -> None:
        """Force-merge every tree into one bottom run: flush every
        memtable, drain policy merges, then merge ALL live tables per
        tree to the deepest level in one op — no older run can overlap
        it, so every tombstone is reclaimed.  This is the space-amp
        floor the durability tests pin."""
        with self._rlock:
            for t in self.trees:
                if len(t.active):
                    t.seal_active()
            self.drain(budget_per_pump)
            started = False
            for t in self.trees:
                started = t.start_full_merge() or started
            if started:
                self.drain(budget_per_pump)

    def live_entries(self) -> int:
        """Distinct keys whose newest version is NOT a tombstone, summed
        over trees (an O(n) full-range scan per tree)."""
        return sum(t.live_entries() for t in self.trees)

    def amplification(self) -> dict:
        """Write/space amplification snapshot (see
        ``metrics.amplification_stats``): bytes written by flush + merge
        + WAL over logical bytes ingested (index maintenance counts in
        the numerator, not the denominator — it IS amplification), and
        physical entries stored over live entries, across all trees."""
        from .metrics import amplification_stats
        with self._rlock:
            return amplification_stats(self.stats,
                                       physical_entries=self.total_entries(),
                                       live_entries=self.live_entries())

    def enable_scrub(self, store=None, entries_per_epoch: int = 256):
        """Attach a background integrity ``Scrubber`` (see
        ``core.scrub``): every pump epoch reserves up to
        ``entries_per_epoch`` of the budget to stream CRC verification
        over live tables, quarantining and repairing on mismatch.
        ``store`` (an ``EngineSnapshotStore``) is the preferred repair
        source.  Returns the scrubber (its ``stats`` feed
        ``health()``)."""
        from .scrub import Scrubber
        with self._rlock:
            self.scrubber = Scrubber(self, store=store,
                                     entries_per_epoch=entries_per_epoch)
            return self.scrubber

    def health(self) -> dict:
        """Fault-plane counters, ``amplification()``-style: a flat
        numeric dict (summable fleet-wide) covering I/O retries and
        backoff, ENOSPC stall epochs, scrub progress and
        quarantine/repair outcomes, WAL archival, and online-recovery
        state."""
        with self._rlock:
            out = {"enospc_stalls": self._health["enospc_stalls"],
                   "recovering": int(self._recovery is not None),
                   "replay_remaining": (self._recovery.remaining
                                        if self._recovery is not None
                                        else 0),
                   "wal_archived_segments": 0, "wal_archived_entries": 0,
                   "io_retries": 0, "io_backoff_s": 0.0, "io_faults": 0,
                   "io_enospc": 0, "io_latency_injected_s": 0.0}
            if self.wal is not None:
                out["wal_archived_segments"] = self.wal.archived_segments
                out["wal_archived_entries"] = self.wal.archived_entries
                for k, v in self.wal.io.stats.items():
                    out[k] += v
            if self.scrubber is not None:
                out.update(self.scrubber.stats)
            else:
                out.update({"scrub_passes": 0, "scrub_tables_checked": 0,
                            "scrub_entries": 0, "tables_quarantined": 0,
                            "tables_repaired": 0,
                            "tables_unrepairable": 0})
            return out

    def close(self) -> None:
        """Graceful shutdown: fsync and release the WAL (no-op without
        one).  The group stays readable afterwards; only the durability
        plane is closed."""
        with self._rlock:
            if self.wal is not None:
                self.wal.close()

    def __enter__(self) -> "StorageGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LSMEngine(StorageGroup):
    """A single-partition LSM store (uint32 keys -> int32 values): the
    1-tree ``StorageGroup`` — the engine every pre-split caller
    constructs.  Secondary indexes can still be declared (``indexes=``
    or ``add_index``); a bare construction is bit-identical to the
    pre-split single-tree engine."""


class BackgroundDriver:
    """Wall-clock driver: pumps an engine at ``bandwidth_bytes_per_s`` on a
    daemon thread (the serving/ingestion examples use this; tests use
    pump() directly)."""

    def __init__(self, engine: LSMEngine, bandwidth_bytes_per_s: float,
                 quantum_s: float = 0.01):
        self.engine = engine
        self.rate = bandwidth_bytes_per_s
        self.quantum_s = quantum_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # the ENGINE's lock, not a private one: a driver-private lock
        # guards nothing, because foreground put/get/scan calls never
        # took it and raced the pump thread.  Sharing engine.lock()
        # makes `with engine.lock():` on the foreground path exclude
        # the pump.
        self._lock = engine.lock()

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        # Pace by monotonic elapsed time, carrying the undelivered-entry
        # deficit across iterations.  The seed computed one fixed
        # per-quantum budget and slept quantum_s per loop, so every source
        # of iteration overrun — pump compute, lock contention with the
        # foreground, sleep overshoot — silently shrank the delivered
        # bandwidth below the configured budget (the knob every experiment
        # in the paper turns).  Here the budget owed is always
        # elapsed * rate, so slow iterations are repaid by larger quanta.
        t0 = time.monotonic()
        delivered = 0.0                # entry quanta offered to pump()
        per_s = self.rate / ENTRY_BYTES
        # cap each catch-up quantum: an unbounded one would grow with
        # every slow pump (bigger quantum -> longer lock hold -> bigger
        # deficit), starving the foreground in ever-larger bursts.  The
        # residual deficit still carries, so a temporarily slow pump is
        # repaid at up to 4x pace; a persistently slow one is genuine
        # saturation the budget cannot force through.
        q_max = max(1, int(4 * per_s * self.quantum_s))
        while not self._stop.is_set():
            deficit = (time.monotonic() - t0) * per_s - delivered
            quantum = min(int(deficit), q_max)
            if quantum >= 1:
                with self._lock:
                    self.engine.pump(quantum)
                delivered += quantum
            self._stop.wait(self.quantum_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def close(self) -> None:
        """Graceful shutdown: stop the pump thread (any in-flight quantum
        completes under the engine lock before ``stop`` returns), then
        close the engine's durability plane (WAL fsync).  Idempotent."""
        self.stop()
        self.engine.close()

    def __enter__(self) -> "BackgroundDriver":
        if self._thread is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()
