"""The real LSM storage engine: paper's scheduling plane + JAX data plane.

Writes land in a MemTable; flushes turn sealed memtables into SSTables
(sorted runs + Pallas-built Bloom filters); merges execute through the
Pallas merge-path kernel.  The *decisions* — which components to merge
(policy), who gets I/O bandwidth (scheduler), when writes stall
(constraint) — are exactly the classes the fluid simulator exercises, so
every figure-level claim in the paper can be replayed against real bytes.

Execution model: deterministic cooperative quanta.  ``pump(budget_bytes)``
advances background I/O by one bandwidth quantum, split across flushes
(strict priority, Section 3.1) and merges per the scheduler's allocation
(pause/resume = simply which ops receive quanta).  A wall-clock driver
(`BackgroundDriver`) turns quanta into a rate-limited background thread
for the serving example; tests use pump() directly for determinism.

Read view contract: point lookups and scans go through a cached
``_ReadView`` — the disk tables snapshotted NEWEST-FIRST by
``(-data_stamp, component.level)`` (on equal stamps the LOWER level holds
the newer version, since levels are age-ordered) together with the
stacked, zero-padded Bloom filter words for the fused multi-table probe.
The view is invalidated (``_view = None``) exactly where ``self.tables``
changes: flush binding in ``pump`` and merge completion in
``_finish_merge``; it is rebuilt lazily on the next read.  ``get``,
``get_batch`` (newest-first, early-exit) and ``scan_range`` (oldest-first
= ``reversed(view.tables)``, newer overrides) share this one ordering —
the seed's `(-stamp, level)` vs `(stamp, -level)` sort keys are the same
total order traversed from opposite ends, now written in one place.

``interpret`` selects the Pallas execution mode for every kernel the
engine launches (bloom probes and the merge path): True keeps CPU tests
on the interpreter, False compiles for the accelerator in benchmarks.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .component import Component, LSMTree, MergeOp
from .constraints import ComponentConstraint, NoConstraint
from .memtable import MemTable
from .policies import MergePolicy
from .scheduler import MergeScheduler
from .sstable import SSTable

try:  # the merge kernel needs jax; engine tests always have it
    from repro.kernels.bloom.ops import bloom_probe_multi, stack_filters
    from repro.kernels.merge.ops import merge_dedup
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    merge_dedup = None
    bloom_probe_multi = stack_filters = None


ENTRY_BYTES = 1024  # paper's 1 KB records: 1 entry == 1 KB of I/O budget


@dataclass
class _ReadView:
    """Cached snapshot of the disk tables for the read plane.

    ``tables`` is newest-first by ``(-data_stamp, level)``; ``filts`` /
    ``meta`` are the stacked padded Bloom words + per-table (n_bits, k)
    for the fused multi-table probe (None when there are no tables).
    Rebuilt lazily after any flush/merge completion invalidates it.
    """
    tables: tuple
    filts: Optional[np.ndarray] = None
    meta: Optional[np.ndarray] = None


@dataclass
class _RunningMerge:
    op: MergeOp
    inputs: list[SSTable]
    # merged-but-unreleased output accumulated across quanta
    out_keys: list[np.ndarray] = field(default_factory=list)
    out_vals: list[np.ndarray] = field(default_factory=list)
    cursor: int = 0            # entries of the merged stream already emitted
    merged_keys: Optional[np.ndarray] = None
    merged_vals: Optional[np.ndarray] = None


class LSMEngine:
    """A single-partition LSM store (uint32 keys -> int32 values)."""

    def __init__(self, policy: MergePolicy, scheduler: MergeScheduler,
                 constraint: ComponentConstraint | None = None,
                 memtable_entries: int = 4096, num_memtables: int = 2,
                 unique_keys: float = 1e6, use_kernels: bool = True,
                 merge_block: int = 256, interpret: bool = True):
        self.policy = policy
        self.scheduler = scheduler
        self.constraint = constraint or NoConstraint()
        self.tree = LSMTree(unique_keys=unique_keys)
        self.memtable_entries = int(memtable_entries)
        self.num_memtables = int(num_memtables)
        self.use_kernels = bool(use_kernels) and merge_dedup is not None
        self.merge_block = int(merge_block)
        self.interpret = bool(interpret)

        self.active = MemTable(self.memtable_entries)
        self.sealed: list[MemTable] = []
        self.tables: dict[int, SSTable] = {}     # component id -> SSTable
        self._view: Optional[_ReadView] = None   # cached read view
        self._view_epoch = 0                     # bumped on invalidation
        self.running: dict[int, _RunningMerge] = {}
        self.pending_flush: list[tuple[np.ndarray, np.ndarray]] = []
        self.now = 0.0
        self._stamp = 0
        self.stalled = False
        self.stats = {"puts": 0, "stall_events": 0, "flushes": 0,
                      "merges": 0, "merge_bytes": 0, "lookups": 0,
                      "bloom_skips": 0}

    # ------------------------------------------------------------------ write
    def put(self, key: int, value: int) -> bool:
        """Returns False when the write must stall (component constraint or
        no free memtable slot) — the caller decides to retry/queue."""
        self._refresh_stall()
        if self.stalled:
            return False
        if self.active.full:
            if len(self.sealed) >= self.num_memtables - 1:
                self.stats["stall_events"] += 1
                return False
            self._seal_active()
        self.active.put(key, value)
        self.stats["puts"] += 1
        return True

    def put_batch(self, keys, values) -> int:
        """Bulk admission: admit entries in numpy-slice chunks, computing
        the seal/stall boundary once per chunk instead of per entry.
        Returns the count accepted before the first stall — identical to
        running the scalar ``put`` loop (the tree, and hence the stall
        predicate, only changes under ``pump``, so one check per chunk is
        exact).  Sole divergence: a reserved sentinel key raises
        ValueError before its chunk admits ANY entry (atomic batch
        validation), where the scalar loop would admit the prefix
        first."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        n = len(keys)
        n_ok = 0
        while n_ok < n:
            self._refresh_stall()
            if self.stalled:
                break
            if self.active.full:
                if len(self.sealed) >= self.num_memtables - 1:
                    self.stats["stall_events"] += 1
                    break
                self._seal_active()
            took = self.active.put_batch(keys[n_ok:], values[n_ok:])
            n_ok += took
            self.stats["puts"] += took
        return n_ok

    def _seal_active(self):
        self.sealed.append(self.active)
        self.active = MemTable(self.memtable_entries)

    def _refresh_stall(self):
        self.stalled = self.constraint.violated(self.tree)

    # ------------------------------------------------------------------ read
    def _read_view(self) -> _ReadView:
        """The cached read view (see module docstring for the contract).
        Epoch-guarded against the wall-clock driver: if a flush/merge
        invalidates mid-build, the snapshot serves this call but is NOT
        cached, so a stale view can never become sticky."""
        view = self._view
        if view is None:
            epoch = self._view_epoch
            tables = tuple(sorted(
                (t for t in self.tables.values() if t.component is not None),
                key=lambda t: (-t.data_stamp, t.component.level)))
            if tables and stack_filters is not None:
                filts, meta = stack_filters(
                    [t.bloom_host() for t in tables],
                    [t.n_bits for t in tables],
                    [t.k_hashes for t in tables])
                view = _ReadView(tables, filts, meta)
            else:
                view = _ReadView(tables)
            if epoch == self._view_epoch:
                self._view = view
        return view

    def _invalidate_view(self):
        self._view_epoch += 1
        self._view = None

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Resolve a whole key batch in one pass: vectorized newest-wins
        lookup over the memtables, then ONE fused Bloom probe across all
        disk tables (a (tables, keys) Pallas grid), then sorted searches
        only for surviving (table, key) pairs, newest table first with
        early exit.  Returns (found mask, values)."""
        keys = np.asarray(keys, np.uint32)
        q = len(keys)
        self.stats["lookups"] += q
        found = np.zeros(q, bool)
        vals = np.zeros(q, np.int32)
        for mt in (self.active, *reversed(self.sealed)):
            if found.all():
                return found, vals
            f, v = mt.get_batch(keys)
            new = f & ~found
            vals[new] = v[new]
            found |= new
        if found.all():
            return found, vals
        view = self._read_view()
        if not view.tables:
            return found, vals
        if view.filts is not None:
            maybe = bloom_probe_multi(view.filts, view.meta, keys,
                                      interpret=self.interpret)
        else:  # pragma: no cover - kernels unavailable
            maybe = np.ones((len(view.tables), q), bool)
        for ti, table in enumerate(view.tables):
            pend = ~found
            if not pend.any():
                break
            cand = pend & maybe[ti]
            self.stats["bloom_skips"] += int((pend & ~maybe[ti]).sum())
            if not cand.any():
                continue
            idx = np.flatnonzero(cand)
            f, v = table.search(keys[idx])
            hit = idx[f]
            vals[hit] = v[f]
            found[hit] = True
        return found, vals

    def scan_range(self, lo: int, hi: int) -> dict[int, int]:
        """Newest-wins range scan across all components (oldest-first
        traversal of the shared read view; newer tables override)."""
        out: dict[int, int] = {}
        for table in reversed(self._read_view().tables):
            ks, vs = table.scan_range(lo, hi)
            out.update(zip(ks.tolist(), vs.tolist()))
        for mt in self.sealed:                 # memory newer than disk
            sk, sv = mt.seal()
            m = (sk >= lo) & (sk < hi)
            out.update(zip(sk[m].tolist(), sv[m].tolist()))
        sk, sv = self.active.seal()
        m = (sk >= lo) & (sk < hi)
        out.update(zip(sk[m].tolist(), sv[m].tolist()))
        return out

    # ------------------------------------------------------- background I/O
    def pump(self, budget_entries: int) -> int:
        """Advance background work by ``budget_entries`` of write I/O.
        Flushes take strict priority; the remainder goes to merges per the
        scheduler's allocation.  Returns entries actually written."""
        spent = 0
        self.now += 1.0
        # 1. flushes
        while self.sealed and spent < budget_entries:
            mt = self.sealed.pop(0)
            keys, vals = mt.seal()
            table = SSTable.build(keys, vals,
                                  level=self.policy.flush_target_level(),
                                  created_at=self.now,
                                  interpret=self.interpret)
            self._stamp += 1
            table.data_stamp = self._stamp
            table.component.stamp = float(self._stamp)
            self.tree.add(table.component)
            self.tables[table.component.cid] = table
            self._invalidate_view()
            self.stats["flushes"] += 1
            spent += len(keys)
            self._collect_merges()
        if spent >= budget_entries:
            self._refresh_stall()
            return spent
        # 2. merges, per scheduler allocation
        self._collect_merges()
        ops = [rm.op for rm in self.running.values()]
        alloc = self.scheduler.allocate(ops) if ops else {}
        remaining = budget_entries - spent
        for op_id, frac in alloc.items():
            if frac <= 0:
                continue
            quantum = int(remaining * frac)
            if quantum > 0:
                spent += self._advance_merge(self.running[op_id], quantum)
        self._refresh_stall()
        return spent

    def drain(self, budget_entries: int = 1 << 30, max_pumps: int = 10_000):
        """Pump until no background work remains (tests/shutdown)."""
        for _ in range(max_pumps):
            self._collect_merges()
            if not self.sealed and not self.running:
                break
            self.pump(budget_entries)

    def _collect_merges(self):
        for op in self.policy.collect_merges(self.tree, self.now):
            inputs = [self.tables[c.cid] for c in op.inputs]
            self.running[op.op_id] = _RunningMerge(op=op, inputs=inputs)

    # -- merge execution (the paper's unit of schedulable I/O) ---------------
    def _materialize_merge(self, rm: _RunningMerge):
        """Compute the full merged run once (kernel or numpy), then emit it
        in scheduler-controlled quanta — I/O pacing is what the paper
        schedules; the compute itself is one kernel launch."""
        # newest component wins: fold oldest -> newest with the newer run
        # as A.  data_stamp is the data-age order (created_at can tie when
        # a flush and a merge complete in the same pump); on equal stamps
        # the HIGHER level is older.
        tables = sorted(rm.inputs,
                        key=lambda t: (t.data_stamp,
                                       -(t.component.level
                                         if t.component else 0)))
        runs = [(np.asarray(t.keys), np.asarray(t.vals)) for t in tables]
        keys, vals = runs[0]
        for nk, nv in runs[1:]:
            keys, vals = self._merge_two(nk, nv, keys, vals)
        rm.merged_keys, rm.merged_vals = keys, vals

    def _merge_two(self, keys_a, vals_a, keys_b, vals_b):
        """A is newer (wins ties)."""
        if self.use_kernels:
            mk, mv, keep, valid = merge_dedup(
                jnp.asarray(keys_a, jnp.uint32), jnp.asarray(vals_a, jnp.int32),
                jnp.asarray(keys_b, jnp.uint32), jnp.asarray(vals_b, jnp.int32),
                block=self.merge_block, interpret=self.interpret)
            mk, mv = np.asarray(mk), np.asarray(mv)
            keep = np.array(keep)          # writable copy
            keep[valid:] = False
            return mk[keep], mv[keep]
        ks = np.concatenate([keys_a, keys_b])
        vs = np.concatenate([vals_a, vals_b])
        src = np.concatenate([np.zeros(len(keys_a), np.int8),
                              np.ones(len(keys_b), np.int8)])
        order = np.lexsort((src, ks))
        ks, vs = ks[order], vs[order]
        first = np.ones(len(ks), bool)
        first[1:] = ks[1:] != ks[:-1]
        return ks[first], vs[first]

    def _advance_merge(self, rm: _RunningMerge, quantum: int) -> int:
        if rm.merged_keys is None:
            self._materialize_merge(rm)
        total = len(rm.merged_keys)
        take = min(quantum, total - rm.cursor)
        if take > 0:
            rm.out_keys.append(rm.merged_keys[rm.cursor:rm.cursor + take])
            rm.out_vals.append(rm.merged_vals[rm.cursor:rm.cursor + take])
            rm.cursor += take
            rm.op.written += take
            self.stats["merge_bytes"] += take * ENTRY_BYTES
        if rm.cursor >= total:
            self._finish_merge(rm)
        return max(take, 0)

    def _finish_merge(self, rm: _RunningMerge):
        keys = np.concatenate(rm.out_keys) if rm.out_keys else \
            np.empty(0, np.uint32)
        vals = np.concatenate(rm.out_vals) if rm.out_vals else \
            np.empty(0, np.int32)
        stamp = max(t.data_stamp for t in rm.inputs)
        # keep the policy's metadata model in sync with the real output size
        rm.op.output_size = float(len(keys))
        rm.op.written = float(len(keys))
        for c in rm.op.inputs:
            self.tables.pop(c.cid, None)
        outs = self.policy.complete_merge(self.tree, rm.op, self.now)
        # partitioned policies may split the output into several files
        def _bind(comp, ks, vs):
            table = SSTable.build(ks, vs, level=comp.level,
                                  created_at=self.now,
                                  interpret=self.interpret)
            table.component = comp
            table.data_stamp = stamp
            comp.stamp = float(stamp)
            # keep the scheduling-plane range metadata honest: the policy's
            # overlap selection must see the REAL key span, else adjacent-
            # level overlaps are missed and newest-wins breaks.
            if len(ks):
                comp.key_lo = float(ks[0]) / 2**32
                comp.key_hi = (float(ks[-1]) + 1) / 2**32
            self.tables[comp.cid] = table

        if len(outs) == 1:
            _bind(outs[0], keys, vals)
        else:
            n = max(len(outs), 1)
            splits = np.array_split(np.arange(len(keys)), n)
            for comp, idx in zip(outs, splits):
                _bind(comp, keys[idx], vals[idx])
        self.running.pop(rm.op.op_id, None)
        self._invalidate_view()
        self.stats["merges"] += 1
        self._collect_merges()

    # ------------------------------------------------------------------ info
    def num_components(self) -> int:
        return self.tree.num_components()

    def total_entries(self) -> int:
        return sum(len(t) for t in self.tables.values()) + \
            sum(len(m) for m in self.sealed) + len(self.active)


class BackgroundDriver:
    """Wall-clock driver: pumps an engine at ``bandwidth_bytes_per_s`` on a
    daemon thread (the serving/ingestion examples use this; tests use
    pump() directly)."""

    def __init__(self, engine: LSMEngine, bandwidth_bytes_per_s: float,
                 quantum_s: float = 0.01):
        self.engine = engine
        self.rate = bandwidth_bytes_per_s
        self.quantum_s = quantum_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        per_quantum = int(self.rate * self.quantum_s / ENTRY_BYTES)
        while not self._stop.is_set():
            with self._lock:
                self.engine.pump(max(per_quantum, 1))
            time.sleep(self.quantum_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
