"""The real LSM storage engine: paper's scheduling plane + JAX data plane.

Writes land in a MemTable; flushes turn sealed memtables into SSTables
(sorted runs; Bloom filters build lazily on first probe); merges execute
through the Pallas merge-path kernel.  The *decisions* — which components to merge
(policy), who gets I/O bandwidth (scheduler), when writes stall
(constraint) — are exactly the classes the fluid simulator exercises, so
every figure-level claim in the paper can be replayed against real bytes.

Execution model: deterministic cooperative quanta.  ``pump(budget_bytes)``
advances background I/O by one bandwidth quantum, split across flushes
(strict priority, Section 3.1) and merges per the scheduler's allocation
(pause/resume = simply which ops receive quanta).  A wall-clock driver
(`BackgroundDriver`) turns quanta into a rate-limited background thread
for the serving example; tests use pump() directly for determinism.

Read view contract: point lookups and scans go through a cached
``_ReadView`` over the disk tables, NEWEST-FIRST by
``(-data_stamp, component.level)`` (on equal stamps the LOWER level holds
the newer version, since levels are age-ordered).  The view is maintained
INCREMENTALLY, per-event cost proportional to the event, never to total
engine state:

* ``self._order`` is the authoritative newest-first table list, updated
  by insertion — a flush carries the globally newest stamp and prepends
  one table; a merge completion removes its k inputs and bisect-inserts
  its outputs at their ``(-stamp, level)`` rank (outputs of one merge
  share that rank and hold disjoint key ranges, so their relative order
  is free).  There is no full re-sort anywhere on the maintenance path.
* The Bloom filter stack for the fused multi-table probe lives in a
  persistent ``_FilterStack``: a preallocated padded DEVICE array with
  slot reuse, maintained EVENT-DRIVEN.  Background events only journal
  their adds/removes (O(1), no device work); the first point lookup
  after an event applies the journal — a flush's table takes one donated
  O(filter-width) row write, a merge frees its k input slots and writes
  one row per output, and an add whose table was merged away before any
  read cancels outright (with lazy Bloom construction, its filter is
  never even built).  The stack is rebuilt from scratch only when
  capacity or row width must grow, or occupancy drops below 1/4
  (geometric, amortized O(1) rows per event).  ``_ReadView.filts``
  stays ``None`` until that first point lookup (``_view_filters``), so
  scan-only and write-only workloads never pay for filter maintenance
  at all; each table's probe row is its own ``stack_slot``, so probing
  needs no per-view gather.

The view is invalidated (``_view = None``, epoch bump) exactly where
``self.tables`` changes: flush binding in ``pump`` and merge completion
in ``_finish_merge``; rebuilding it is an O(tables) tuple snapshot of
``_order``.  The epoch guard keeps a snapshot built concurrently with an
invalidation from becoming sticky.  Because row writes donate the
previous device buffer, a reader NOT holding ``lock()`` against a
concurrent pump may observe a deleted-buffer error rather than stale
bits — the locking discipline below was already mandatory.

``get`` and ``get_batch`` walk the view newest-first with early exit.
``scan_range`` is the range plane over the same view: every live run
contributes its ``[lo, hi)`` window (sliced by ``searchsorted`` on the
host mirrors — active memtable first, then sealed memtables newest-first,
then ``view.tables``), and the windows are resolved newest-wins in ONE
k-way merge (the ``merge_dedup_kway`` tournament kernel, or its
packed-sort host equivalent) — the run list's newest-first order IS the
age order the merge dedups by, so scans and point reads share a single
total order.  ``scan_range`` returns sorted (keys, values) arrays;
``scan_range_dict`` is the dict-compat wrapper.

Background execution model: ALL background work is streamed so that one
scheduler quantum costs O(quantum), never O(total state).  A merge never
materializes its full output: ``_advance_merge`` keeps per-input-run
cursors and, per quantum, cuts the next window at a GLOBAL key boundary
(binary search on the key space over the host mirrors — the merge-path
pivot), merges just that window (``merge_dedup_kway_window`` on the
kernel path, the packed-sort host merge otherwise) and appends it to the
pending output.  Key-boundary cuts mean no equal-key group straddles
windows, so concatenated window outputs are bit-identical to the one-shot
merge; ``streaming_merge=False`` keeps the legacy
materialize-then-emit path as a benchmark baseline.  This bounds the
time ``BackgroundDriver`` holds the engine lock per pump, which is what
makes writer/reader tail latency track the configured quantum instead of
the largest in-flight merge (see ``benchmarks/latency_tail.py``).

Backend / dispatch contract (``core/backend.py``): every launch the
engine makes — the fused Bloom probe, the k-way compaction merge, the
streaming window merge, the scan plane's merge — routes through ONE
``ExecBackend``, which owns the kernel-vs-host decision.  The backend
carries the interpret/compiled Pallas mode and, in ``auto`` mode, picks
host vs kernel *per op per size class* from a MEASURED crossover table
(``artifacts/bench/backend_calibration.json``, produced by the
``kernels_bench`` sweep and loaded at engine construction; a built-in
default applies when the artifact is absent: compiled when the XLA
backend supports it, else host — the interpreter is a correctness
harness, never a performance choice).  Construct the engine with
``backend=ExecBackend(...)`` (or a mode string: ``"auto"``, ``"host"``,
``"interpret"``, ``"compiled"``) to choose the discipline explicitly.

The three historical booleans survive as thin DEPRECATED overrides,
mapped by ``ExecBackend.from_legacy`` to forced per-op modes that
reproduce the old dispatch bit-for-bit: ``interpret`` selects the
Pallas execution mode for every kernel launch; ``use_kernels`` picks
kernel-vs-host for merges; ``scan_use_kernels`` forces the scan plane
(None = auto: kernel only when compiled).  They are ignored when an
explicit ``backend`` is passed.  The engine's ``use_kernels`` /
``interpret`` / ``scan_use_kernels`` attributes are read-only views of
the backend's configuration.

Device residency: the merge→flush→probe plane avoids host↔device
round-trips end-to-end.  ``SSTable.build`` never uploads (device arrays
materialize lazily, or are ADOPTED when the output already lives on
device); the streaming merge accumulates window outputs into
preallocated output buffers — host mirrors seeded incrementally per
window, and on kernel paths a device buffer updated in place via
donation — so ``_finish_merge`` binds the finished table as O(1) views
into those buffers with NO O(merge-size) host concatenate+rebuild
(pinned in ``tests/test_backend.py``).

Thread safety: every foreground entry point (``put``/``put_batch``,
``get``/``get_batch``, ``scan_range``) and the background plane
(``pump``/``drain``) takes the engine's REENTRANT lock internally, so a
router worker thread racing a live ``BackgroundDriver`` can never
observe a half-updated ``_order`` list or a donated filter-stack buffer
(``scan_range`` releases the lock for the k-way merge itself — its run
windows are immutable snapshots).  ``lock()`` still exposes the lock for
callers needing compound atomicity (e.g. read-modify-write sequences, or
the harnesses' multi-call invariant checks); holding it around a call
that also locks internally costs one reentrant acquire.  Uncontended
acquisition is ~100 ns — noise against any engine call's numpy work.

Durability contract (the WAL plane; ``core/wal.py``):

* **With no WAL attached** (``wal=None``, the default) the engine is a
  volatile store: a crash loses every memtable entry and every SSTable
  not captured by an explicit snapshot — exactly the seed's behavior.
* **With a WAL**, every admitted entry (put OR delete) is appended to
  the log BEFORE the memtable admits it, so the admitted-write history
  and the log agree entry-for-entry (LSN == admission index).  An
  acknowledged write is in the OS file buffer immediately and durable
  after the next fsync; fsyncs happen when ``group_commit_entries``
  accumulate (group commit) and unconditionally at every ``pump`` epoch.
  Synced WAL traffic is charged to ``_flush_debt`` — the same budget
  flushes and merges draw from — so durability I/O competes with
  compaction for the configured bandwidth (the paper's single-disk
  write-budget model).
* **Crash loss model**: everything fsynced survives; of the
  appended-but-unsynced tail an arbitrary byte prefix survives (page
  cache).  Recovery (``wal.RecoverySession``) restores the last
  snapshot's SSTables (``checkpoint.EngineSnapshotStore``) and replays
  the WAL suffix from the snapshot's ``flushed_lsn``; the recovered
  read view answers every get/get_batch/scan_range bit-identically to
  an uncrashed engine fed the same durable prefix (the differential
  ``tests/test_durability.py`` pins, across policies and crash points).
* **Tombstone lifecycle**: ``delete``/``delete_batch`` admit the
  reserved ``TOMBSTONE`` value (int32 min, rejected on the user put
  path) through the ordinary write path — WAL, memtable, flush, merge
  all carry it as data, so newest-wins dedup resolves put-vs-delete
  races for free.  The READ plane hides it: a tombstone hit reports
  "not found" / is filtered from scans (both backends).  A merge whose
  output nothing older overlaps (decided at open against ``_order``)
  DROPS tombstones — reclaiming the deleted keys' space — so a full
  compaction returns space-amp to ~1 (``compact_all``).
"""
from __future__ import annotations

import bisect
import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .backend import ExecBackend, merge_kway_host  # noqa: F401 (re-export:
                                                   # the fleet's scan gather
                                                   # shares the host merge)
from .component import Component, LSMTree, MergeOp
from .constraints import ComponentConstraint, NoConstraint
from .memtable import (MemTable, SENTINEL_KEY, TOMBSTONE,
                       drop_tombstones)
from .policies import MergePolicy
from .scheduler import MergeScheduler, apportion_largest_remainder
from .sstable import SSTable

try:  # the kernels need jax; engine tests always have it
    from repro.kernels.bloom.ops import set_stack_row
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    set_stack_row = None
    jax = jnp = None


ENTRY_BYTES = 1024  # paper's 1 KB records: 1 entry == 1 KB of I/O budget


def _next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << max(n - 1, 0).bit_length()


if jax is not None:
    @functools.partial(jax.jit, donate_argnums=(0,))
    def _write_window(buf, win, start):
        """Fold one merge window into the device accumulation buffer.
        The buffer is DONATED so backends with input-output aliasing
        update it in place (O(window), no O(buffer) copy); windows are
        pow2-padded by the caller so the jit cache holds O(log cap)
        shapes per merge instead of one entry per distinct window."""
        return jax.lax.dynamic_update_slice(buf, win, (start,))
else:  # pragma: no cover - kernels unavailable
    _write_window = None


@dataclass
class _ReadView:
    """Cached snapshot of the disk tables for the read plane.

    ``tables`` is newest-first by ``(-data_stamp, level)`` — an O(tables)
    tuple snapshot of the engine's insertion-maintained ``_order`` list.
    ``filts``/``meta`` stay ``None`` until the first point lookup applies
    the persistent ``_FilterStack``'s pending journal
    (``LSMEngine._view_filters``): ``filts`` is the stack's DEVICE array
    (capacity rows, only live slots meaningful), ``meta`` the host-side
    per-row (n_bits, k) geometry; each table's probe row is its own
    ``stack_slot``.  Scan-only workloads never populate them.
    """
    tables: tuple
    filts: Optional["jnp.ndarray"] = None
    meta: Optional[np.ndarray] = None


class _FilterStack:
    """Persistent device-side Bloom filter stack with slot reuse — the
    fused multi-table probe's operand, maintained incrementally and
    EVENT-DRIVEN.

    The engine notes every table add/remove as it happens
    (``note_add``/``note_remove``, O(1) bookkeeping, NO device work — so
    background quanta and scan-only workloads never touch the stack).
    ``sync(tables)``, called on the first point lookup after a view
    rebuild, applies the pending journal: removed tables free their
    rows; each added table takes a free row via ONE donated device row
    write (``set_stack_row``, O(filter width)) and records the row in
    ``SSTable.stack_slot`` so the probe path needs no per-view gather.
    An add whose table is merged away before any read CANCELS against
    its remove — its filter row (and, with lazy Bloom construction, the
    filter itself) is never built at all.

    The stack is rebuilt from scratch only when capacity or row width
    must grow or occupancy falls below 1/4 of capacity — geometric
    sizing, amortized O(rows changed) per background event instead of
    the O(tables * filter-bytes) restack-and-reupload of the per-view
    ``stack_filters`` path this replaces.  Free rows keep
    (n_bits=128, k=1) metadata so they never inflate the probe's static
    ``k_max``; their stale word content is only reachable through a
    stale (raced, uncached) view's ``stack_slot``.
    """

    def __init__(self):
        self.filts: Optional["jnp.ndarray"] = None   # (cap, width) uint32
        self.filts_np: Optional[np.ndarray] = None   # host mirror of the
                                                     # stack — the backend's
                                                     # HOST probe operand
        self.meta = np.zeros((0, 2), np.uint32)      # host (cap, 2)
        self.slots: dict[int, int] = {}              # component cid -> row
        self.free: list[int] = []
        self._add: dict[int, SSTable] = {}           # pending, cid-keyed
        self._remove: list[int] = []                 # pending, cids

    @property
    def cap(self) -> int:
        return 0 if self.filts is None else int(self.filts.shape[0])

    @property
    def width(self) -> int:
        return 0 if self.filts is None else int(self.filts.shape[1])

    def note_add(self, table: SSTable) -> None:
        self._add[table.component.cid] = table

    def note_remove(self, cid: int) -> None:
        if self._add.pop(cid, None) is not None:
            return                       # never materialized: cancelled
        if cid in self.slots:
            self._remove.append(cid)

    def _rebuild(self, tables) -> None:
        cap = max(4, 2 * len(tables))
        width = max(max((t.bloom_host().shape[0] for t in tables),
                        default=1), 1)
        stk = np.zeros((cap, width), np.uint32)
        self.meta = np.zeros((cap, 2), np.uint32)
        self.meta[:, 0] = 128
        self.meta[:, 1] = 1
        self.slots = {}
        for i, t in enumerate(tables):
            w = t.bloom_host()
            stk[i, :w.shape[0]] = w
            self.meta[i] = (t.n_bits, t.k_hashes)
            self.slots[t.component.cid] = i
            t.stack_slot = i
        self.free = list(range(len(tables), cap))
        self.filts_np = stk
        self.filts = jnp.array(stk)      # independent device copy: row
                                         # writes donate the device buffer
                                         # and must never alias the mirror
        self._add.clear()
        self._remove.clear()

    def sync(self, tables) -> tuple["jnp.ndarray", np.ndarray]:
        """Apply the pending add/remove journal; returns
        ``(filts, meta)`` (probe rows come from each table's
        ``stack_slot``).  The previous device array is donated by row
        writes — every external reference must be replaced by the
        returned one."""
        if self.filts is None:
            self._rebuild(tables)
            return self.filts, self.meta
        for cid in self._remove:
            row = self.slots.pop(cid, None)
            if row is not None:
                self.free.append(row)
                self.meta[row] = (128, 1)
        self._remove.clear()
        if self._add:
            adds = list(self._add.values())
            need_w = max(t.bloom_host().shape[0] for t in adds)
            n_live = len(self.slots) + len(adds)
            if need_w > self.width or len(adds) > len(self.free) \
                    or (self.cap > 8 and 4 * n_live < self.cap):
                self._rebuild(tables)
                return self.filts, self.meta
            for t in adds:
                row = self.free.pop()
                words = t.bloom_host()
                if words.shape[0] != self.width:
                    padded = np.zeros(self.width, np.uint32)
                    padded[:words.shape[0]] = words
                    words = padded
                self.filts = set_stack_row(self.filts, words, row)
                self.filts_np[row] = words        # keep the host mirror
                                                  # (HOST probe operand)
                                                  # in lockstep
                self.meta[row] = (t.n_bits, t.k_hashes)
                self.slots[t.component.cid] = row
                t.stack_slot = row
            self._add.clear()
        elif self.cap > 8 and 4 * len(self.slots) < self.cap:
            self._rebuild(tables)
        return self.filts, self.meta


@dataclass
class _RunningMerge:
    op: MergeOp
    inputs: list[SSTable]
    drop: bool = False         # reclaim tombstones (bottom-level merge)
    # -- streaming cursor state (opened lazily by ``_open_merge``) -----
    tables: Optional[list] = None          # inputs sorted newest-first
    run_keys: Optional[list] = None        # per-run host key mirrors
    run_vals: Optional[list] = None
    cursors: Optional[np.ndarray] = None   # per-run consumed prefix
    lens: Optional[np.ndarray] = None
    # merged-but-unreleased output: windows are written incrementally
    # into PREALLOCATED host buffers (capacity = sum of input lens,
    # allocated once at ``_open_merge``) so ``_finish_merge`` binds the
    # finished table as O(1) views — no O(merge-size) concatenate
    buf_keys: Optional[np.ndarray] = None
    buf_vals: Optional[np.ndarray] = None
    # device accumulation (kernel windows only): the window outputs are
    # folded into a donated device buffer so the finished table adopts
    # device-resident arrays without a re-upload.  ``dev_ok`` drops to
    # False permanently once any window ran on the host path.
    dev_keys: Optional["jnp.ndarray"] = field(default=None, repr=False)
    dev_vals: Optional["jnp.ndarray"] = field(default=None, repr=False)
    dev_ok: bool = True
    emitted: int = 0           # post-dedup entries emitted so far
    tombs_in: int = 0          # input tombstones seen in consumed windows
                               # (counted per quantum: O(consumed), so the
                               # finish step never scans the inputs)
    # -- legacy one-shot state (``streaming_merge=False`` baseline) ----
    cursor: int = 0            # entries of the merged stream already emitted
    merged_keys: Optional[np.ndarray] = None
    merged_vals: Optional[np.ndarray] = None


class LSMEngine:
    """A single-partition LSM store (uint32 keys -> int32 values)."""

    def __init__(self, policy: MergePolicy, scheduler: MergeScheduler,
                 constraint: ComponentConstraint | None = None,
                 memtable_entries: int = 4096, num_memtables: int = 2,
                 unique_keys: float = 1e6, use_kernels: bool = True,
                 merge_block: int = 256, interpret: bool = True,
                 scan_use_kernels: Optional[bool] = None,
                 streaming_merge: bool = True,
                 wal=None, group_commit_entries: int = 512,
                 wal_sync_cost: int = 32, faults=None,
                 backend: "ExecBackend | str | None" = None):
        self.policy = policy
        self.scheduler = scheduler
        self.constraint = constraint or NoConstraint()
        # -- durability plane (see module docstring) -------------------
        self.wal = wal                           # WriteAheadLog | None
        self.group_commit_entries = int(group_commit_entries)
        self.wal_sync_cost = int(wal_sync_cost)  # fixed fsync charge
                                                 # (entries of budget)
        self.faults = faults                     # FaultInjector | None
        self._lsn = wal.end_lsn if wal is not None else 0
        self.tree = LSMTree(unique_keys=unique_keys)
        self.memtable_entries = int(memtable_entries)
        self.num_memtables = int(num_memtables)
        # -- execution backend (see module docstring): every kernel-vs-
        # host decision lives here.  The three legacy booleans map to a
        # forced-dispatch backend reproducing the old behavior exactly.
        if backend is None:
            backend = ExecBackend.from_legacy(
                use_kernels=use_kernels, interpret=interpret,
                scan_use_kernels=scan_use_kernels,
                merge_block=merge_block)
        elif isinstance(backend, str):
            backend = ExecBackend(mode=backend, merge_block=merge_block,
                                  interpret=interpret)
        self.backend = backend
        self.merge_block = int(backend.merge_block)
        self.streaming_merge = bool(streaming_merge)
        self._rlock = threading.RLock()

        self.active = MemTable(self.memtable_entries)
        self.sealed: list[MemTable] = []
        self.tables: dict[int, SSTable] = {}     # component id -> SSTable
        self._order: list[SSTable] = []          # newest-first (see module
                                                 # docstring: insertion-
                                                 # maintained, no re-sort)
        self._fstack = _FilterStack()            # lazy device filter stack
        self._view: Optional[_ReadView] = None   # cached read view
        self._view_epoch = 0                     # bumped on invalidation
        self.running: dict[int, _RunningMerge] = {}
        self.pending_flush: list[tuple[np.ndarray, np.ndarray]] = []
        self.now = 0.0
        self._stamp = 0
        self.stalled = False
        self._flush_debt = 0             # flush-quantum overshoot owed
        self._recorder = None            # optional WriteTraceRecorder
        self.stats = {"puts": 0, "stall_events": 0, "flushes": 0,
                      "merges": 0, "merge_bytes": 0, "merge_touched": 0,
                      "lookups": 0, "bloom_skips": 0,
                      # durability / amplification counters (PR 7)
                      "deletes": 0, "replayed": 0, "tombstones_dropped": 0,
                      "wal_entries": 0, "wal_bytes": 0, "wal_syncs": 0,
                      "flush_bytes": 0, "logical_bytes": 0}

    # ----------------------------------------------------------- backend
    def set_backend(self, backend: "ExecBackend | str") -> None:
        """Swap the execution backend (the fleet plumbs ONE shared
        backend to every shard through here).  Takes an ``ExecBackend``
        or a mode string (``"auto"``/``"host"``/``"interpret"``/
        ``"compiled"``)."""
        if isinstance(backend, str):
            backend = ExecBackend(mode=backend,
                                  merge_block=self.merge_block)
        with self._rlock:
            self.backend = backend
            self.merge_block = int(backend.merge_block)

    # Legacy dispatch flags, now READ-ONLY views of the backend's
    # configuration (no engine code branches on them anymore; they are
    # kept for callers/tests that introspect the dispatch discipline).
    @property
    def use_kernels(self) -> bool:
        lk = self.backend.legacy_use_kernels
        if lk is not None:
            return lk
        return self.backend.decide("merge_kway", 1 << 20) != "host"

    @property
    def interpret(self) -> bool:
        return self.backend.interpret

    @property
    def scan_use_kernels(self) -> bool:
        lk = self.backend.legacy_scan_use_kernels
        if lk is not None:
            return lk
        return self.backend.decide("scan_merge", 1 << 20) != "host"

    # -------------------------------------------------------- fault hooks
    def _fault(self, point: str) -> None:
        """Hit a named crash point (no-op without an injector)."""
        if self.faults is not None:
            self.faults.hit(point)

    def attach_write_recorder(self, recorder) -> None:
        """Attach a ``metrics.WriteTraceRecorder`` (or None to detach).
        The write path then reports (admitted, offered) ONCE per
        ``put``/``put_batch`` call — per-batch timestamping, so tracing
        costs one branch and the hot path stays vectorized.  Stall
        intervals fall out of the recorder's admitted<offered transitions
        (see ``metrics.py``); this is the engine half of the two-phase
        harness's measurement plane."""
        self._recorder = recorder

    # ------------------------------------------------------------------ write
    def put(self, key: int, value: int) -> bool:
        """Returns False when the write must stall (component constraint or
        no free memtable slot) — the caller decides to retry/queue."""
        if np.int32(value) == TOMBSTONE:
            raise ValueError("value -2**31 is reserved (delete tombstone)")
        with self._rlock:
            return self._put_locked(key, value)

    def _put_locked(self, key: int, value: int) -> bool:
        if np.uint32(key) == SENTINEL_KEY:
            raise ValueError("key 2**32-1 is reserved")
        self._refresh_stall()
        ok = True
        if self.stalled:
            # a constraint-induced rejection IS a stall event: the paper's
            # stall accounting charges the writer whenever the write path
            # refuses work, whichever side (memtable backpressure or the
            # component constraint) refused it
            self.stats["stall_events"] += 1
            ok = False
        elif self.active.full and len(self.sealed) >= self.num_memtables - 1:
            self.stats["stall_events"] += 1
            ok = False
        else:
            if self.active.full:
                self.seal_active()
            self._wal_log(np.array([key], np.uint32),
                          np.array([value], np.int32))
            self.active.put(key, value)
            self.stats["puts"] += 1
            self.stats["logical_bytes"] += ENTRY_BYTES
        if self._recorder is not None:
            self._recorder.on_puts(int(ok), 1)
        return ok

    def put_batch(self, keys, values) -> int:
        """Bulk admission: admit entries in numpy-slice chunks, computing
        the seal/stall boundary once per chunk instead of per entry.
        Returns the count accepted before the first stall — identical to
        running the scalar ``put`` loop (the tree, and hence the stall
        predicate, only changes under ``pump``, so one check per chunk is
        exact).  Sole divergence: a reserved sentinel key raises
        ValueError before its chunk admits ANY entry (atomic batch
        validation), where the scalar loop would admit the prefix
        first."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.int32)
        if (values == TOMBSTONE).any():
            raise ValueError("value -2**31 is reserved (delete tombstone)")
        with self._rlock:
            return self._put_batch_locked(keys, values)

    def delete(self, key: int) -> bool:
        """Blind delete: admit a TOMBSTONE for ``key`` through the
        ordinary write path (WAL-logged, stall-checked).  Returns False
        when the write must stall — True says the delete was ADMITTED,
        not that the key existed (LSM deletes never look)."""
        return self.delete_batch(np.array([key], np.uint32)) == 1

    def delete_batch(self, keys) -> int:
        """Bulk blind deletes: ``put_batch`` semantics (admit until the
        first stall, returns the admitted count), writing TOMBSTONE
        values.  The markers flow through flush/merge as data and are
        reclaimed by bottom-level merges (see module docstring)."""
        keys = np.asarray(keys, np.uint32)
        vals = np.full(len(keys), TOMBSTONE, np.int32)
        with self._rlock:
            return self._put_batch_locked(keys, vals, deletes=True)

    def _put_batch_locked(self, keys, values, deletes: bool = False) -> int:
        n = len(keys)
        if (keys == SENTINEL_KEY).any():
            raise ValueError("key 2**32-1 is reserved")
        n_ok = 0
        while n_ok < n:
            self._refresh_stall()
            if self.stalled:
                # mirror ``put``: one stall event per batch rejection,
                # whichever predicate (constraint here, memtable
                # backpressure below) refused the remainder
                self.stats["stall_events"] += 1
                break
            if self.active.full:
                if len(self.sealed) >= self.num_memtables - 1:
                    self.stats["stall_events"] += 1
                    break
                self.seal_active()
            # chunk size is known up front (memtable room), so the WAL
            # frame and the memtable admission carry identical entries —
            # the LSN == admission-index invariant recovery relies on
            take = min(n - n_ok, self.active.capacity - len(self.active))
            chunk_k = keys[n_ok:n_ok + take]
            chunk_v = values[n_ok:n_ok + take]
            self._wal_log(chunk_k, chunk_v)
            took = self.active.put_batch(chunk_k, chunk_v)
            assert took == take, "memtable admitted less than its room"
            n_ok += took
            self.stats["deletes" if deletes else "puts"] += took
        self.stats["logical_bytes"] += n_ok * ENTRY_BYTES
        if self._recorder is not None and n > 0:
            self._recorder.on_puts(n_ok, n)
        return n_ok

    # ------------------------------------------------------------- WAL
    def _wal_log(self, keys, vals) -> None:
        """Append one admitted chunk as one WAL frame (the group-commit
        unit) BEFORE memtable admission, hit the ack-unknown crash
        point, and group-commit when enough entries accumulated."""
        if self.wal is None:
            self._lsn += len(keys)
            return
        self.wal.append(keys, vals)
        self._lsn = self.wal.end_lsn
        self.stats["wal_entries"] += len(keys)
        self._fault("post-wal-pre-memtable")
        if self.wal.unsynced_entries >= self.group_commit_entries:
            self._wal_sync()

    def _wal_sync(self) -> None:
        """fsync the WAL and charge the synced traffic (entries plus the
        fixed ``wal_sync_cost`` seek charge) to ``_flush_debt`` — repaid
        from pump budget before flushes/merges, so durability I/O
        competes with compaction for the configured bandwidth."""
        if self.wal is None:
            return
        n = self.wal.unsynced_entries
        if n <= 0:
            return
        self.wal.sync()
        self._flush_debt += n + self.wal_sync_cost
        self.stats["wal_bytes"] += n * ENTRY_BYTES
        self.stats["wal_syncs"] += 1

    def seal_active(self) -> None:
        """Seal the active memtable (it becomes a flush candidate) and
        open a fresh one whose ``start_lsn`` is the current WAL position
        — the bookkeeping behind ``flushed_lsn``."""
        self.sealed.append(self.active)
        self.active = MemTable(self.memtable_entries)
        self.active.start_lsn = self._lsn

    _seal_active = seal_active        # compat alias (pre-PR7 name)

    def _refresh_stall(self):
        self.stalled = self.constraint.violated(self.tree)

    # ------------------------------------------------------------------ read
    def _read_view(self) -> _ReadView:
        """The cached read view (see module docstring for the contract):
        an O(tables) snapshot of the insertion-maintained ``_order`` list
        — no sorting, no filter work (filters sync lazily in
        ``_view_filters``).  Epoch-guarded against the wall-clock driver:
        if a flush/merge invalidates mid-build, the snapshot serves this
        call but is NOT cached, so a stale view can never become
        sticky."""
        view = self._view
        if view is None:
            epoch = self._view_epoch
            view = _ReadView(tuple(self._order))
            if epoch == self._view_epoch:
                self._view = view
        return view

    def _view_filters(self, view: _ReadView):
        """Lazily apply the filter stack's pending add/remove journal
        (first point lookup after a background event pays O(rows
        changed); scans never call this).  The stack syncs against the
        authoritative ``_order`` list — a raced, uncached view probes
        through its tables' ``stack_slot``s, which stay correct for
        every table still live.  Returns ``(filts, meta)`` — ``None``s
        when the bloom kernels are unavailable."""
        if view.filts is None and view.tables and set_stack_row is not None:
            view.filts, view.meta = self._fstack.sync(self._order)
        return view.filts, view.meta

    def _invalidate_view(self):
        self._view_epoch += 1
        self._view = None

    @staticmethod
    def _order_key(t: SSTable):
        """Newest-first rank of a table in the read view / merge order."""
        return (-t.data_stamp, t.component.level if t.component else 0)

    def get(self, key: int):
        found, vals = self.get_batch(np.array([key], np.uint32))
        return int(vals[0]) if found[0] else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Resolve a whole key batch in one pass: vectorized newest-wins
        lookup over the memtables, then ONE fused Bloom probe across all
        disk tables (a (tables, keys) Pallas grid), then sorted searches
        only for surviving (table, key) pairs, newest table first with
        early exit.  Returns (found mask, values).

        Thread-safe: the whole resolution runs under ``lock()`` — the
        memtable walk, the view snapshot, the filter-stack sync (whose
        row writes DONATE the previous device buffer) and the per-table
        sorted searches must all see one consistent engine state against
        a live ``BackgroundDriver`` pump."""
        keys = np.asarray(keys, np.uint32)
        with self._rlock:
            return self._get_batch_locked(keys)

    def _get_batch_locked(self, keys) -> tuple[np.ndarray, np.ndarray]:
        # ``resolved`` tracks keys whose NEWEST version is known — a
        # tombstone hit resolves the key (stop searching older runs) but
        # must still report "not found"; the final mask strips them.
        q = len(keys)
        self.stats["lookups"] += q
        resolved = np.zeros(q, bool)
        vals = np.zeros(q, np.int32)
        for mt in (self.active, *reversed(self.sealed)):
            if resolved.all():
                break
            f, v = mt.get_batch(keys)
            new = f & ~resolved
            vals[new] = v[new]
            resolved |= new
        if not resolved.all():
            view = self._read_view()
            if view.tables:
                filts, meta = self._view_filters(view)
                if filts is not None:
                    # probe the full stack (capacity rows, <= 2x live
                    # tables); each table's row is its own stack_slot —
                    # no gather.  The backend picks host vs kernel; the
                    # host path probes the stack's host mirror.
                    probed = self.backend.probe_multi(
                        filts, meta, keys,
                        filts_host=self._fstack.filts_np)
                else:  # pragma: no cover - kernels unavailable
                    probed = None
                for table in view.tables:
                    pend = ~resolved
                    if not pend.any():
                        break
                    maybe_t = probed[table.stack_slot] \
                        if probed is not None else np.ones(q, bool)
                    cand = pend & maybe_t
                    self.stats["bloom_skips"] += int((pend & ~maybe_t).sum())
                    if not cand.any():
                        continue
                    idx = np.flatnonzero(cand)
                    f, v = table.search(keys[idx])
                    hit = idx[f]
                    vals[hit] = v[f]
                    resolved[hit] = True
        found = resolved & (vals != TOMBSTONE)
        vals = np.where(found, vals, 0).astype(np.int32)
        return found, vals

    def _scan_runs(self, lo: int, hi: int) -> list[tuple[np.ndarray,
                                                         np.ndarray]]:
        """Per-run ``[lo, hi)`` windows, NEWEST first (active memtable,
        sealed memtables newest-first, then the read view's tables) —
        the age order the k-way merge dedups by.  Empty windows are
        dropped."""
        runs: list[tuple[np.ndarray, np.ndarray]] = []
        for mt in (self.active, *reversed(self.sealed)):
            ks, vs = mt.scan_range(lo, hi)
            if len(ks):
                runs.append((ks, vs))
        for table in self._read_view().tables:
            ks, vs = table.scan_range(lo, hi)
            if len(ks):
                runs.append((ks, vs))
        return runs

    def scan_range(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        """Newest-wins range scan: sorted (keys, values) arrays for
        ``lo <= key < hi``, resolved across all live runs in one k-way
        merge (vs the seed's per-table Python dict replay).

        Thread-safe: the run-window snapshot (``_scan_runs`` — the part
        that reads ``_order`` and the live memtables) runs under
        ``lock()``; the k-way merge itself runs OUTSIDE it, because the
        captured windows are (copies of, or views into) immutable
        arrays — sealed-memtable caches and SSTable host mirrors stay
        valid and unchanged even if a concurrent merge retires their
        tables — so a large scan never extends the pump's lock-hold
        tail."""
        with self._rlock:
            runs = self._scan_runs(lo, hi)
        if not runs:
            return np.empty(0, np.uint32), np.empty(0, np.int32)
        if len(runs) == 1:
            # copy: the windows are views into live run storage (sealed
            # caches / host mirrors), which callers must not alias.
            # Tombstones are filtered like any other scan result.
            ks, vs = drop_tombstones(runs[0][0], runs[0][1])
            return ks.copy(), vs.copy()
        # the backend fuses tombstone filtering into its merge (kernel:
        # the compaction mask; host: drop_tombstones on the merged run)
        return self.backend.scan_merge(runs, drop_value=int(TOMBSTONE))

    def scan_runs(self, lo: int, hi: int) -> list[tuple[np.ndarray,
                                                        np.ndarray]]:
        """Locked snapshot of the per-run ``[lo, hi)`` windows, newest
        first (the k-way merge's age order), merge NOT applied.  The
        fleet router gathers these across shards into ONE flat k-way
        merge instead of merging per shard and re-merging the gather —
        half the sort work for a fan-out scan.  The returned windows are
        immutable snapshots (sealed caches / host mirrors) but may alias
        live storage: callers must not write through them."""
        with self._rlock:
            return self._scan_runs(lo, hi)

    def scan_range_dict(self, lo: int, hi: int) -> dict[int, int]:
        """Dict-compat wrapper over ``scan_range`` (the seed's contract)."""
        ks, vs = self.scan_range(lo, hi)
        return dict(zip(ks.tolist(), vs.tolist()))

    _merge_kway_host = staticmethod(merge_kway_host)

    # ------------------------------------------------------- background I/O
    def pump(self, budget_entries: int) -> int:
        """Advance background work by ``budget_entries`` of write I/O.
        Flushes take strict priority; the remainder goes to merges per the
        scheduler's allocation.  Returns entries actually written.

        Flushes are atomic (one SSTable build per sealed memtable), so a
        flush larger than the remaining budget overshoots the quantum —
        the overshoot is carried as a DEBT repaid from subsequent quanta
        before any new work, so the long-run delivered bandwidth matches
        the configured budget even when the pacing quantum is smaller than
        a memtable (the seed spent the overshoot for free, which made the
        I/O budget knob a no-op for flush-bound workloads at fine
        quanta)."""
        with self._rlock:
            return self._pump_locked(budget_entries)

    def _pump_locked(self, budget_entries: int) -> int:
        spent = 0
        self.now += 1.0
        # every pump is an fsync-epoch boundary: sync the WAL first so
        # its traffic lands in _flush_debt and is repaid below, ahead of
        # flushes/merges — durability shares the bandwidth budget
        self._wal_sync()
        # 0. repay flush overshoot from previous quanta
        repay = min(self._flush_debt, budget_entries)
        self._flush_debt -= repay
        spent += repay
        # 1. flushes
        while self.sealed and spent < budget_entries:
            self._fault("pre-flush")
            mt = self.sealed.pop(0)
            keys, vals = mt.seal()
            table = SSTable.build(keys, vals,
                                  level=self.policy.flush_target_level(),
                                  created_at=self.now,
                                  interpret=self.interpret)
            self._bind_table(table)
            self.stats["flushes"] += 1
            self.stats["flush_bytes"] += len(keys) * ENTRY_BYTES
            cost = len(keys)
            avail = budget_entries - spent
            if cost > avail:
                self._flush_debt += cost - avail
                spent = budget_entries
            else:
                spent += cost
            self._collect_merges()
        if spent >= budget_entries:
            self._refresh_stall()
            return spent
        # 2. merges, per scheduler allocation.  Quanta are apportioned by
        # largest remainder (``scheduler.apportion_largest_remainder``,
        # shared with the fleet's GlobalBudgetArbiter): sub-1 fair shares
        # must not starve, and the quanta never exceed ``remaining``.
        self._collect_merges()
        ops = [rm.op for rm in self.running.values()]
        alloc = self.scheduler.allocate(ops) if ops else {}
        remaining = budget_entries - spent
        shares = sorted((op_id, frac) for op_id, frac in alloc.items()
                        if frac > 0)
        if shares and remaining > 0:
            quanta = apportion_largest_remainder(shares, remaining)
            for (op_id, _), quantum in zip(shares, quanta):
                if quantum > 0:
                    spent += self._advance_merge(self.running[op_id],
                                                 quantum)
            assert spent <= budget_entries, \
                "merge quanta exceeded the pump budget"
        self._refresh_stall()
        return spent

    def _bind_table(self, table: SSTable) -> None:
        """Register a freshly built run as the globally NEWEST table:
        stamp it, enter it into the scheduling plane and the read plane
        (prepend to ``_order`` — O(1) rank — and journal the filter-stack
        add).  The flush path binds through here; benchmarks use it to
        inject preloaded runs with flush-identical semantics."""
        self._stamp += 1
        table.data_stamp = self._stamp
        table.component.stamp = float(self._stamp)
        self.tree.add(table.component)
        self.tables[table.component.cid] = table
        self._order.insert(0, table)
        self._fstack.note_add(table)
        self._invalidate_view()

    def drain(self, budget_entries: int = 1 << 30, max_pumps: int = 10_000):
        """Pump until no background work remains (tests/shutdown)."""
        with self._rlock:
            for _ in range(max_pumps):
                self._collect_merges()
                if not self.sealed and not self.running:
                    break
                self.pump(budget_entries)

    def _collect_merges(self):
        for op in self.policy.collect_merges(self.tree, self.now):
            inputs = [self.tables[c.cid] for c in op.inputs]
            self.running[op.op_id] = _RunningMerge(op=op, inputs=inputs)

    # -- merge execution (the paper's unit of schedulable I/O) ---------------
    def _open_merge(self, rm: _RunningMerge):
        """Set up the streaming cursor: sort inputs newest-first (the
        k-way age order — data_stamp is the data-age order; on equal
        stamps the LOWER level holds the newer version) and zero the
        per-run cursors.  No merged output is computed here: each quantum
        merges only its own window."""
        rm.tables = sorted(rm.inputs, key=self._order_key)
        rm.drop = self._tombstone_drop_safe(rm)
        hosts = [t._host() for t in rm.tables]
        rm.run_keys = [h[0] for h in hosts]
        rm.run_vals = [h[1] for h in hosts]
        rm.lens = np.array([len(k) for k in rm.run_keys], np.int64)
        rm.cursors = np.zeros(len(rm.tables), np.int64)
        # preallocate the output ONCE (dedup can only shrink it): each
        # quantum writes its window into the next buffer slice, and
        # ``_finish_merge`` binds ``buf[:emitted]`` views — the finish
        # step never concatenates or copies the merged output
        cap = int(rm.lens.sum())
        rm.buf_keys = np.empty(cap, np.uint32)
        rm.buf_vals = np.empty(cap, np.int32)

    def _tombstone_drop_safe(self, rm: _RunningMerge) -> bool:
        """May this merge reclaim tombstones?  Safe iff NO live table
        OLDER than the merge's output overlaps its key range — then a
        tombstone winner shadows nothing, so dropping it (and the data
        versions it already shadowed via dedup) changes no read.  Checked
        once at merge open against the authoritative ``_order``; tables
        born later are NEWER than the output, so the decision cannot be
        invalidated mid-merge."""
        in_cids = {t.component.cid for t in rm.inputs}
        out_key = (-max(t.data_stamp for t in rm.inputs),
                   rm.op.output_level)
        lo = min(t.component.key_lo for t in rm.inputs)
        hi = max(t.component.key_hi for t in rm.inputs)
        for t in self._order:
            if t.component.cid in in_cids:
                continue
            if self._order_key(t) > out_key and \
                    t.component.key_lo < hi and lo < t.component.key_hi:
                return False
        return True

    def _merge_cut(self, rm: _RunningMerge,
                   target: int) -> tuple[np.ndarray, int]:
        """The merge-path pivot: the largest key-boundary cut whose
        remaining input entries number at most ``target`` (binary search
        for the pivot key over the uint32 key space; per-run window ends
        via ``searchsorted`` on the host mirrors, so only O(k log n)
        entries are touched).  Cutting at a key boundary means no
        equal-key group straddles windows — per-window newest-wins dedup
        composes to the one-shot result.  When even the first key group
        exceeds ``target`` (up to k duplicates of one key), that group is
        taken whole as forced minimal progress: it emits exactly one
        entry.  Returns ``(stops, consumed)``."""
        cur, lens, ks = rm.cursors, rm.lens, rm.run_keys
        rem = int((lens - cur).sum())
        if rem <= target:
            return lens.copy(), rem

        def below(p: int) -> int:
            c = 0
            for i, k in enumerate(ks):
                if cur[i] < lens[i]:
                    c += max(0, int(np.searchsorted(k, np.uint32(p)))
                             - int(cur[i]))
            return c

        lo, hi = 0, 0xFFFFFFFF      # sentinel key never stored: p covers all
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if below(mid) <= target:
                lo = mid
            else:
                hi = mid - 1
        stops = np.array(
            [min(int(lens[i]),
                 max(int(cur[i]), int(np.searchsorted(ks[i],
                                                      np.uint32(lo)))))
             for i in range(len(ks))], np.int64)
        consumed = int((stops - cur).sum())
        if consumed == 0:
            # forced progress: the whole first key group (<= k entries)
            nxt = min(int(ks[i][cur[i]]) for i in range(len(ks))
                      if cur[i] < lens[i])
            stops = np.array(
                [min(int(lens[i]),
                     max(int(cur[i]),
                         int(np.searchsorted(ks[i], np.uint32(nxt),
                                             side="right"))))
                 for i in range(len(ks))], np.int64)
            consumed = int((stops - cur).sum())
        return stops, consumed

    def _advance_merge(self, rm: _RunningMerge, quantum: int) -> int:
        """Advance one merge by ~``quantum`` output entries: cut the next
        window at a global key boundary and merge ONLY that window, so
        the work (and lock-hold time) under a live ``BackgroundDriver``
        is O(quantum + k), never O(total merge size).  Emitted entries
        (post-dedup) are what the budget is charged for, matching the
        paper's written-bytes accounting; heavy dedup therefore spends
        less than the allocated quantum rather than overshooting it."""
        self._fault("mid-merge-quantum")
        if not self.streaming_merge:
            return self._advance_merge_oneshot(rm, quantum)
        if rm.tables is None:
            self._open_merge(rm)
        if int((rm.lens - rm.cursors).sum()) == 0:
            self._finish_merge(rm)
            return 0
        starts = rm.cursors
        stops, consumed = self._merge_cut(rm, quantum)
        drop = int(TOMBSTONE) if rm.drop else None
        if rm.drop:
            # count reclaimed markers window-by-window (O(consumed)) so
            # ``_finish_merge`` never re-scans the full inputs
            rm.tombs_in += sum(
                int((rm.run_vals[i][starts[i]:stops[i]]
                     == TOMBSTONE).sum())
                for i in range(len(rm.tables)))
        wk, wv, dev = self.backend.merge_kway_window(
            list(zip(rm.run_keys, rm.run_vals)),
            starts.tolist(), stops.tolist(), drop_value=drop,
            runs_dev=lambda: [(t.keys, t.vals) for t in rm.tables])
        take = len(wk)
        assert take <= max(quantum, 1), "window emitted beyond its quantum"
        rm.cursors = stops
        rm.buf_keys[rm.emitted:rm.emitted + take] = wk
        rm.buf_vals[rm.emitted:rm.emitted + take] = wv
        self._accumulate_device(rm, dev, take)
        rm.emitted += take
        rm.op.written += take
        self.stats["merge_bytes"] += take * ENTRY_BYTES
        self.stats["merge_touched"] += consumed
        if int((rm.lens - rm.cursors).sum()) == 0:
            self._finish_merge(rm)
        return take

    def _accumulate_device(self, rm: _RunningMerge, dev, take: int) -> None:
        """Fold a kernel window's device-resident output into the merge's
        device accumulation buffer (allocated lazily at 2x output
        capacity so a pow2-padded window never clamps over earlier data;
        the pad tail is overwritten by the next window or sliced off at
        finish).  One host-mode window drops the buffer for good — the
        finished table then falls back to lazy upload on first kernel
        use, which is exactly what a host-merged table wants anyway."""
        if not rm.dev_ok:
            return
        if dev is None or _write_window is None:
            rm.dev_keys = rm.dev_vals = None
            rm.dev_ok = False
            return
        if take == 0:
            return
        if rm.dev_keys is None:
            cap = 2 * max(int(rm.lens.sum()), 1)
            rm.dev_keys = jnp.zeros(cap, jnp.uint32)
            rm.dev_vals = jnp.zeros(cap, jnp.int32)
        dk, dv = dev
        pad = _next_pow2(take) - take
        if pad:
            dk = jnp.pad(dk, (0, pad))
            dv = jnp.pad(dv, (0, pad))
        rm.dev_keys = _write_window(rm.dev_keys, dk, rm.emitted)
        rm.dev_vals = _write_window(rm.dev_vals, dv, rm.emitted)

    def _materialize_merge(self, rm: _RunningMerge):
        """LEGACY one-shot path (``streaming_merge=False``; kept as the
        measured baseline in ``benchmarks/latency_tail.py`` and the
        streaming differential tests): compute the full merged run at the
        first quantum — an unbounded compute spike under the engine lock,
        which is exactly the cliff the streaming cursor removes."""
        self.stats["merge_touched"] += sum(len(t) for t in rm.inputs)
        tables = sorted(rm.inputs, key=self._order_key)
        rm.drop = self._tombstone_drop_safe(rm)
        drop = int(TOMBSTONE) if rm.drop else None
        if rm.drop:
            rm.tombs_in = sum(int((t._host()[1] == TOMBSTONE).sum())
                              for t in rm.inputs)
        mk, mv, _ = self.backend.merge_kway(
            [t._host() for t in tables], drop_value=drop,
            runs_dev=lambda: [(t.keys, t.vals) for t in tables])
        rm.merged_keys, rm.merged_vals = mk, mv

    def _advance_merge_oneshot(self, rm: _RunningMerge, quantum: int) -> int:
        if rm.merged_keys is None:
            self._materialize_merge(rm)
        total = len(rm.merged_keys)
        take = min(quantum, total - rm.cursor)
        if take > 0:
            # the merged run is already materialized whole; the cursor
            # only paces budget charging — finish binds it directly
            rm.cursor += take
            rm.op.written += take
            self.stats["merge_bytes"] += take * ENTRY_BYTES
        if rm.cursor >= total:
            self._finish_merge(rm)
        return max(take, 0)

    def _finish_merge(self, rm: _RunningMerge):
        # O(1) output binding: the streaming path binds VIEWS into the
        # preallocated buffers (no concatenate, no copy — pinned in
        # tests/test_backend.py); the one-shot baseline binds its
        # materialized arrays directly.
        if rm.buf_keys is not None:
            keys = rm.buf_keys[:rm.emitted]
            vals = rm.buf_vals[:rm.emitted]
        elif rm.merged_keys is not None:
            keys, vals = rm.merged_keys, rm.merged_vals
        else:  # finished before any quantum ran (all-empty inputs)
            keys = np.empty(0, np.uint32)
            vals = np.empty(0, np.int32)
        dev_pair = None
        if rm.dev_ok and rm.dev_keys is not None:
            # ONE device slice binds the accumulated kernel output — the
            # finished table adopts it, so the merge→flush→probe plane
            # never re-uploads what a kernel already produced on device
            dev_pair = (rm.dev_keys[:rm.emitted],
                        rm.dev_vals[:rm.emitted])
        stamp = max(t.data_stamp for t in rm.inputs)
        if rm.drop:
            # every input tombstone died here: winners to the drop mask,
            # shadowed ones to dedup — the count was accumulated window-
            # by-window (O(consumed) per quantum, never an input re-scan)
            self.stats["tombstones_dropped"] += rm.tombs_in
        # keep the policy's metadata model in sync with the real output size
        rm.op.output_size = float(len(keys))
        rm.op.written = float(len(keys))
        in_cids = {c.cid for c in rm.op.inputs}
        for cid in in_cids:
            self.tables.pop(cid, None)
            self._fstack.note_remove(cid)
        self._order = [t for t in self._order
                       if t.component.cid not in in_cids]
        outs = self.policy.complete_merge(self.tree, rm.op, self.now)
        # partitioned policies may split the output into several files
        def _bind(comp, ks, vs, dev=None):
            table = SSTable.build(ks, vs, level=comp.level,
                                  created_at=self.now,
                                  interpret=self.interpret, dev=dev)
            table.component = comp
            table.data_stamp = stamp
            comp.stamp = float(stamp)
            # keep the scheduling-plane range metadata honest: the policy's
            # overlap selection must see the REAL key span, else adjacent-
            # level overlaps are missed and newest-wins breaks.  An empty
            # output file spans nothing — an empty range keeps its stale
            # stamp from shadowing future merges in the policy's
            # age-safety audit.
            if len(ks):
                comp.key_lo = float(ks[0]) / 2**32
                comp.key_hi = (float(ks[-1]) + 1) / 2**32
            else:
                comp.key_lo = comp.key_hi = 0.0
            self.tables[comp.cid] = table

        if len(outs) == 1:
            _bind(outs[0], keys, vals, dev_pair)
        else:
            # contiguous slice VIEWS at np.array_split's boundaries (the
            # historical split), not index-gather copies; the device
            # accumulation (when live) splits at the same boundaries
            n = max(len(outs), 1)
            sizes = np.full(n, len(keys) // n, np.int64)
            sizes[:len(keys) % n] += 1
            bounds = np.concatenate([[0], np.cumsum(sizes)])
            for j, comp in enumerate(outs):
                lo, hi = int(bounds[j]), int(bounds[j + 1])
                dv = (dev_pair[0][lo:hi], dev_pair[1][lo:hi]) \
                    if dev_pair is not None else None
                _bind(comp, keys[lo:hi], vals[lo:hi], dv)
        # bisect-insert the outputs at their (-stamp, level) rank: all
        # outputs of one merge share the rank (same stamp, same level)
        # and hold disjoint key ranges, so inserting them adjacently
        # keeps the newest-first order without a full re-sort
        out_tables = [self.tables[c.cid] for c in outs]
        if out_tables:          # a policy may complete a merge to nothing
            pos = bisect.bisect_left(self._order,
                                     self._order_key(out_tables[0]),
                                     key=self._order_key)
            self._order[pos:pos] = out_tables
        for t in out_tables:
            self._fstack.note_add(t)
        self.running.pop(rm.op.op_id, None)
        self._invalidate_view()
        self.stats["merges"] += 1
        self._collect_merges()

    # ------------------------------------------------------------------ info
    def lock(self) -> threading.RLock:
        """The engine's reentrant lock (see module docstring): the
        ``BackgroundDriver`` holds it around ``pump``; foreground callers
        sharing an engine with a driver must hold it around every engine
        call (``with engine.lock(): ...``)."""
        return self._rlock

    def num_components(self) -> int:
        with self._rlock:
            return self.tree.num_components()

    def total_entries(self) -> int:
        with self._rlock:
            return sum(len(t) for t in self.tables.values()) + \
                sum(len(m) for m in self.sealed) + len(self.active)

    def pending_background_entries(self) -> int:
        """Background I/O debt in entries: outstanding flush-quantum debt,
        sealed memtables awaiting flush, and the unconsumed inputs of
        every running merge (plus merges the policy would start right
        now).  This is the per-shard 'pending debt' the fleet's
        ``GlobalBudgetArbiter`` apportions the global budget by."""
        with self._rlock:
            self._collect_merges()
            pending = self._flush_debt + sum(len(m) for m in self.sealed)
            for rm in self.running.values():
                if rm.lens is not None:       # streaming cursor open
                    pending += int((rm.lens - rm.cursors).sum())
                elif rm.merged_keys is not None:   # one-shot materialized
                    pending += len(rm.merged_keys) - rm.cursor
                else:
                    pending += sum(len(t) for t in rm.inputs)
            return pending

    # ----------------------------------------------- durability lifecycle
    @property
    def flushed_lsn(self) -> int:
        """First LSN NOT yet captured in on-disk SSTables — the WAL
        replay origin a snapshot records.  Memtables are flushed FIFO and
        filled in LSN order, so everything below the oldest unflushed
        memtable's ``start_lsn`` lives in SSTables."""
        return self.sealed[0].start_lsn if self.sealed \
            else self.active.start_lsn

    def snapshot(self, store) -> dict:
        """Persist the durable view: fsync the WAL, save every live
        SSTable plus metadata atomically through ``store``
        (``checkpoint.EngineSnapshotStore``), then drop WAL frames whose
        entries are all captured by the saved tables.  Returns the
        manifest dict."""
        with self._rlock:
            self._wal_sync()
            manifest = store.save(self)
            if self.wal is not None:
                self.wal.truncate_upto(self.flushed_lsn)
            return manifest

    def restore_tables(self, tables, snap: dict) -> int:
        """Rebuild the read view from a snapshot (the recovery path):
        re-bind each saved run at its recorded (stamp, level) rank —
        ``_order`` re-sorts once, the filter stack rebuilds lazily on the
        first probe — and restore the clocks.  Returns the snapshot's
        ``flushed_lsn`` (the WAL replay origin)."""
        with self._rlock:
            for keys, vals, meta in tables:
                t = SSTable.build(keys, vals, level=int(meta["level"]),
                                  created_at=float(meta["created_at"]),
                                  interpret=self.interpret)
                t.data_stamp = int(meta["stamp"])
                t.component.stamp = float(meta["stamp"])
                self.tree.add(t.component)
                self.tables[t.component.cid] = t
                self._order.append(t)
            self._order.sort(key=self._order_key)
            self._stamp = max(self._stamp, int(snap.get("stamp", 0)),
                              max((t.data_stamp for t in self._order),
                                  default=0))
            self.now = max(self.now, float(snap.get("now", 0.0)))
            self._invalidate_view()
            return int(snap.get("flushed_lsn", 0))

    def begin_replay(self, lsn: int) -> None:
        """Position the engine at WAL offset ``lsn`` before replay: the
        next admitted entry (via ``replay_admit``) is entry ``lsn`` of
        the admitted-write history."""
        with self._rlock:
            self._lsn = int(lsn)
            self.active.start_lsn = self._lsn

    def replay_admit(self, keys, vals) -> int:
        """Recovery-only admission: entries already durable in the WAL
        re-enter the memtable plane WITHOUT re-logging and WITHOUT
        constraint stalls (recovery must not deadlock on a shape
        constraint mid-rebuild).  Callers size chunks to the active
        memtable's room (``RecoverySession`` does)."""
        keys = np.asarray(keys, np.uint32)
        vals = np.asarray(vals, np.int32)
        with self._rlock:
            if self.active.full:
                self.seal_active()
            took = self.active.put_batch(keys, vals)
            assert took == len(keys), "replay chunk exceeded memtable room"
            self._lsn += took
            self.stats["replayed"] += took
            return took

    def compact_all(self, budget_per_pump: int = 1 << 30) -> None:
        """Force-merge the whole store into one bottom run: flush every
        memtable, drain policy merges, then merge ALL live tables to the
        deepest level in one op — no older run can overlap it, so every
        tombstone is reclaimed.  This is the space-amp floor the
        durability tests pin (delete-all then compact_all -> ~0 live
        entries)."""
        with self._rlock:
            if len(self.active):
                self.seal_active()
            self.drain(budget_per_pump)
            live = list(self._order)
            if not live:
                return
            if len(live) == 1 and \
                    int((live[0]._host()[1] == TOMBSTONE).sum()) == 0:
                return            # already one run with nothing to drop
            comps = [t.component for t in live]
            op = MergeOp(inputs=comps,
                         output_level=max(self.tree.max_level(),
                                          max(c.level for c in comps)),
                         output_size=float(sum(len(t) for t in live)))
            self.running[op.op_id] = _RunningMerge(op=op, inputs=live)
            self.drain(budget_per_pump)

    def live_entries(self) -> int:
        """Distinct keys whose newest version is NOT a tombstone — the
        logical data size behind ``space_amp`` (an O(n) full-range
        scan)."""
        return int(len(self.scan_range(0, 0xFFFFFFFF)[0]))

    def amplification(self) -> dict:
        """Write/space amplification snapshot (see
        ``metrics.amplification_stats``): bytes written by flush + merge
        + WAL over logical bytes ingested, and physical entries stored
        over live entries."""
        from .metrics import amplification_stats
        with self._rlock:
            return amplification_stats(self.stats,
                                       physical_entries=self.total_entries(),
                                       live_entries=self.live_entries())

    def close(self) -> None:
        """Graceful shutdown: fsync and release the WAL (no-op without
        one).  The engine object stays readable afterwards; only the
        durability plane is closed."""
        with self._rlock:
            if self.wal is not None:
                self.wal.close()

    def __enter__(self) -> "LSMEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BackgroundDriver:
    """Wall-clock driver: pumps an engine at ``bandwidth_bytes_per_s`` on a
    daemon thread (the serving/ingestion examples use this; tests use
    pump() directly)."""

    def __init__(self, engine: LSMEngine, bandwidth_bytes_per_s: float,
                 quantum_s: float = 0.01):
        self.engine = engine
        self.rate = bandwidth_bytes_per_s
        self.quantum_s = quantum_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # the ENGINE's lock, not a private one: a driver-private lock
        # guards nothing, because foreground put/get/scan calls never
        # took it and raced the pump thread.  Sharing engine.lock()
        # makes `with engine.lock():` on the foreground path exclude
        # the pump.
        self._lock = engine.lock()

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        # Pace by monotonic elapsed time, carrying the undelivered-entry
        # deficit across iterations.  The seed computed one fixed
        # per-quantum budget and slept quantum_s per loop, so every source
        # of iteration overrun — pump compute, lock contention with the
        # foreground, sleep overshoot — silently shrank the delivered
        # bandwidth below the configured budget (the knob every experiment
        # in the paper turns).  Here the budget owed is always
        # elapsed * rate, so slow iterations are repaid by larger quanta.
        t0 = time.monotonic()
        delivered = 0.0                # entry quanta offered to pump()
        per_s = self.rate / ENTRY_BYTES
        # cap each catch-up quantum: an unbounded one would grow with
        # every slow pump (bigger quantum -> longer lock hold -> bigger
        # deficit), starving the foreground in ever-larger bursts.  The
        # residual deficit still carries, so a temporarily slow pump is
        # repaid at up to 4x pace; a persistently slow one is genuine
        # saturation the budget cannot force through.
        q_max = max(1, int(4 * per_s * self.quantum_s))
        while not self._stop.is_set():
            deficit = (time.monotonic() - t0) * per_s - delivered
            quantum = min(int(deficit), q_max)
            if quantum >= 1:
                with self._lock:
                    self.engine.pump(quantum)
                delivered += quantum
            self._stop.wait(self.quantum_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
            self._thread = None

    def close(self) -> None:
        """Graceful shutdown: stop the pump thread (any in-flight quantum
        completes under the engine lock before ``stop`` returns), then
        close the engine's durability plane (WAL fsync).  Idempotent."""
        self.stop()
        self.engine.close()

    def __enter__(self) -> "BackgroundDriver":
        if self._thread is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()
