"""Gradient compression with error feedback (distributed-optimization
trick for bandwidth-constrained meshes).

int8 block-quantization: each gradient is scaled per block of 256
values, rounded to int8, and the quantization error is carried into the
next step's gradient (error feedback keeps SGD-style convergence).  On
hardware this halves-to-quarters the reduce-scatter volume; here the
quantize/dequantize pair is exact-shape so the train step can flip it on
with one flag, and the roofline's collective term shows the delta.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x, m):
    n = x.size
    pad = (-n) % m
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat, n


def quantize_int8(g):
    """g -> (q int8, scale f32 per block).  Lossy; pair with dequantize."""
    flat, n = _pad_to(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def dequantize_int8(q, scale, n, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compress_grads_with_feedback(grads, error_state):
    """One error-feedback round: returns (decompressed grads to apply,
    new error state).  ``error_state`` is a grads-shaped fp32 pytree."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale, n = quantize_int8(g32)
        deq = dequantize_int8(q, scale, n, g.shape, jnp.float32)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, error_state)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    new_g = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_e = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params) -> int:
    """Collective bytes if gradients were exchanged int8+scales."""
    total = 0
    for p in jax.tree.leaves(params):
        total += p.size  # int8 payload
        total += -(-p.size // BLOCK) * 4
    return total
