from .sharding import (Constrainer, default_rules, make_constrainer,
                       sharding_for, spec_for, tree_shardings)

__all__ = ["Constrainer", "default_rules", "make_constrainer",
           "sharding_for", "spec_for", "tree_shardings"]
