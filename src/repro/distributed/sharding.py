"""Logical-axis sharding: the single mapping from model-space axis names
to mesh axes (MaxText-style logical annotations, hand-rolled).

Rules are applied left-to-right per tensor dim with two hard invariants:
  1. a mesh axis is consumed at most once per tensor (no double-sharding);
  2. a dim is sharded only if its size is divisible by the product of the
     mapped mesh axes (small archs fall back to replication per-dim —
     e.g. smollm's 9 q-heads on a 16-way model axis stay replicated while
     its FFN/vocab dims still tensor-parallelize).

The same tables drive parameters, optimizer state, activations and KV
caches, so resharding points are fully determined by this file.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def default_rules(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    """Logical axis -> mesh axes.  ``pod`` is present only multi-pod."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = ("model",) if "model" in mesh.axis_names else ()
    return {
        # data / batch
        "batch": fsdp,
        # tensor-parallel families
        "vocab": model,
        "q_heads": model,
        "kv_heads": model,
        "ffn": model,
        "experts": model,
        "ssm_inner": model,
        "ssm_heads": model,
        # fully-sharded parameter axis (ZeRO-3)
        "embed": fsdp,
        # serving
        "cache_seq": model,
        # sequence-parallel residual activations (opt-in per config)
        "seq_act": model,
        # never sharded
        "layers": (),
        "head_dim": (),
    }


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names], dtype=np.int64)) if names else 1


def spec_for(mesh: Mesh, rules: dict, shape: tuple, axes: tuple) -> P:
    """Resolve one tensor's PartitionSpec from its logical axes."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        entry: tuple[str, ...] = ()
        if name:
            cand = tuple(rules.get(name, ()))
            if cand and not (set(cand) & used):
                if dim % _axis_size(mesh, cand) == 0:
                    entry = cand
        used |= set(entry)
        out.append(entry if entry else None)
    # trailing dims beyond the axes tuple stay replicated
    out += [None] * (len(shape) - len(axes))
    return P(*[e if e is None else (e if len(e) > 1 else e[0]) for e in out])


def sharding_for(mesh: Mesh, rules: dict, shape: tuple, axes: tuple
                 ) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, rules, shape, axes))


def tree_shardings(mesh: Mesh, rules: dict, tree, axes_tree):
    """Pytree of NamedShardings from matching (values, logical-axes) trees.

    ``axes_tree`` leaves are tuples of logical names; value leaves provide
    shapes (arrays or ShapeDtypeStructs)."""
    def one(leaf, axes):
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        if axes is None:
            axes = ()
        return sharding_for(mesh, rules, tuple(shape), tuple(axes))

    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


class Constrainer:
    """Model-injectable ``sh(tensor, logical_axes)`` hook.

    Carries ``mesh``/``rules`` so modules that need explicit collectives
    (the expert-parallel MoE shard_map) can discover the mesh without a
    separate plumbing path."""

    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = rules

    def __call__(self, x, axes):
        spec = spec_for(self.mesh, self.rules, tuple(x.shape), tuple(axes))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def make_constrainer(mesh: Mesh, rules: dict) -> Constrainer:
    return Constrainer(mesh, rules)


def tree_logical_to_shardings(mesh: Mesh, rules: dict, abstract_tree,
                              axes_tree):
    """Shardings for a tree given abstract leaves (dry-run entrypoint)."""
    return tree_shardings(mesh, rules, abstract_tree, axes_tree)
