"""Optimizers as pure pytree transforms (no external deps).

* AdamW — fp32 first/second moments (the <=100B-class default).
* Adafactor — factored fp32 second moment + optional bf16 momentum; the
  340B/405B/1T configs use it so optimizer state fits v5e HBM (the
  factored state is ~sqrt of Adam's).

Optimizer state carries the SAME logical sharding axes as its parameter
(factored Adafactor rows/cols inherit the parameter's respective dims),
so ZeRO-3-style state sharding falls out of the sharding tables for free.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


def _tmap(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"step": jnp.zeros((), jnp.int32),
            "m": _tmap(zeros32, params),
            "v": _tmap(zeros32, params)}


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = _tmap(upd, params, grads, state["m"], state["v"])
    new_p = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = _tmap(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"step": step, "m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params, *, momentum: bool = False):
    def one(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            st = {"row": row, "col": col}
        else:
            st = {"v": jnp.zeros(p.shape, jnp.float32)}
        if momentum:
            st["m"] = jnp.zeros(p.shape, jnp.bfloat16)
        return st

    return {"step": jnp.zeros((), jnp.int32),
            "slots": _tmap(one, params)}


def adafactor_update(params, grads, state, lr, *, decay=0.8, eps=1e-30,
                     clip=1.0, weight_decay=0.0, momentum: bool = False,
                     b1=0.9):
    step = state["step"] + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -decay

    def upd(p, g, st):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            row = beta * st["row"] + (1 - beta) * g2.mean(axis=-1)
            col = beta * st["col"] + (1 - beta) * g2.mean(axis=-2)
            rmean = row.mean(axis=-1, keepdims=True)
            rfac = row / jnp.maximum(rmean, eps)
            u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(col)[..., None, :])
            new = {"row": row, "col": col}
        else:
            v = beta * st["v"] + (1 - beta) * g2
            u = g / jnp.sqrt(v)
            new = {"v": v}
        # update clipping (RMS(u) <= clip)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        if momentum:
            m = b1 * st["m"].astype(jnp.float32) + (1 - b1) * u
            new["m"] = m.astype(jnp.bfloat16)
            u = m
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new

    out = _tmap(upd, params, grads, state["slots"])
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and \
        isinstance(x[1], dict)
    new_p = _tmap(lambda o: o[0], out, is_leaf=is_pair)
    new_s = _tmap(lambda o: o[1], out, is_leaf=is_pair)
    return new_p, {"step": step, "slots": new_s}


# ---------------------------------------------------------------------------
# Dispatch + sharding axes for optimizer state
# ---------------------------------------------------------------------------
def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return (functools.partial(adafactor_init, momentum=False),
                functools.partial(adafactor_update, momentum=False))
    raise ValueError(f"unknown optimizer {name!r}")


def opt_state_logical_axes(name: str, params_axes, params_abstract):
    """Logical axes for the optimizer state, mirroring the parameters."""
    if name == "adamw":
        return {"step": (), "m": params_axes, "v": params_axes}

    def one(axes, p):
        axes = tuple(axes)
        if _factored(p.shape):
            return {"row": axes[:-1], "col": axes[:-2] + axes[-1:]}
        return {"v": axes}

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    slots = jax.tree.map(one, params_axes, params_abstract, is_leaf=is_axes)
    return {"step": (), "slots": slots}
