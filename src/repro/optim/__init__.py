from .optimizers import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, make_optimizer, opt_state_logical_axes)
from .schedules import cosine_schedule, linear_warmup

__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "make_optimizer", "opt_state_logical_axes",
           "cosine_schedule", "linear_warmup"]
