"""Shared neural-net layers (functional, pytree params).

Everything here is pure jnp + lax so the dry-run lowers through XLA on
any backend.  The attention entry point mirrors the Pallas flash kernel's
online-softmax math (kernels/attention) — a KV-blocked ``lax.scan`` keeps
live memory O(S * block) instead of O(S^2), which is what lets the 32k
prefill cells compile within v5e HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# -- norms -------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def norm(kind: str, x, scale):
    return rms_norm(x, scale) if kind == "rmsnorm" else layer_norm(x, scale)


# -- activations --------------------------------------------------------------
def activate(kind: str, gate, up=None):
    """GLU-style activations take (gate, up); plain ones take a single arg."""
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    if kind == "relu2":
        r = jax.nn.relu(gate)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(gate, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def is_glu(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# -- rotary embeddings ----------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)          # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d)


def flash_attention_jnp(q, k, v, *, causal: bool = True,
                        prefix_len: int = 0, q_offset: int = 0,
                        block: int = 1024, q_block: int = 1024,
                        causal_skip: bool = False):
    """Q- and KV-blocked online-softmax attention.

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D).  ``prefix_len`` marks a
    bidirectional prefix (prefix-LM / VLM image tokens); ``q_offset`` is
    the absolute position of q[0] (chunked prefill).  Matches
    ``kernels/attention`` math; lives here so dry-runs lower pure XLA.
    The live score tile is (B, H, q_block, block) regardless of Sq/Sk.

    ``causal_skip`` unrolls the q-chunk loop in Python so each chunk only
    touches kv[:chunk_end] — the triangular schedule the Pallas kernel
    gets from ``pl.when``, here traded against a ~nq-times-larger layer
    HLO.  Halves causal-attention flops/traffic (§Perf lever).
    """
    B, H, Sq, D = q.shape
    if Sq > q_block:
        nq = -(-Sq // q_block)
        qpad = nq * q_block - Sq
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0))) if qpad else q
        if causal_skip and causal:
            outs = []
            for qi in range(nq):
                q_i = qp[:, :, qi * q_block:(qi + 1) * q_block]
                hi = min(q_offset + (qi + 1) * q_block, k.shape[2])
                hi = max(hi, prefix_len)
                hi = -(-hi // block) * block
                hi = min(hi, -(-k.shape[2] // block) * block, k.shape[2])
                outs.append(flash_attention_jnp(
                    q_i, k[:, :, :hi], v[:, :, :hi], causal=causal,
                    prefix_len=prefix_len,
                    q_offset=q_offset + qi * q_block, block=block,
                    q_block=q_block))
            out = jnp.concatenate(outs, axis=2)
            return out[:, :, :Sq]
        qs = qp.reshape(B, H, nq, q_block, D).transpose(2, 0, 1, 3, 4)

        def qstep(_, inp):
            q_i, qi = inp
            o = flash_attention_jnp(
                q_i, k, v, causal=causal, prefix_len=prefix_len,
                q_offset=q_offset + qi * q_block, block=block,
                q_block=q_block)
            return None, o

        _, outs = jax.lax.scan(qstep, None,
                               (qs, jnp.arange(nq, dtype=jnp.int32)))
        out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, nq * q_block, D)
        return out[:, :, :Sq]
    _, Hkv, Sk, _ = k.shape
    G = H // Hkv
    scale = D ** -0.5
    # grouped layout: never materialize repeated K/V (a G-fold HBM-traffic
    # tax for GQA) — the einsums carry the group dim instead.  G-MAJOR
    # head order (head = g*Hkv + kv) so a model-axis sharding of H maps
    # onto the G dim and the reshape never forces a re-gather of q.
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, G, Hkv, Sq, D)

    nb = -(-Sk // block)
    pad = nb * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, Hkv, nb, block, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nb, block, D).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inputs):
        m, l, acc = carry
        k_i, v_i, ki = inputs
        s = jnp.einsum("bghqd,bhkd->bghqk", qf, k_i,
                       preferred_element_type=jnp.float32)
        kv_pos = ki * block + jnp.arange(block)
        valid = kv_pos < Sk
        if causal:
            ok = (q_pos[:, None] >= kv_pos[None, :]) | \
                (kv_pos < prefix_len)[None, :]
            valid = valid[None, :] & ok
        else:
            valid = jnp.broadcast_to(valid[None, :], (Sq, block))
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        # all-masked rows keep m == NEG_INF; zero their probabilities
        # explicitly so exp(NEG_INF - NEG_INF) cannot leak mass.
        p = jnp.exp(s - m_new[..., None]) * \
            valid[None, None, None].astype(jnp.float32)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bghqk,bhkd->bghqd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, G, Hkv, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, Hkv, Sq), jnp.float32)
    a0 = jnp.zeros((B, G, Hkv, Sq, D), jnp.float32)
    # checkpoint the block step: backward recomputes the (Sq, block) score
    # tile from q/k instead of saving it — the flash-attention memory law.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0),
        (kb, vb, jnp.arange(nb, dtype=jnp.int32)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def decode_attention_jnp(q, k_cache, v_cache, cache_len):
    """Single-token attention over a (possibly partially filled) cache.

    q: (B, H, 1, D); caches: (B, Hkv, S, D); cache_len: valid prefix length
    (scalar int32).  Softmax/scores in fp32; invalid tail masked out.
    Grouped einsums — the cache is never materialized H/Hkv-fold.
    """
    B, H, T, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qf = q.reshape(B, G, Hkv, T, D).astype(jnp.float32)
    # one shared f32 view of the cache (measured cheaper than per-dot
    # implicit upconversion under XLA:CPU legalization; on TPU the Pallas
    # decode kernel is the native-bf16 answer)
    s = jnp.einsum("bghtd,bhkd->bghtk", qf,
                   k_cache.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(S) < cache_len
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bghtk,bhkd->bghtd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, T, D).astype(q.dtype)


# -- misc ---------------------------------------------------------------------
def softcap(logits, cap: float):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, C); w: (C, K).

    ``state``: (B, K-1, C) left context for decode; returns (y, new_state).
    """
    B, S, C = x.shape
    K = w.shape[-1]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    idx = jnp.arange(S)[:, None] + jnp.arange(K)[None, :]
    windows = xp[:, idx]                              # (B, S, K, C)
    y = jnp.einsum("bskc,ck->bsc", windows.astype(jnp.float32),
                   w.astype(jnp.float32))
    new_state = xp[:, S:]
    return jax.nn.silu(y).astype(x.dtype), new_state


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token cross-entropy; logits fp32-normalized over last axis."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean(), nll.size
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, denom
