"""Mamba2 (SSD) block — chunked scan for train/prefill, O(1) recurrent
state for decode.

Math follows state-space duality [arXiv:2405.21060] with per-head scalar
decay: ``h_t = exp(alog_t) h_{t-1} + dt_t B_t x_t^T``, ``y_t = C_t h_t +
D x_t``, ngroups=1 (B/C shared across heads).  The chunked formulation
here is the pure-jnp twin of ``kernels/ssd`` (dense intra-chunk matmuls
against a causal decay mask + an inter-chunk state carry), so dry-runs
lower pure XLA while the Pallas kernel targets TPU.

Sharding: heads (= d_inner / head_dim) carry the tensor-parallel axis;
B/C/state-dim N is small and replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, rms_norm


def _project(cfg, x, p):
    """Common projections.  x: (B, S, E) -> parts dict (pre-conv)."""
    xs = jnp.einsum("bse,ed->bsd", x, p["w_x"])
    z = jnp.einsum("bse,ed->bsd", x, p["w_z"])
    b = jnp.einsum("bse,en->bsn", x, p["w_b"])
    c = jnp.einsum("bse,en->bsn", x, p["w_c"])
    dt = jnp.einsum("bse,eh->bsh", x.astype(jnp.float32),
                    p["w_dt"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return xs, z, b, c, dt


def _gate_out(cfg, y, z, p):
    """Gated RMSNorm + output projection.  y, z: (B, S, din)."""
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_scale"])
    return jnp.einsum("bsd,de->bse", y, p["w_out"])


def ssd_chunked(x, b, c, alog, dt, chunk: int):
    """x: (B, S, Hs, P); b, c: (B, S, N); alog, dt: (B, S, Hs).

    Returns y: (B, S, Hs, P) and the final state (B, Hs, N, P).
    """
    B, S, Hs, P = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, b, c, alog, dt = map(zf, (x, b, c, alog, dt))
    nc = x.shape[1] // chunk
    xq = x.reshape(B, nc, chunk, Hs, P).transpose(1, 0, 2, 3, 4)
    bq = b.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    cq = c.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    aq = alog.reshape(B, nc, chunk, Hs).transpose(1, 0, 2, 3)
    dq = dt.reshape(B, nc, chunk, Hs).transpose(1, 0, 2, 3)

    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]
    tri = rows >= cols

    def step(state, inp):
        x_c, b_c, c_c, a_c, d_c = inp                 # (B,Q,...)
        cum = jnp.cumsum(a_c, axis=1)                 # (B,Q,Hs) fp32
        total = cum[:, -1]                            # (B,Hs)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])      # (B,Q,Q,Hs)
        cb = jnp.einsum("bqn,bsn->bqs", c_c.astype(jnp.float32),
                        b_c.astype(jnp.float32))
        m = jnp.where(tri[None, :, :, None],
                      cb[..., None] * decay * d_c[:, None, :, :], 0.0)
        y = jnp.einsum("bqsh,bshp->bqhp", m, x_c.astype(jnp.float32))
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhnp->bqhp", c_c.astype(jnp.float32), state)
        w = jnp.exp(total[:, None] - cum) * d_c       # (B,Q,Hs)
        new_state = jnp.exp(total)[..., None, None] * state + jnp.einsum(
            "bqn,bqh,bqhp->bhnp", b_c.astype(jnp.float32), w,
            x_c.astype(jnp.float32))
        return new_state, y

    state0 = jnp.zeros((B, Hs, N, P), jnp.float32)
    # checkpoint per chunk: backward recomputes the (Q, Q, Hs) decay mask
    # and score tile instead of saving them across the whole sequence.
    state, yq = jax.lax.scan(jax.checkpoint(step), state0,
                             (xq, bq, cq, aq, dq))
    y = yq.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, Hs, P)
    return y[:, :S].astype(x.dtype), state


def ssm_forward(cfg, x, p, return_state: bool = False):
    """Full-sequence Mamba2 block.  x: (B, S, E) -> (B, S, E).

    With ``return_state`` also returns the decode cache for this layer
    (SSD state + raw pre-conv tails so decode resumes exactly)."""
    B, S, E = x.shape
    Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    xs_raw, z, b_raw, c_raw, dt = _project(cfg, x, p)
    xs, _ = causal_conv1d(xs_raw, p["conv_x"])
    b, _ = causal_conv1d(b_raw, p["conv_b"])
    c, _ = causal_conv1d(c_raw, p["conv_c"])
    alog = -jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :] * dt
    xh = xs.reshape(B, S, Hs, P)
    y, state = ssd_chunked(xh, b, c, alog, dt, cfg.ssm_chunk)
    y = y + p["d"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, Hs * P).astype(x.dtype)
    out = _gate_out(cfg, y, z, p)
    if return_state:
        tail = lambda a: a[:, -(K - 1):].astype(x.dtype) if S >= K - 1 else \
            jnp.pad(a, ((0, 0), (K - 1 - S, 0), (0, 0))).astype(x.dtype)
        layer_cache = {"state": state, "conv_x": tail(xs_raw),
                       "conv_b": tail(b_raw), "conv_c": tail(c_raw)}
        return out, layer_cache
    return out


def ssm_init_cache(cfg, batch: int, dtype):
    K = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                            cfg.ssm_head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        "conv_c": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
    }


def ssm_decode(cfg, x_t, p, cache):
    """One recurrent step.  x_t: (B, E) -> (y_t: (B, E), new cache)."""
    B, E = x_t.shape
    Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x1 = x_t[:, None, :]                              # (B, 1, E)
    xs, z, b, c, dt = _project(cfg, x1, p)
    xs, conv_x = causal_conv1d(xs, p["conv_x"], cache["conv_x"])
    b, conv_b = causal_conv1d(b, p["conv_b"], cache["conv_b"])
    c, conv_c = causal_conv1d(c, p["conv_c"], cache["conv_c"])
    dt = dt[:, 0]                                      # (B, Hs)
    alog = -jnp.exp(p["a_log"].astype(jnp.float32))[None, :] * dt
    xh = xs[:, 0].reshape(B, Hs, P).astype(jnp.float32)
    bt = b[:, 0].astype(jnp.float32)                   # (B, N)
    ct = c[:, 0].astype(jnp.float32)
    state = cache["state"]
    state = (jnp.exp(alog)[..., None, None] * state
             + dt[..., None, None] * bt[:, None, :, None] * xh[:, :, None, :])
    y = jnp.einsum("bn,bhnp->bhp", ct, state)
    y = y + p["d"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, Hs * P).astype(x_t.dtype)
    out = _gate_out(cfg, y[:, None, :], z, p)[:, 0]
    return out, {"state": state, "conv_x": conv_x,
                 "conv_b": conv_b, "conv_c": conv_c}
