"""Top-k routed mixture-of-experts with sort-based capacity dispatch.

Dispatch is the standard production scheme (GShard/MaxText lineage):
flatten tokens, pick top-k experts, stable-sort assignments by expert id,
compute each assignment's slot within its expert via a cumsum, drop
assignments past the expert capacity, gather into a dense
``(n_experts, capacity, d_model)`` buffer, run the expert FFNs as one
batched einsum (MXU-friendly), and scatter-add weighted outputs back.

Two execution paths share that algorithm:

* ``moe_ffn`` — pure-jnp single-device path (tests, CPU examples).
* ``_moe_ffn_shard_map`` — the expert-parallel production path.  Because
  activations are batch-sharded over (pod, data) and *replicated* over
  "model", every expert shard already holds every token: routing is
  computed redundantly per shard (router flops are negligible), each
  shard dispatches only the assignments owned by its expert slice, and
  the combine is one fp32 ``psum`` over "model" — the same volume as any
  tensor-parallel FFN's all-reduce.  No gather/scatter ever crosses
  devices, which is what keeps GSPMD from replicating the (X, C, E)
  dispatch buffer (a ~150 GB tensor for kimi-k2's train cell).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import activate, is_glu


def _route(cfg, xt, router_w):
    """Routing (fp32).  xt: (N, E) -> gates (N, k), expert ids (N, k), aux."""
    N = xt.shape[0]
    X, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("ne,ex->nx", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): mean_prob * mean_assignment
    me = probs.mean(axis=0)
    ce = jnp.zeros((X,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (N * k))
    aux_loss = X * jnp.sum(me * ce)
    return gate_vals, expert_ids, aux_loss


def _dispatch_compute_combine(cfg, xt, gate_vals, expert_ids, w_in, w_out,
                              *, n_local: int, expert_lo):
    """Sort-based capacity dispatch over the ``n_local`` experts starting
    at ``expert_lo``, batched expert FFNs, weighted scatter-add combine.

    Returns (yt (N, E) fp32 partial output, drop_frac).
    """
    N, E = xt.shape
    X, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(cfg.capacity_factor * N * k / X)))
    if N <= 1024:
        # decode / tiny-batch floor: cap = N makes dispatch dropless for
        # ANY routing (an expert receives at most one slot per token) —
        # serving must never drop tokens, and the buffer stays small.
        cap = max(cap, N)

    local_e = expert_ids - expert_lo                          # (N, k)
    mine = (local_e >= 0) & (local_e < n_local)
    flat_e = jnp.where(mine, local_e, n_local).reshape(-1)    # bucket n_local = foreign
    flat_gate = (gate_vals * mine).reshape(-1)
    token_of = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_gate[order], token_of[order]
    # slot within expert = rank among equal expert ids
    pos = jnp.arange(N * k, dtype=jnp.int32)
    seg_start = jnp.full((n_local + 1,), N * k, jnp.int32).at[se].min(pos)
    slot = pos - seg_start[se]
    keep = (slot < cap) & (se < n_local)
    dest = jnp.where(keep, se * cap + slot, n_local * cap)    # OOB -> dropped

    buf = jnp.zeros((n_local * cap, E), xt.dtype).at[dest].add(
        xt[st], mode="drop")
    dispatched = buf.reshape(n_local, cap, E)

    h_in = jnp.einsum("xce,xgef->xgcf", dispatched, w_in)
    if is_glu(cfg.activation):
        h = activate(cfg.activation, h_in[:, 0], h_in[:, 1])
    else:
        h = activate(cfg.activation, h_in[:, 0])
    y_exp = jnp.einsum("xcf,xfe->xce", h.astype(xt.dtype), w_out)

    flat_y = y_exp.reshape(n_local * cap, E)
    src = jnp.where(keep, dest, 0)
    gathered = flat_y[src].astype(jnp.float32) * \
        (sg * keep).astype(jnp.float32)[:, None]
    yt = jnp.zeros((N, E), jnp.float32).at[st].add(gathered)
    drop = 1.0 - (keep | ~mine.reshape(-1)[order]).astype(jnp.float32).mean()
    return yt, drop


def _shared_experts(cfg, x, shared_in, shared_out):
    h_in = jnp.einsum("bse,gef->bsgf", x, shared_in)
    if is_glu(cfg.activation):
        h = activate(cfg.activation, h_in[..., 0, :], h_in[..., 1, :])
    else:
        h = activate(cfg.activation, h_in[..., 0, :])
    return jnp.einsum("bsf,fe->bse", h.astype(x.dtype), shared_out)


def moe_ffn(cfg, x, router_w, w_in, w_out, shared_in=None, shared_out=None,
            constrain=None):
    """x: (B, S, E) -> (B, S, E); router_w: (E, X);
    w_in: (X, 2|1, E, F); w_out: (X, F, E).

    ``constrain`` is the distributed layer's sharding hook; when it
    carries a mesh with a >1 "model" axis (and X divides it), the
    expert-parallel shard_map path is used.  Returns (y, aux).
    """
    B, S, E = x.shape
    mesh = getattr(constrain, "mesh", None)
    tp = int(mesh.shape["model"]) if (
        mesh is not None and "model" in mesh.axis_names) else 1
    if tp > 1 and cfg.n_experts % tp == 0:
        y, aux_loss, drop = _moe_ffn_shard_map(cfg, x, router_w, w_in, w_out,
                                               mesh)
    else:
        xt = x.reshape(B * S, E)
        gate_vals, expert_ids, aux_loss = _route(cfg, xt, router_w)
        yt, drop = _dispatch_compute_combine(
            cfg, xt, gate_vals, expert_ids, w_in, w_out,
            n_local=cfg.n_experts, expert_lo=0)
        y = yt.astype(x.dtype).reshape(B, S, E)

    if shared_in is not None:
        y = y + _shared_experts(cfg, x, shared_in, shared_out)
    return y, {"moe_aux_loss": aux_loss, "moe_drop_frac": drop}


def _moe_ffn_shard_map(cfg, x, router_w, w_in, w_out, mesh):
    """Expert-parallel path (see module docstring)."""
    from jax.experimental.shard_map import shard_map

    tp = int(mesh.shape["model"])
    X_loc = cfg.n_experts // tp
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def inner(x_l, rw, wi, wo):
        B_l, S, E = x_l.shape
        xt = x_l.reshape(B_l * S, E)
        gate_vals, expert_ids, aux_loss = _route(cfg, xt, rw)
        lo = jax.lax.axis_index("model") * X_loc
        yt, drop = _dispatch_compute_combine(
            cfg, xt, gate_vals, expert_ids, wi, wo,
            n_local=X_loc, expert_lo=lo)
        y_l = jax.lax.psum(yt.astype(jnp.dtype(cfg.moe_combine_dtype)),
                           "model")
        # aux/drop differ per dp shard; reduce over the whole mesh so the
        # P() out_specs really are replicated.
        all_axes = dp + ("model",)
        aux_loss = jax.lax.pmean(aux_loss, all_axes)
        drop = jax.lax.pmean(drop, all_axes)
        return y_l.astype(x_l.dtype).reshape(B_l, S, E), aux_loss, drop

    f = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None, None), P("model", None, None)),
        out_specs=(P(bspec, None, None), P(), P()),
        check_rep=False)
    return f(x, router_w, w_in, w_out)
