from .config import ModelConfig, SHAPES, ShapeCell, cell_applicable
from .model import (abstract_params, cache_logical_axes, decode_step,
                    init_cache, init_params, param_count,
                    param_logical_axes, prefill, train_loss)

__all__ = [
    "ModelConfig", "SHAPES", "ShapeCell", "cell_applicable",
    "abstract_params", "cache_logical_axes", "decode_step", "init_cache",
    "init_params", "param_count", "param_logical_axes", "prefill",
    "train_loss",
]
