"""The architecture zoo as one functional model.

Parameters are nested dicts of arrays; ``param_specs`` is the single
source of truth for shapes, logical sharding axes and initializers, so
``init_params`` (real arrays), ``abstract_params`` (ShapeDtypeStructs for
the dry-run) and ``param_logical_axes`` (for pjit shardings) can never
drift apart.

Entry points (all pure functions of (cfg, params, ...)):
  * ``train_loss``       — full-sequence loss for the train cells
  * ``prefill``          — full forward building a decode cache
  * ``decode_step``      — one token through the cache (serve cells)

``sh(tensor, logical_axes)`` is an injectable sharding-constraint hook;
the distributed layer passes a mesh-aware one, tests pass nothing.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, cross_entropy_loss, decode_attention_jnp,
                     flash_attention_jnp, is_glu, norm, softcap, activate)
from .moe import moe_ffn
from .ssm import ssm_decode, ssm_forward, ssm_init_cache

Axes = tuple  # logical axis names (str | None) per dim


class ParamSpec(NamedTuple):
    shape: tuple
    axes: Axes
    init: str          # normal | out_proj | zeros | ones | ssm_a | ssm_dt | conv
    dtype: str = ""    # "" -> cfg.dtype


def _noop_sh(x, axes):
    return x


# ===========================================================================
# Parameter specs
# ===========================================================================
def _attn_specs(cfg, L, prefix, specs):
    E, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lead, lax_ = ((L,), ("layers",)) if L else ((), ())
    specs[f"{prefix}/wq"] = ParamSpec(lead + (E, H, Dh),
                                      lax_ + ("embed", "q_heads", "head_dim"),
                                      "normal")
    specs[f"{prefix}/wk"] = ParamSpec(lead + (E, Hkv, Dh),
                                      lax_ + ("embed", "kv_heads", "head_dim"),
                                      "normal")
    specs[f"{prefix}/wv"] = ParamSpec(lead + (E, Hkv, Dh),
                                      lax_ + ("embed", "kv_heads", "head_dim"),
                                      "normal")
    specs[f"{prefix}/wo"] = ParamSpec(lead + (H, Dh, E),
                                      lax_ + ("q_heads", "head_dim", "embed"),
                                      "out_proj")


def _mlp_specs(cfg, L, prefix, specs, d_ff=None):
    E = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    G = 2 if is_glu(cfg.activation) else 1
    lead, lax_ = ((L,), ("layers",)) if L else ((), ())
    specs[f"{prefix}/w_in"] = ParamSpec(lead + (G, E, F),
                                        lax_ + (None, "embed", "ffn"), "normal")
    specs[f"{prefix}/w_out"] = ParamSpec(lead + (F, E),
                                         lax_ + ("ffn", "embed"), "out_proj")


def _ssm_specs(cfg, L, prefix, specs):
    E, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    Hs, K = cfg.ssm_heads, cfg.ssm_conv
    lead, lax_ = ((L,), ("layers",)) if L else ((), ())
    specs[f"{prefix}/w_x"] = ParamSpec(lead + (E, din),
                                       lax_ + ("embed", "ssm_inner"), "normal")
    specs[f"{prefix}/w_z"] = ParamSpec(lead + (E, din),
                                       lax_ + ("embed", "ssm_inner"), "normal")
    specs[f"{prefix}/w_b"] = ParamSpec(lead + (E, N), lax_ + ("embed", None),
                                       "normal")
    specs[f"{prefix}/w_c"] = ParamSpec(lead + (E, N), lax_ + ("embed", None),
                                       "normal")
    specs[f"{prefix}/w_dt"] = ParamSpec(lead + (E, Hs),
                                        lax_ + ("embed", "ssm_heads"), "normal")
    specs[f"{prefix}/conv_x"] = ParamSpec(lead + (din, K),
                                          lax_ + ("ssm_inner", None), "conv")
    specs[f"{prefix}/conv_b"] = ParamSpec(lead + (N, K), lax_ + (None, None),
                                          "conv")
    specs[f"{prefix}/conv_c"] = ParamSpec(lead + (N, K), lax_ + (None, None),
                                          "conv")
    specs[f"{prefix}/a_log"] = ParamSpec(lead + (Hs,), lax_ + ("ssm_heads",),
                                         "ssm_a", "float32")
    specs[f"{prefix}/dt_bias"] = ParamSpec(lead + (Hs,), lax_ + ("ssm_heads",),
                                           "ssm_dt", "float32")
    specs[f"{prefix}/d"] = ParamSpec(lead + (Hs,), lax_ + ("ssm_heads",),
                                     "ones", "float32")
    specs[f"{prefix}/gate_scale"] = ParamSpec(lead + (din,),
                                              lax_ + ("ssm_inner",), "zeros",
                                              "float32")
    specs[f"{prefix}/w_out"] = ParamSpec(lead + (din, E),
                                         lax_ + ("ssm_inner", "embed"),
                                         "out_proj")


def _norm_spec(cfg, L, name, specs, dim=None):
    E = dim if dim is not None else cfg.d_model
    lead, lax_ = ((L,), ("layers",)) if L else ((), ())
    specs[name] = ParamSpec(lead + (E,), lax_ + (None,), "zeros", "float32")


def param_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    E, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    specs: dict[str, ParamSpec] = {}
    specs["embed/table"] = ParamSpec((V, E), ("vocab", "embed"), "normal")
    if not cfg.tie_embeddings:
        specs["lm_head/w"] = ParamSpec((V, E), ("vocab", "embed"), "normal")
    _norm_spec(cfg, 0, "final_norm/scale", specs)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        _norm_spec(cfg, L, "layers/ln1/scale", specs)
        _attn_specs(cfg, L, "layers/attn", specs)
        _norm_spec(cfg, L, "layers/ln2/scale", specs)
        _mlp_specs(cfg, L, "layers/mlp", specs)
        if fam == "vlm":
            specs["patch_proj/w"] = ParamSpec((E, E), ("embed", None), "normal")
    elif fam == "moe":
        _norm_spec(cfg, L, "layers/ln1/scale", specs)
        _attn_specs(cfg, L, "layers/attn", specs)
        _norm_spec(cfg, L, "layers/ln2/scale", specs)
        X, F = cfg.n_experts, cfg.d_ff
        G = 2 if is_glu(cfg.activation) else 1
        specs["layers/moe/router"] = ParamSpec((L, E, X),
                                               ("layers", "embed", None),
                                               "normal", "float32")
        specs["layers/moe/w_in"] = ParamSpec(
            (L, X, G, E, F), ("layers", "experts", None, "embed", None),
            "normal")
        specs["layers/moe/w_out"] = ParamSpec(
            (L, X, F, E), ("layers", "experts", None, "embed"), "out_proj")
        if cfg.n_shared_experts:
            _mlp_specs(cfg, L, "layers/moe/shared",
                       specs, d_ff=F * cfg.n_shared_experts)
    elif fam == "ssm":
        _norm_spec(cfg, L, "layers/ln/scale", specs)
        _ssm_specs(cfg, L, "layers/ssm", specs)
    elif fam == "hybrid":
        _norm_spec(cfg, L, "layers/ln/scale", specs)
        _ssm_specs(cfg, L, "layers/ssm", specs)
        _norm_spec(cfg, 0, "shared/ln1/scale", specs)
        _attn_specs(cfg, 0, "shared/attn", specs)
        _norm_spec(cfg, 0, "shared/ln2/scale", specs)
        _mlp_specs(cfg, 0, "shared/mlp", specs)
    elif fam == "encdec":
        Le = cfg.n_enc_layers
        _norm_spec(cfg, Le, "enc_layers/ln1/scale", specs)
        _attn_specs(cfg, Le, "enc_layers/attn", specs)
        _norm_spec(cfg, Le, "enc_layers/ln2/scale", specs)
        _mlp_specs(cfg, Le, "enc_layers/mlp", specs)
        _norm_spec(cfg, 0, "enc_norm/scale", specs)
        _norm_spec(cfg, L, "layers/ln1/scale", specs)
        _attn_specs(cfg, L, "layers/self_attn", specs)
        _norm_spec(cfg, L, "layers/ln_cross/scale", specs)
        _attn_specs(cfg, L, "layers/cross_attn", specs)
        _norm_spec(cfg, L, "layers/ln2/scale", specs)
        _mlp_specs(cfg, L, "layers/mlp", specs)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return specs


# -- pytree assembly --------------------------------------------------------
def _nest(flat: dict[str, object]) -> dict:
    tree: dict = {}
    for path, leaf in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def _init_leaf(key, spec: ParamSpec, cfg: ModelConfig):
    dt = jnp.dtype(spec.dtype or cfg.dtype)
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    if spec.init == "normal":
        return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dt)
    if spec.init == "out_proj":
        scale = 0.02 / max(1.0, (2 * max(cfg.n_layers, 1)) ** 0.5)
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dt)
    if spec.init == "conv":
        fan = shape[-1]
        return (jax.random.uniform(key, shape, jnp.float32,
                                   -1.0, 1.0) / fan ** 0.5).astype(dt)
    if spec.init == "ssm_a":
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dt)
    if spec.init == "ssm_dt":
        u = jax.random.uniform(key, shape, jnp.float32, 0.001, 0.1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dt)  # softplus^-1
    raise ValueError(spec.init)


def init_params(cfg: ModelConfig, key) -> dict:
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    flat = {p: _init_leaf(k, s, cfg) for (p, s), k in zip(specs.items(), keys)}
    return _nest(flat)


def abstract_params(cfg: ModelConfig) -> dict:
    specs = param_specs(cfg)
    flat = {p: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or cfg.dtype))
            for p, s in specs.items()}
    return _nest(flat)


def param_logical_axes(cfg: ModelConfig) -> dict:
    return _nest({p: s.axes for p, s in param_specs(cfg).items()})


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for s in param_specs(cfg).values():
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


# ===========================================================================
# Blocks
# ===========================================================================
def _attn_full(cfg, x, p, positions, *, causal=True, prefix_len=0,
               kv_override=None, sh=_noop_sh):
    """Full-sequence attention.  Returns (out, (k, v)) for cache capture."""
    q = jnp.einsum("bse,ehd->bhsd", x, p["wq"])
    src = kv_override if kv_override is not None else x
    k = jnp.einsum("bse,ehd->bhsd", src, p["wk"])
    v = jnp.einsum("bse,ehd->bhsd", src, p["wv"])
    if kv_override is None:            # self-attention gets RoPE
        q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    q = sh(q, ("batch", "q_heads", None, None))
    k = sh(k, ("batch", "kv_heads", None, None))
    out = flash_attention_jnp(q, k, v, causal=causal, prefix_len=prefix_len,
                              causal_skip=cfg.attn_causal_skip)
    out = jnp.einsum("bhsd,hde->bse", out, p["wo"])
    return out, (k, v)


def _attn_decode(cfg, x_t, p, k_cache, v_cache, pos, sh=_noop_sh):
    """One-token attention against a cache.  x_t: (B, E).

    Returns (out (B, E), k_t, v_t) — the caller owns the cache update so
    scan layouts stay in one place."""
    q = jnp.einsum("be,ehd->bhd", x_t, p["wq"])[:, :, None, :]
    k_t = jnp.einsum("be,ehd->bhd", x_t, p["wk"])
    v_t = jnp.einsum("be,ehd->bhd", x_t, p["wv"])
    posb = jnp.full((1, 1, 1), pos)
    q = apply_rope(q, posb, cfg.rope_theta)
    k_t = apply_rope(k_t[:, :, None, :], posb, cfg.rope_theta)[:, :, 0, :]
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_t[:, :, None, :].astype(k_cache.dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_t[:, :, None, :].astype(v_cache.dtype), pos, axis=2)
    out = decode_attention_jnp(q, k_cache, v_cache, pos + 1)
    out = jnp.einsum("bhsd,hde->bse", out, p["wo"])[:, 0]
    return out, k_cache, v_cache


def _mlp(cfg, x, p):
    h_in = jnp.einsum("bse,gef->bsgf", x, p["w_in"])
    if is_glu(cfg.activation):
        h = activate(cfg.activation, h_in[..., 0, :], h_in[..., 1, :])
    else:
        h = activate(cfg.activation, h_in[..., 0, :])
    return jnp.einsum("bsf,fe->bse", h.astype(x.dtype), p["w_out"])


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_layers(cfg, layer_fn, x, layers_params, n_layers: int):
    """Scan over layers with optional two-level (grouped) remat.

    Flat scan saves one residual carry per layer — for 100B+ configs that
    alone exceeds HBM (126 x (B,S,E) for llama3-405b).  With
    ``cfg.scan_group = G`` the stack runs as G checkpointed groups of an
    inner checkpointed scan: saved carries drop to G + L/G at the cost of
    one extra forward per group (~25% more compute) — the classic
    sqrt-remat trade, selectable per architecture.
    """
    f = _remat(cfg, layer_fn)
    G = cfg.scan_group
    if G and n_layers % G == 0:
        grouped = jax.tree.map(
            lambda a: a.reshape((G, n_layers // G) + a.shape[1:]),
            layers_params)

        def group_fn(x, gp):
            return jax.lax.scan(f, x, gp)

        x, ys = jax.lax.scan(_remat(cfg, group_fn), x, grouped)
        ys = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), ys)
        return x, ys
    return jax.lax.scan(f, x, layers_params)


# ===========================================================================
# Full-sequence forwards (train / prefill)
# ===========================================================================
def _transformer_stack(cfg, params, x, positions, *, prefix_len=0,
                       collect_cache=False, sh=_noop_sh):
    """Dense/MoE/VLM decoder stack via scan-over-layers."""
    moe = cfg.family == "moe"

    def layer(x, lp):
        h, kv = _attn_full(cfg, norm(cfg.norm, x, lp["ln1"]["scale"]),
                           lp["attn"], positions, prefix_len=prefix_len, sh=sh)
        x = x + h
        hin = norm(cfg.norm, x, lp["ln2"]["scale"])
        if moe:
            mp = lp["moe"]
            shared = mp.get("shared")
            h2, aux = moe_ffn(cfg, hin, mp["router"], mp["w_in"], mp["w_out"],
                              shared_in=shared["w_in"] if shared else None,
                              shared_out=shared["w_out"] if shared else None,
                              constrain=sh)
        else:
            h2, aux = _mlp(cfg, hin, lp["mlp"]), {
                "moe_aux_loss": jnp.float32(0.0),
                "moe_drop_frac": jnp.float32(0.0)}
        x = x + h2
        if cfg.seq_shard_activations:
            # Megatron-style sequence parallelism: the residual carried
            # between layers (and saved by the scan) is S-sharded over the
            # model axis; GSPMD re-gathers inside attention/FFN.
            x = sh(x, ("batch", "seq_act", None))
        ys = {"aux": aux["moe_aux_loss"], "drop": aux["moe_drop_frac"]}
        if collect_cache:
            ys["k"], ys["v"] = kv
        return x, ys

    return _scan_layers(cfg, layer, x, params["layers"], cfg.n_layers)


def _ssm_stack(cfg, params, x, *, collect_state=False, sh=_noop_sh):
    def layer(x, lp):
        h = ssm_forward(cfg, norm(cfg.norm, x, lp["ln"]["scale"]), lp["ssm"],
                        return_state=collect_state)
        if collect_state:
            h, state = h
            return x + h, state
        return x + h, None

    return _scan_layers(cfg, layer, x, params["layers"], cfg.n_layers)


def _hybrid_stack(cfg, params, x, positions, *, collect_cache=False,
                  sh=_noop_sh):
    """Zamba2: shared attention block every ``attn_every`` mamba layers."""
    L, every = cfg.n_layers, cfg.attn_every
    n_groups = L // every
    shared = params["shared"]

    def group(x, glp):
        h, kv = _attn_full(cfg, norm(cfg.norm, x, shared["ln1"]["scale"]),
                           shared["attn"], positions, sh=sh)
        x = x + h
        x = x + _mlp(cfg, norm(cfg.norm, x, shared["ln2"]["scale"]),
                     shared["mlp"])

        def mamba_layer(x, lp):
            h = ssm_forward(cfg, norm(cfg.norm, x, lp["ln"]["scale"]),
                            lp["ssm"], return_state=collect_cache)
            if collect_cache:
                h, state = h
                return x + h, state
            return x + h, None

        x, states = jax.lax.scan(mamba_layer, x, glp)
        ys = {"states": states} if collect_cache else {}
        if collect_cache:
            ys["k"], ys["v"] = kv
        return x, ys

    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]),
        params["layers"])
    x, ys = jax.lax.scan(_remat(cfg, group), x, grouped)
    return x, ys


def _encoder(cfg, params, frames, sh=_noop_sh):
    positions = jnp.arange(frames.shape[1])

    def layer(x, lp):
        h, _ = _attn_full(cfg, norm(cfg.norm, x, lp["ln1"]["scale"]),
                          lp["attn"], positions, causal=False, sh=sh)
        x = x + h
        x = x + _mlp(cfg, norm(cfg.norm, x, lp["ln2"]["scale"]), lp["mlp"])
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, layer), frames, params["enc_layers"])
    return norm(cfg.norm, x, params["enc_norm"]["scale"])


def _decoder_encdec(cfg, params, x, enc_out, positions, *,
                    collect_cache=False, sh=_noop_sh):
    def layer(x, lp):
        h, kv_self = _attn_full(cfg, norm(cfg.norm, x, lp["ln1"]["scale"]),
                                lp["self_attn"], positions, sh=sh)
        x = x + h
        h, kv_cross = _attn_full(
            cfg, norm(cfg.norm, x, lp["ln_cross"]["scale"]), lp["cross_attn"],
            positions, causal=False, kv_override=enc_out, sh=sh)
        x = x + h
        x = x + _mlp(cfg, norm(cfg.norm, x, lp["ln2"]["scale"]), lp["mlp"])
        ys = {}
        if collect_cache:
            ys["k"], ys["v"] = kv_self
            ys["ck"], ys["cv"] = kv_cross
        return x, ys

    return jax.lax.scan(_remat(cfg, layer), x, params["layers"])


# ===========================================================================
# Embedding / head
# ===========================================================================
def _embed(cfg, params, tokens, sh=_noop_sh):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return sh(x, ("batch", None, None))


def _head_weight(cfg, params):
    return params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["w"]


def lm_loss(cfg, params, x, labels, mask, *, chunk: int = 2048, sh=_noop_sh):
    """Chunked LM head + cross-entropy so (B, S, V) logits never fully
    materialize (V is vocab-sharded; S is chunked via scan + remat)."""
    B, S, E = x.shape
    w = _head_weight(cfg, params)
    cs = min(chunk, S)
    nc = -(-S // cs)
    pad = nc * cs - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(B, nc, cs, E).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, cs).transpose(1, 0, 2)
    mc = mask.reshape(B, nc, cs).transpose(1, 0, 2)

    def chunk_fn(carry, inp):
        xi, li, mi = inp
        logits = jnp.einsum("bse,ve->bsv", xi, w)
        logits = softcap(logits.astype(jnp.float32), cfg.logits_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mi
        return (carry[0] + nll.sum(), carry[1] + mi.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_fn), (jnp.float32(0.0), jnp.float32(0.0)),
        (xc, lc, mc.astype(jnp.float32)))
    return tot / jnp.maximum(cnt, 1.0)


def _logits_last(cfg, params, x_last):
    """x_last: (B, E) -> (B, V)."""
    w = _head_weight(cfg, params)
    logits = jnp.einsum("be,ve->bv", x_last, w)
    return softcap(logits.astype(jnp.float32), cfg.logits_softcap)


# ===========================================================================
# Public entry points
# ===========================================================================
def _backbone(cfg, params, batch, *, collect_cache=False, sh=_noop_sh):
    """Shared full-sequence path.  Returns (x, ys, aux_info)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens, sh)
        positions = jnp.arange(tokens.shape[1])
        x, ys = _transformer_stack(cfg, params, x, positions,
                                   collect_cache=collect_cache, sh=sh)
        prefix = 0
    elif fam == "vlm":
        tokens = batch["tokens"]
        patches = batch["patches"].astype(jnp.dtype(cfg.dtype))
        pemb = jnp.einsum("bpe,ef->bpf", patches, params["patch_proj"]["w"])
        x = jnp.concatenate([pemb, _embed(cfg, params, tokens, sh)], axis=1)
        positions = jnp.arange(x.shape[1])
        x, ys = _transformer_stack(cfg, params, x, positions,
                                   prefix_len=cfg.n_patches,
                                   collect_cache=collect_cache, sh=sh)
        prefix = cfg.n_patches
    elif fam == "ssm":
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens, sh)
        x, ys = _ssm_stack(cfg, params, x, collect_state=collect_cache, sh=sh)
        prefix = 0
    elif fam == "hybrid":
        tokens = batch["tokens"]
        x = _embed(cfg, params, tokens, sh)
        positions = jnp.arange(tokens.shape[1])
        x, ys = _hybrid_stack(cfg, params, x, positions,
                              collect_cache=collect_cache, sh=sh)
        prefix = 0
    elif fam == "encdec":
        tokens = batch["tokens"]
        frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
        enc_out = _encoder(cfg, params, frames, sh)
        x = _embed(cfg, params, tokens, sh)
        positions = jnp.arange(tokens.shape[1])
        x, ys = _decoder_encdec(cfg, params, x, enc_out, positions,
                                collect_cache=collect_cache, sh=sh)
        prefix = 0
    else:
        raise ValueError(fam)
    x = norm(cfg.norm, x, params["final_norm"]["scale"])
    return x, ys, prefix


def train_loss(cfg, params, batch, sh=_noop_sh):
    """Mean next-token loss (+ MoE aux).  batch: tokens (B, S) [+ frames /
    patches for encdec / vlm].  Returns (loss, metrics)."""
    x, ys, prefix = _backbone(cfg, params, batch, sh=sh)
    tokens = batch["tokens"]
    if prefix:
        x = x[:, prefix:]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    loss = lm_loss(cfg, params, x, labels, mask, sh=sh)
    metrics = {"lm_loss": loss}
    if cfg.family == "moe" and isinstance(ys, dict):
        aux = ys["aux"].mean()
        metrics["moe_aux_loss"] = aux
        metrics["moe_drop_frac"] = ys["drop"].mean()
        loss = loss + 0.01 * aux
    metrics["loss"] = loss
    return loss, metrics


# -- caches -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract-friendly cache construction (jnp.zeros only)."""
    dt = jnp.dtype(cfg.dtype)
    L, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family
    cache: dict = {"len": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        cache["k"] = jnp.zeros((L, batch, Hkv, max_len, Dh), dt)
        cache["v"] = jnp.zeros((L, batch, Hkv, max_len, Dh), dt)
    elif fam == "ssm":
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((L,) + a.shape, a.dtype),
            ssm_init_cache(cfg, batch, dt))
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((n_groups, cfg.attn_every) + a.shape, a.dtype),
            ssm_init_cache(cfg, batch, dt))
        cache["k"] = jnp.zeros((n_groups, batch, Hkv, max_len, Dh), dt)
        cache["v"] = jnp.zeros((n_groups, batch, Hkv, max_len, Dh), dt)
    elif fam == "encdec":
        cache["k"] = jnp.zeros((L, batch, Hkv, max_len, Dh), dt)
        cache["v"] = jnp.zeros((L, batch, Hkv, max_len, Dh), dt)
        cache["ck"] = jnp.zeros((L, batch, Hkv, cfg.enc_frames, Dh), dt)
        cache["cv"] = jnp.zeros((L, batch, Hkv, cfg.enc_frames, Dh), dt)
    return cache


def cache_logical_axes(cfg: ModelConfig) -> dict:
    kv = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    axes: dict = {"len": ()}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        axes["k"] = kv
        axes["v"] = kv
        if fam == "encdec":
            axes["ck"] = kv
            axes["cv"] = kv
    elif fam == "ssm":
        axes["ssm"] = {
            "state": ("layers", "batch", "ssm_heads", None, None),
            "conv_x": ("layers", "batch", None, "ssm_inner"),
            "conv_b": ("layers", "batch", None, None),
            "conv_c": ("layers", "batch", None, None),
        }
    elif fam == "hybrid":
        axes["ssm"] = {
            "state": ("layers", None, "batch", "ssm_heads", None, None),
            "conv_x": ("layers", None, "batch", None, "ssm_inner"),
            "conv_b": ("layers", None, "batch", None, None),
            "conv_c": ("layers", None, "batch", None, None),
        }
        axes["k"] = kv
        axes["v"] = kv
    return axes


def prefill(cfg, params, batch, max_len: int, sh=_noop_sh):
    """Full forward that also builds the decode cache.

    Returns (cache, logits_last (B, V))."""
    x, ys, prefix = _backbone(cfg, params, batch, collect_cache=True, sh=sh)
    tokens = batch["tokens"]
    B, S = tokens.shape[0], x.shape[1]
    cache = init_cache(cfg, B, max_len)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        k, v = ys["k"], ys["v"]          # (L, B, Hkv, S, Dh)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=3)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=3)
        if fam == "encdec":
            cache["ck"], cache["cv"] = (ys["ck"].astype(cache["ck"].dtype),
                                        ys["cv"].astype(cache["cv"].dtype))
    elif fam == "ssm":
        cache["ssm"] = ys                # per-layer state + conv tails
    elif fam == "hybrid":
        cache["ssm"] = ys["states"]
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ys["k"].astype(cache["k"].dtype), 0, axis=3)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], ys["v"].astype(cache["v"].dtype), 0, axis=3)
    cache["len"] = jnp.asarray(S, jnp.int32)
    logits = _logits_last(cfg, params, x[:, -1])
    return cache, logits


def decode_step(cfg, params, cache, tokens_t, sh=_noop_sh):
    """One decode step.  tokens_t: (B,) int32.  Returns (cache, logits)."""
    pos = cache["len"]
    x = jnp.take(params["embed"]["table"], tokens_t, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = sh(x, ("batch", None))
    fam = cfg.family

    if fam in ("dense", "moe", "vlm", "encdec"):
        def layer(x, inp):
            lp, kc, vc = inp["lp"], inp["k"], inp["v"]
            attn_p = lp["self_attn"] if fam == "encdec" else lp["attn"]
            h, kc, vc = _attn_decode(
                cfg, norm(cfg.norm, x[None], lp["ln1"]["scale"])[0],
                attn_p, kc, vc, pos, sh=sh)
            x = x + h
            if fam == "encdec":
                hq = norm(cfg.norm, x[None], lp["ln_cross"]["scale"])[0]
                q = jnp.einsum("be,ehd->bhd", hq, lp["cross_attn"]["wq"])
                out = decode_attention_jnp(q[:, :, None], inp["ck"], inp["cv"],
                                           inp["ck"].shape[2])
                x = x + jnp.einsum("bhsd,hde->bse", out,
                                   lp["cross_attn"]["wo"])[:, 0]
            hin = norm(cfg.norm, x[None], lp["ln2"]["scale"])
            if fam == "moe":
                mp = lp["moe"]
                shared = mp.get("shared")
                # batch-major layout so the EP shard_map sees batch on dim 0
                h2, _ = moe_ffn(cfg, hin.transpose(1, 0, 2), mp["router"],
                                mp["w_in"], mp["w_out"],
                                shared_in=shared["w_in"] if shared else None,
                                shared_out=shared["w_out"] if shared else None,
                                constrain=sh)
                h2 = h2.transpose(1, 0, 2)
            else:
                h2 = _mlp(cfg, hin, lp["mlp"])
            x = x + h2[0]
            return x, {"k": kc, "v": vc}

        inp = {"lp": params["layers"], "k": cache["k"], "v": cache["v"]}
        if fam == "encdec":
            inp["ck"], inp["cv"] = cache["ck"], cache["cv"]
        x, new_kv = jax.lax.scan(layer, x, inp)
        cache = dict(cache, k=new_kv["k"], v=new_kv["v"])
    elif fam == "ssm":
        def layer(x, inp):
            h, new_c = ssm_decode(
                cfg, norm(cfg.norm, x[None], inp["lp"]["ln"]["scale"])[0],
                inp["lp"]["ssm"], inp["c"])
            return x + h, new_c

        x, new_ssm = jax.lax.scan(layer, x, {"lp": params["layers"],
                                             "c": cache["ssm"]})
        cache = dict(cache, ssm=new_ssm)
    elif fam == "hybrid":
        shared = params["shared"]
        n_groups = cfg.n_layers // cfg.attn_every
        grouped_lp = jax.tree.map(
            lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
            params["layers"])

        def group(x, inp):
            h, kc, vc = _attn_decode(
                cfg, norm(cfg.norm, x[None], shared["ln1"]["scale"])[0],
                shared["attn"], inp["k"], inp["v"], pos, sh=sh)
            x = x + h
            x = x + _mlp(cfg, norm(cfg.norm, x[None], shared["ln2"]["scale"]),
                         shared["mlp"])[0]

            def mamba_layer(x, minp):
                h, new_c = ssm_decode(
                    cfg, norm(cfg.norm, x[None],
                              minp["lp"]["ln"]["scale"])[0],
                    minp["lp"]["ssm"], minp["c"])
                return x + h, new_c

            x, new_ssm = jax.lax.scan(mamba_layer, x,
                                      {"lp": inp["lp"], "c": inp["c"]})
            return x, {"k": kc, "v": vc, "ssm": new_ssm}

        x, new = jax.lax.scan(group, x, {"lp": grouped_lp, "k": cache["k"],
                                         "v": cache["v"], "c": cache["ssm"]})
        cache = dict(cache, k=new["k"], v=new["v"], ssm=new["ssm"])
    else:
        raise ValueError(fam)

    x = norm(cfg.norm, x[None], params["final_norm"]["scale"])[0]
    logits = _logits_last(cfg, params, x)
    cache["len"] = pos + 1
    return cache, logits
