"""Model configuration for the architecture zoo.

One ``ModelConfig`` describes any member of the six supported families:

  * ``dense``   — decoder-only transformer (GQA/MQA attention + MLP)
  * ``moe``     — decoder-only transformer with top-k routed experts
  * ``ssm``     — attention-free Mamba2 (SSD) stack
  * ``hybrid``  — Mamba2 backbone with a *shared* attention block applied
                  every ``attn_every`` layers (Zamba2 style)
  * ``encdec``  — encoder–decoder transformer over a stubbed modality
                  frontend (Whisper style: precomputed frame embeddings)
  * ``vlm``     — prefix-LM decoder over stubbed patch embeddings +
                  text tokens (PaliGemma style)

Configs are pure data; the functional model in ``model.py`` interprets
them.  ``reduced()`` produces the CPU-smoke-test variant of the same
family (small widths/depths, tiny vocab, few experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm

    # -- transformer core ---------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    activation: str = "swiglu"       # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logits_softcap: float = 0.0      # gemma-style tanh soft-capping (0 = off)
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) embed scale

    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0        # DeepSeek/Kimi-style always-on experts
    capacity_factor: float = 1.25
    router_jitter: bool = False

    # -- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0               # N, the SSD state dimension
    ssm_head_dim: int = 64           # P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv: int = 4                # causal depthwise conv width
    ssm_chunk: int = 64              # SSD chunk length

    # -- hybrid (Zamba2) --------------------------------------------------------
    attn_every: int = 6              # shared attention block cadence

    # -- encoder–decoder (Whisper) ---------------------------------------------
    n_enc_layers: int = 0
    enc_frames: int = 1500           # stub frontend: precomputed frame embeds

    # -- VLM (PaliGemma) ----------------------------------------------------------
    n_patches: int = 256             # stub frontend: precomputed patch embeds

    # -- numerics / execution ----------------------------------------------------
    dtype: str = "bfloat16"          # activation/param compute dtype
    remat: str = "full"              # none | dots | full
    scan_layers: bool = True
    scan_group: int = 0              # >0: two-level remat, this many groups
    microbatches: int = 1            # grad-accumulation steps per train step
    accum_dtype: str = "float32"     # grad accumulator dtype
    attn_causal_skip: bool = False   # unrolled triangular attention schedule
    seq_shard_activations: bool = False   # Megatron-style sequence parallel
    moe_combine_dtype: str = "float32"    # EP psum dtype (bf16 = half bytes)
    use_kernels: bool = False        # Pallas (TPU target / interpret) vs jnp
    optimizer: str = "adamw"         # adamw | adafactor (set per scale)

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state => can run the long_500k cell."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all zoo members autoregressively decode

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (used for 6ND model-FLOPs)."""
        return sum(int(jnp.prod(jnp.array(s))) for s in self._param_shapes())

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: top_k + shared experts only)."""
        total = 0
        for tag, shape in self._tagged_param_shapes():
            n = 1
            for d in shape:
                n *= d
            if tag == "expert":
                n = n // max(self.n_experts, 1) * (self.top_k + self.n_shared_experts)
            total += n
        return total

    def _param_shapes(self):
        return [s for _, s in self._tagged_param_shapes()]

    def _tagged_param_shapes(self):
        """(tag, shape) pairs; tag 'expert' marks routed-expert weights."""
        E, F, V = self.d_model, self.d_ff, self.vocab
        H, Hkv, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        L = self.n_layers
        out = []
        if V:
            out.append(("dense", (V, E)))
            if not self.tie_embeddings:
                out.append(("dense", (V, E)))
        glu = self.activation in ("swiglu", "geglu")

        def attn(layers):
            out.append(("dense", (layers, E, H * Dh)))
            out.append(("dense", (layers, E, Hkv * Dh)))
            out.append(("dense", (layers, E, Hkv * Dh)))
            out.append(("dense", (layers, H * Dh, E)))

        def mlp(layers, ff):
            k = 2 if glu else 1
            out.append(("dense", (layers, k, E, ff)))
            out.append(("dense", (layers, ff, E)))

        def ssm(layers):
            din, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            out.append(("dense", (layers, E, 2 * din + 2 * N + Hs)))  # in_proj
            out.append(("dense", (layers, din + 2 * N, self.ssm_conv)))
            out.append(("dense", (layers, din, E)))                    # out_proj
            out.append(("dense", (layers, 3, Hs)))                     # A/dt/D

        if self.family in ("dense", "vlm"):
            attn(L)
            mlp(L, F)
        elif self.family == "moe":
            attn(L)
            out.append(("dense", (L, E, self.n_experts)))              # router
            k = 2 if glu else 1
            out.append(("expert", (L, self.n_experts, k, E, F)))
            out.append(("expert", (L, self.n_experts, F, E)))
            if self.n_shared_experts:
                mlp(L, F * self.n_shared_experts)
        elif self.family == "ssm":
            ssm(L)
        elif self.family == "hybrid":
            ssm(L)
            attn(1)                                                     # shared
            mlp(1, F if F else 4 * E)
        elif self.family == "encdec":
            attn(L)            # decoder self
            attn(L)            # decoder cross
            mlp(L, F)
            attn(self.n_enc_layers)
            mlp(self.n_enc_layers, F)
        return out

    def reduced(self, **overrides) -> "ModelConfig":
        """CPU smoke-test variant: same family/topology, tiny sizes."""
        small = dict(
            n_layers=min(self.n_layers, 2) or 2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=(min(self.n_kv_heads, 2) or 0) if self.n_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=min(self.vocab, 256) if self.vocab else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            attn_every=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=16,
            n_patches=8,
            dtype="float32",
            remat="none",
            scan_group=0,
            microbatches=1,
            accum_dtype="float32",
            name=self.name + "-smoke",
            optimizer="adamw",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k-token decode is "
                       "quadratic-prefill / KV-resident; excluded per "
                       "assignment (DESIGN.md SArch-applicability)")
    return True, ""
