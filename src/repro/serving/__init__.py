from .kv_pool import PagedKVPool
from .server import BatchServer, ServerConfig, two_phase_admission

__all__ = ["PagedKVPool", "BatchServer", "ServerConfig",
           "two_phase_admission"]
