"""Log-structured paged KV-cache pool with LSM-style compaction.

Serving appends KV pages per request (writes); finished requests retire
their pages, leaving holes (obsolete entries).  Reclaiming holes means
copying live pages down — background I/O identical in shape to LSM
merges.  Compaction work items are scheduled by the paper's machinery:
the greedy rule (fewest remaining live bytes first, Theorem 2) minimizes
fragmented pages over time exactly as it minimizes component counts,
and an occupancy constraint (= the component constraint) is what stalls
admissions when compaction lags.

The pool is device-layout-aware: pages live in one (n_pages, page,
n_kv, head_dim) array per layer group so the gather in paged attention
is a single ``take`` along the page axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.component import MergeOp, Component
from repro.core.scheduler import MergeScheduler, GreedyScheduler


@dataclass
class Request:
    rid: int
    pages: list[int] = field(default_factory=list)
    length: int = 0
    done: bool = False


class PagedKVPool:
    """Host-metadata page allocator (device arrays owned by the server)."""

    def __init__(self, n_pages: int, page_tokens: int,
                 scheduler: Optional[MergeScheduler] = None,
                 occupancy_stall: float = 0.95):
        self.n_pages = int(n_pages)
        self.page_tokens = int(page_tokens)
        self.free: list[int] = list(range(self.n_pages))[::-1]
        self.requests: dict[int, Request] = {}
        self.retired_pages: list[int] = []      # holes awaiting reclaim
        self.scheduler = scheduler or GreedyScheduler()
        self.occupancy_stall = float(occupancy_stall)
        self.compactions: dict[int, MergeOp] = {}
        self.stats = {"alloc": 0, "retire": 0, "compact_pages": 0,
                      "admission_stalls": 0}

    # ------------------------------------------------------------- admission
    @property
    def occupancy(self) -> float:
        return 1.0 - len(self.free) / self.n_pages

    def can_admit(self, prompt_tokens: int) -> bool:
        need = -(-prompt_tokens // self.page_tokens)
        if len(self.free) < need or \
                self.occupancy >= self.occupancy_stall:
            self.stats["admission_stalls"] += 1
            return False
        return True

    def admit(self, rid: int, prompt_tokens: int) -> Optional[list[int]]:
        if not self.can_admit(prompt_tokens):
            return None
        need = -(-prompt_tokens // self.page_tokens)
        pages = [self.free.pop() for _ in range(need)]
        self.requests[rid] = Request(rid=rid, pages=pages,
                                     length=prompt_tokens)
        self.stats["alloc"] += need
        return pages

    def extend(self, rid: int, new_tokens: int = 1) -> Optional[int]:
        """Account decode growth; returns a new page id when one is
        allocated, None otherwise.  Raises KeyError on unknown rid."""
        req = self.requests[rid]
        req.length += new_tokens
        need = -(-req.length // self.page_tokens)
        if need > len(req.pages):
            if not self.free:
                return None
            p = self.free.pop()
            req.pages.append(p)
            self.stats["alloc"] += 1
            return p
        return -1

    def retire(self, rid: int):
        """Request finished: its pages become holes until compacted."""
        req = self.requests.pop(rid)
        self.retired_pages.extend(req.pages)
        self.stats["retire"] += len(req.pages)
        # one compaction work item per retirement batch; remaining bytes =
        # pages to reclaim (the greedy rule ranks the smallest first)
        comps = [Component(size=float(self.page_tokens), level=0)
                 for _ in req.pages]
        if comps:
            op = MergeOp(inputs=comps, output_level=0,
                         output_size=float(len(comps) * self.page_tokens))
            op.pages = list(req.pages)          # type: ignore[attr-defined]
            self.compactions[op.op_id] = op

    # ------------------------------------------------------------ compaction
    def pump(self, budget_tokens: int) -> list[int]:
        """Reclaim up to ``budget_tokens`` of retired pages, scheduler-
        ranked.  Returns the page ids freed this quantum."""
        freed: list[int] = []
        if not self.compactions:
            return freed
        alloc = self.scheduler.allocate(list(self.compactions.values()))
        for op_id, frac in alloc.items():
            op = self.compactions[op_id]
            quota = int(budget_tokens * frac)
            while quota >= self.page_tokens and \
                    getattr(op, "pages", None):
                page = op.pages.pop()           # type: ignore[attr-defined]
                self.free.append(page)
                freed.append(page)
                quota -= self.page_tokens
                op.written += self.page_tokens
                self.stats["compact_pages"] += 1
            if not getattr(op, "pages", None):
                self.compactions.pop(op_id, None)
        return freed
