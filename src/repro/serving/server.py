"""Batched serving loop + two-phase admission-rate calibration.

The serving analogue of the paper's write-stall story: requests arrive
(writes), decode steps process them (in-memory writes), page compaction
is background I/O.  Admitting as fast as possible measures an
*unsustainable* peak (holes accumulate until admission stalls), so the
server calibrates with the paper's two-phase method:

  testing phase — closed loop, admit as fast as possible, measure max
                  sustained decode throughput;
  running phase — open loop at ``utilization`` (default 95%) of that
                  max; p99 request latency decides sustainability.

``BatchServer`` runs a real model (decode_step) on whatever devices
exist; the examples drive it with a reduced config on CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from .kv_pool import PagedKVPool


@dataclass
class ServerConfig:
    batch_size: int = 8
    max_len: int = 256
    page_tokens: int = 16
    n_pages: int = 512
    compact_budget_tokens: int = 64      # per decode step
    max_new_tokens: int = 32


@dataclass
class _Slot:
    rid: int = -1
    remaining: int = 0
    arrived: float = 0.0


class BatchServer:
    """Continuous-batching decode server over a fixed slot batch."""

    def __init__(self, cfg_model, params, scfg: ServerConfig):
        self.cfg = cfg_model
        self.params = params
        self.scfg = scfg
        self.pool = PagedKVPool(scfg.n_pages, scfg.page_tokens)
        self.slots = [_Slot() for _ in range(scfg.batch_size)]
        self.cache = init_cache(cfg_model, scfg.batch_size, scfg.max_len)
        self.tokens = jnp.zeros((scfg.batch_size,), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg_model, p, c, t))
        self.queue: list[tuple[int, float, int]] = []   # rid, t_arrive, len
        self.completed: list[tuple[int, float, float]] = []
        self._next_rid = 0
        self.steps = 0

    # ------------------------------------------------------------- clients
    def submit(self, now: float, prompt_tokens: int = 8) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, now, prompt_tokens))
        return rid

    def _try_admit(self, now: float):
        for slot in self.slots:
            if slot.rid >= 0 or not self.queue:
                continue
            rid, t0, plen = self.queue[0]
            if self.pool.admit(rid, plen) is None:
                break                        # admission stalled on pages
            self.queue.pop(0)
            slot.rid = rid
            slot.remaining = self.scfg.max_new_tokens
            slot.arrived = t0

    # ---------------------------------------------------------------- step
    def step(self, now: float):
        """One decode step for the whole batch + compaction quantum."""
        self._try_admit(now)
        self.cache, logits = self._decode(self.params, self.cache,
                                          self.tokens)
        self.tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.steps += 1
        for slot in self.slots:
            if slot.rid < 0:
                continue
            self.pool.extend(slot.rid, 1)
            slot.remaining -= 1
            if slot.remaining <= 0:
                self.pool.retire(slot.rid)
                self.completed.append((slot.rid, slot.arrived, now))
                slot.rid = -1
        self.pool.pump(self.scfg.compact_budget_tokens)

    def active(self) -> int:
        return sum(1 for s in self.slots if s.rid >= 0)


def two_phase_admission(make_server: Callable[[], BatchServer],
                        testing_steps: int = 300,
                        running_steps: int = 600,
                        utilization: float = 0.95,
                        prompt_tokens: int = 8,
                        pcts=(50, 95, 99)) -> dict:
    """Calibrate a sustainable admission rate with the paper's two-phase
    method.  Time unit = decode steps (deterministic on CPU)."""
    # -- testing phase: closed system (always keep the queue non-empty)
    srv = make_server()
    for t in range(testing_steps):
        while len(srv.queue) < srv.scfg.batch_size:
            srv.submit(float(t), prompt_tokens)
        srv.step(float(t))
    done = [c for c in srv.completed if c[1] > testing_steps * 0.2]
    max_rate = len(done) / (testing_steps * 0.8)        # requests per step

    # -- running phase: open system at 95% of measured max
    rate = utilization * max_rate
    srv = make_server()
    acc = 0.0
    for t in range(running_steps):
        acc += rate
        while acc >= 1.0:
            srv.submit(float(t), prompt_tokens)
            acc -= 1.0
        srv.step(float(t))
    lats = np.array([t1 - t0 for _, t0, t1 in srv.completed])
    lat_pcts = {p: float(np.percentile(lats, p)) if len(lats) else
                float("inf") for p in pcts}
    return {"max_rate_per_step": max_rate,
            "admitted_rate": rate,
            "completed": len(srv.completed),
            "latency_pcts_steps": lat_pcts,
            "admission_stalls": srv.pool.stats["admission_stalls"],
            "occupancy": srv.pool.occupancy}
