"""Pallas TPU kernels for the perf-critical compute layers.

merge     — two-way sorted merge via merge-path (LSM compaction inner loop)
bloom     — Bloom filter probe (point-lookup hot path)
attention — blocked causal flash attention with GQA (LM substrate)
ssd       — Mamba2 state-space-duality chunked scan (ssm/hybrid archs)

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public API), ref.py (pure-jnp/numpy oracle).  Kernels target TPU and are
validated on CPU with interpret=True.
"""
from . import attention, bloom, merge, ssd  # noqa: F401
