"""Pallas TPU kernel: paged decode attention (block-table indirection).

The serving-side hot spot of the LSM-style KV pool (serving/kv_pool.py):
each request's KV lives in non-contiguous fixed-size pages, located by a
block table — reading it contiguously would require the compaction the
pool schedules; the kernel instead follows the indirection, which is
what makes lazy (greedy-scheduled) page reclamation affordable.

Layout: pages are (n_pages, Hkv, page_tokens, D) so one (page, D) tile
per kv-head is a contiguous dynamic slice.  Grid: (B, Hkv), one step per
(sequence, kv head); the block table and sequence lengths ride in SMEM
via scalar prefetch; the online-softmax state lives in registers across
a ``fori_loop`` over the table.  On real TPUs the page loads become
double-buffered DMAs; in interpret mode they are dynamic slices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, kp_ref, vp_ref, o_ref,
                  *, page: int, max_pages: int, scale: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
    G, D = q.shape
    n = lens_ref[b]

    def body(i, carry):
        m, l, acc = carry
        pid = tables_ref[b, i]
        k = kp_ref[pid, h].astype(jnp.float32)         # (page, D)
        v = vp_ref[pid, h].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        valid = (pos < n) & (i < ((n + page - 1) // page))
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new) * valid.astype(jnp.float32)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, 1), jnp.float32)
    a0 = jnp.zeros((G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, max_pages, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_kernel(q, k_pages, v_pages, block_tables, seq_lens,
                           interpret: bool = True):
    """q: (B, Hkv, G, D); k/v_pages: (n_pages, Hkv, page, D);
    block_tables: (B, max_pages) int32; seq_lens: (B,) int32.
    Returns (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    n_pages, _, page, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    scale = D ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, *_: (b, h, 0, 0)),
            pl.BlockSpec(k_pages.shape, lambda b, h, *_: (0, 0, 0, 0)),
            pl.BlockSpec(v_pages.shape, lambda b, h, *_: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, *_: (b, h, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, page=page, max_pages=max_pages,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages)
