"""Public paged-attention API: head-layout plumbing around the kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .paged_attention import paged_attention_kernel


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                           interpret: bool = True):
    """q: (B, H, D) single-token queries with G-major head order
    (head = g*Hkv + kv, matching models/layers.py); pages as in the
    kernel.  Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv = k_pages.shape[1]
    G = H // Hkv
    qg = q.reshape(B, G, Hkv, D).transpose(0, 2, 1, 3)   # (B, Hkv, G, D)
    out = paged_attention_kernel(qg, k_pages, v_pages,
                                 jnp.asarray(block_tables, jnp.int32),
                                 jnp.asarray(seq_lens, jnp.int32),
                                 interpret=interpret)
    return out.transpose(0, 2, 1, 3).reshape(B, H, D)
