"""Pure-jnp oracle for paged decode attention: gather the pages into a
contiguous cache, then dense masked attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Same signature as the kernel; returns (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    _, _, page, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    def per_seq(qb, table, n):
        k = k_pages[table]                     # (max_pages, Hkv, page, D)
        v = v_pages[table]
        k = k.transpose(1, 0, 2, 3).reshape(Hkv, max_pages * page, D)
        v = v.transpose(1, 0, 2, 3).reshape(Hkv, max_pages * page, D)
        s = jnp.einsum("hgd,hkd->hgk", qb.astype(jnp.float32),
                       k.astype(jnp.float32)) * (D ** -0.5)
        mask = jnp.arange(max_pages * page) < n
        s = jnp.where(mask[None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("hgk,hkd->hgd", p, v.astype(jnp.float32))

    out = jax.vmap(per_seq)(q, block_tables, seq_lens)
    return out.astype(q.dtype)
