from .ops import paged_decode_attention
