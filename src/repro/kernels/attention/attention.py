"""Pallas TPU kernel: blocked (flash) attention with GQA and causal
masking — the LM substrate's dominant non-matmul hot spot.

Canonical TPU structure: a sequential 3D grid (batch*heads, q_blocks,
kv_blocks) with VMEM scratch carrying the running max / normalizer /
accumulator across the innermost kv dimension; out-of-causal kv blocks
are skipped with ``pl.when`` so the diagonal costs ~half of full
attention.  GQA maps query head -> kv head purely in the BlockSpec
index_map, so grouped K/V blocks are fetched once per group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    in_past = (ki * bk <= qi * bq + bq - 1) if causal else True

    @pl.when(in_past)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                              "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) with H % Hkv == 0.

    Sequence length must be a multiple of the block sizes (ops.py pads).
    """
    B, H, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0 and S % bq == 0 and Sk % bk == 0
    group = H // Hkv
    scale = D ** -0.5
    grid = (B * H, S // bq, Sk // bk)

    def q_map(bh, qi, ki):
        return (bh // H, bh % H, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // H, (bh % H) // group, ki, 0)

    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), q_map),
            pl.BlockSpec((1, 1, bk, D), kv_map),
            pl.BlockSpec((1, 1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
