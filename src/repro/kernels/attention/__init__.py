"""attention kernel package."""
from . import ops, ref
