"""Public attention API: padding/plumbing around the flash kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .attention import flash_attention


def attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128,
              interpret: bool = True):
    """Flash attention with automatic sequence padding.

    q: (B, H, S, D); k, v: (B, Hkv, Sk, D).  Padded kv positions are
    masked by the causal structure (query padding rows are sliced off;
    for non-causal inputs kv must already be a block multiple).
    """
    B, H, S, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, max(S, 8))
    bk = min(bk, max(Sk, 8))
    pad_q = (-S) % bq
    pad_k = (-Sk) % bk
    if pad_k and not causal:
        raise ValueError("non-causal attention requires block-aligned kv")
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=interpret)
    return out[:, :, :S, :]
