"""Pure-jnp oracle for flash attention (materialized softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        Sk = k.shape[2]
        mask = jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
