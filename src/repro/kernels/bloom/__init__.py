"""bloom kernel package."""
from . import ops, ref
