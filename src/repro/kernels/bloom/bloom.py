"""Pallas TPU kernel: Bloom-filter probe (Section 2.1 — the point-lookup
filter the paper's query experiments lean on).

The SSD-era idiom pokes single bits through byte addressing; the TPU
adaptation probes a whole 128-lane block of query keys per grid step with
double hashing, gathering filter words from a VMEM-resident filter.
Building the filter is a scatter (done once per flush/merge) and stays in
ops.py as an XLA ``.at[].max()``; probing is the hot path (once per
component per point lookup).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def hash_u32(x, seed: int):
    """xorshift-multiply finalizer on uint32 lanes."""
    x = x.astype(jnp.uint32) ^ jnp.uint32(seed)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    return x ^ (x >> 16)


def bit_positions(keys, n_bits: int, k_hashes: int):
    """Double hashing: pos_i = (h1 + i*h2) mod n_bits, shape (k, n)."""
    h1 = hash_u32(keys, 0x9E3779B9)
    h2 = hash_u32(keys, 0x85EBCA6B) | jnp.uint32(1)  # odd stride
    i = jnp.arange(k_hashes, dtype=jnp.uint32)[:, None]
    return ((h1[None, :] + i * h2[None, :]) % jnp.uint32(n_bits)).astype(jnp.int32)


def _probe_kernel(filt_ref, keys_ref, out_ref, *, n_bits, k_hashes):
    filt = filt_ref[...]
    keys = keys_ref[...].reshape(-1)
    pos = bit_positions(keys, n_bits, k_hashes)       # (k, q)
    words = filt[pos >> 5]                            # gather (k, q)
    bits = (words >> (pos & 31).astype(jnp.uint32)) & jnp.uint32(1)
    hit = jnp.min(bits, axis=0)                       # AND over k hashes
    out_ref[...] = hit.astype(jnp.uint8).reshape(out_ref.shape)


def _probe_multi_kernel(filt_ref, meta_ref, keys_ref, out_ref, *, k_max):
    """One grid step probes one key block against ONE table's filter.

    Per-table (n_bits, k_hashes) arrive as data (``meta``), not statics, so
    a single launch covers tables with heterogeneous filter geometry: each
    table hashes modulo its own n_bits (padding words past n_bits/32 are
    never addressed) and hash lanes beyond its own k are forced to 1 so
    they cannot veto membership.
    """
    filt = filt_ref[...].reshape(-1)
    n_bits = meta_ref[0, 0]                           # uint32 scalar
    k = meta_ref[0, 1]
    keys = keys_ref[...].reshape(-1)
    h1 = hash_u32(keys, 0x9E3779B9)
    h2 = hash_u32(keys, 0x85EBCA6B) | jnp.uint32(1)   # odd stride
    i = jnp.arange(k_max, dtype=jnp.uint32)[:, None]
    pos = ((h1[None, :] + i * h2[None, :]) % n_bits).astype(jnp.int32)
    words = filt[pos >> 5]                            # gather (k_max, q)
    bits = (words >> (pos & 31).astype(jnp.uint32)) & jnp.uint32(1)
    bits = jnp.where(i < k, bits, jnp.uint32(1))      # unused lanes pass
    hit = jnp.min(bits, axis=0)                       # AND over k hashes
    out_ref[...] = hit.astype(jnp.uint8).reshape(out_ref.shape)


@functools.partial(jax.jit, static_argnames=("k_max", "block", "interpret"))
def bloom_probe_multi_kernel(filts, meta, keys, k_max: int,
                             block: int = 1024, interpret: bool = True):
    """Fused probe of one key batch against a STACK of filters.

    ``filts`` is (tables, words) uint32 — each row a filter zero-padded to
    the common word count; ``meta`` is (tables, 2) uint32 rows of
    (n_bits, k_hashes).  Returns (tables, n_keys) uint8 maybe-present
    flags in one launch over a (tables, key-blocks) grid — the hot path
    for batched point lookups across a whole LSM tree.
    """
    t, w = filts.shape
    n = keys.shape[0]
    assert n % block == 0, "pad keys in ops.py"
    grid = (t, n // block)
    return pl.pallas_call(
        functools.partial(_probe_multi_kernel, k_max=k_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i, j: (i, 0)),   # this table's filter
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),   # its (n_bits, k)
            pl.BlockSpec((block,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.uint8),
        interpret=interpret,
    )(filts, meta, keys)


@functools.partial(jax.jit, static_argnames=("n_bits", "k_hashes", "block",
                                              "interpret"))
def bloom_probe_kernel(filt, keys, n_bits: int, k_hashes: int,
                       block: int = 1024, interpret: bool = True):
    """Probe ``keys`` (padded to a multiple of ``block``) against ``filt``
    (uint32 words).  Returns uint8 maybe-present flags."""
    n = keys.shape[0]
    assert n % block == 0, "pad keys in ops.py"
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, n_bits=n_bits, k_hashes=k_hashes),
        grid=grid,
        in_specs=[
            pl.BlockSpec(filt.shape, lambda i: (0,)),       # filter resident
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=interpret,
    )(filt, keys)
