"""Oracle for the Bloom filter: an explicit numpy bit-set with the same
hash family (membership semantics verified independently of the packing
and kernel paths)."""
from __future__ import annotations

import numpy as np


def _hash_np(x: np.ndarray, seed: int) -> np.ndarray:
    x = x.astype(np.uint32) ^ np.uint32(seed)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x45D9F3B)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x45D9F3B)
    return x ^ (x >> np.uint32(16))


def bit_positions_ref(keys: np.ndarray, n_bits: int, k_hashes: int) -> np.ndarray:
    h1 = _hash_np(keys, 0x9E3779B9)
    h2 = _hash_np(keys, 0x85EBCA6B) | np.uint32(1)
    i = np.arange(k_hashes, dtype=np.uint32)[:, None]
    return ((h1[None, :] + i * h2[None, :]) % np.uint32(n_bits)).astype(np.int64)


def bloom_build_ref(keys: np.ndarray, n_bits: int, k_hashes: int) -> np.ndarray:
    bits = np.zeros(n_bits, dtype=bool)
    bits[bit_positions_ref(np.asarray(keys), n_bits, k_hashes).reshape(-1)] = True
    return bits


def bloom_probe_ref(bits: np.ndarray, keys: np.ndarray, n_bits: int,
                    k_hashes: int) -> np.ndarray:
    pos = bit_positions_ref(np.asarray(keys), n_bits, k_hashes)
    return bits[pos].all(axis=0)
