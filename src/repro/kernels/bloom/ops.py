"""Public Bloom-filter API: build (XLA scatter, once per component) +
probe (Pallas kernel, the per-lookup hot path)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .bloom import (bit_positions, bloom_probe_kernel,
                    bloom_probe_multi_kernel)


def filter_params(n_keys: int, fpr: float = 0.01) -> tuple[int, int]:
    """(n_bits, k_hashes) for a target false-positive rate (1% in the
    paper's setup, Section 3.1)."""
    n_keys = max(n_keys, 1)
    n_bits = int(math.ceil(-n_keys * math.log(fpr) / (math.log(2) ** 2)))
    n_bits = max(128, (n_bits + 127) // 128 * 128)
    k = max(1, round(n_bits / n_keys * math.log(2)))
    return n_bits, min(k, 16)


@functools.partial(jax.jit, static_argnames=("n_bits", "k_hashes"))
def bloom_build(keys, n_bits: int, k_hashes: int):
    """Build the filter as uint32 words.

    OR-semantics via an idempotent scatter-max into a byte-per-bit array,
    then a vectorized pack — duplicate positions are harmless by
    construction.
    """
    pos = bit_positions(keys.astype(jnp.uint32), n_bits, k_hashes).reshape(-1)
    bits = jnp.zeros((n_bits,), jnp.uint8).at[pos].max(jnp.uint8(1))
    lanes = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(bits.reshape(-1, 32).astype(jnp.uint32) * lanes[None, :],
                   axis=1, dtype=jnp.uint32)


def bloom_probe(filt, keys, n_bits: int, k_hashes: int, block: int = 1024,
                interpret: bool = True):
    """Probe keys; returns a bool maybe-present mask (no false negatives)."""
    n = keys.shape[0]
    pad = (-n) % block
    kp = jnp.concatenate([keys.astype(jnp.uint32),
                          jnp.zeros((pad,), jnp.uint32)])
    out = bloom_probe_kernel(filt, kp, n_bits, k_hashes, block=block,
                             interpret=interpret)
    return out[:n].astype(bool)


def stack_filters(filters, n_bits_list, k_hashes_list):
    """Pad per-table filters to a common word count and pack their
    geometry: returns (filts (T, W) uint32, meta (T, 2) uint32) ready for
    ``bloom_probe_multi``.  ``meta`` stays host-side numpy so callers can
    derive the static k_max without a device sync."""
    t = len(filters)
    w = max((f.shape[0] for f in filters), default=1)
    filts = np.zeros((t, max(w, 1)), np.uint32)
    meta = np.zeros((t, 2), np.uint32)
    for i, (f, nb, kh) in enumerate(zip(filters, n_bits_list,
                                        k_hashes_list)):
        f = np.asarray(f, np.uint32)
        filts[i, :f.shape[0]] = f
        meta[i] = (nb, kh)
    return filts, meta


@functools.partial(jax.jit, donate_argnums=(0,))
def _set_row_donated(filts, row, slot):
    return filts.at[slot].set(row)


def set_stack_row(filts, row_words, slot):
    """Write one filter's words into row ``slot`` of a stacked device
    filter array, donating the input buffer so backends that support
    input-output aliasing update the row IN PLACE — O(row) instead of the
    O(tables * width) restack-and-reupload of ``stack_filters``.  This is
    the engine's incremental read-view maintenance primitive: one call
    per flush output / merge output.  ``row_words`` shorter than the
    stack width must be pre-padded by the caller.  The donated input
    array is consumed — callers must replace every reference with the
    returned array.  Operands cross the jit boundary raw (the row as
    host uint32 words, the slot as a Python int): explicit
    ``jnp.asarray``/``jnp.int32`` staging costs an order of magnitude
    more dispatch than the row write itself."""
    return _set_row_donated(filts, row_words, int(slot))


def bloom_probe_multi_host(filts_np: np.ndarray, meta: np.ndarray,
                           keys: np.ndarray) -> np.ndarray:
    """Host twin of ``bloom_probe_multi``: the same double-hashing probe
    over the HOST mirror of the stacked filter words, pure numpy — the
    execution backend's CPU fast path for the fused probe (bit-identical
    to the kernel by construction: same hash family, same per-row
    geometry semantics, unused hash lanes pass).

    ``filts_np`` is (tables, words) uint32, ``meta`` (tables, 2) uint32
    rows of (n_bits, k_hashes).  Returns a (tables, keys) bool matrix.
    Rows iterate in Python (tables are tens, keys are the batch — the
    inner work is vectorized numpy over (k, q))."""
    from .ref import _hash_np
    keys = np.asarray(keys, np.uint32)
    t, q = int(filts_np.shape[0]), len(keys)
    out = np.zeros((t, q), bool)
    if t == 0 or q == 0:
        return out
    h1 = _hash_np(keys, 0x9E3779B9)
    h2 = _hash_np(keys, 0x85EBCA6B) | np.uint32(1)
    i_max = np.arange(int(meta[:, 1].max()), dtype=np.uint32)[:, None]
    for r in range(t):
        n_bits = np.uint32(meta[r, 0])
        k = int(meta[r, 1])
        pos = ((h1[None, :] + i_max[:k] * h2[None, :]) % n_bits) \
            .astype(np.int64)                           # (k, q)
        words = filts_np[r, pos >> 5]
        bits = (words >> (pos & 31).astype(np.uint32)) & np.uint32(1)
        out[r] = bits.min(axis=0).astype(bool)
    return out


def bloom_probe_multi(filts, meta, keys, block: int = 1024,
                      interpret: bool = True):
    """Probe one key batch against a stack of padded filters (see
    ``stack_filters``) in a single fused launch; returns a (tables, keys)
    bool maybe-present matrix (no false negatives per table)."""
    t = filts.shape[0]
    n = keys.shape[0]
    if t == 0 or n == 0:
        return np.zeros((t, n), bool)
    meta = np.asarray(meta, np.uint32)
    pad = (-n) % block
    kp = jnp.concatenate([jnp.asarray(keys, jnp.uint32),
                          jnp.zeros((pad,), jnp.uint32)])
    out = bloom_probe_multi_kernel(jnp.asarray(filts), jnp.asarray(meta),
                                   kp, k_max=int(meta[:, 1].max()),
                                   block=block, interpret=interpret)
    return np.asarray(out[:, :n]).astype(bool)
