"""Public SSD API: padding/reshaping around the chunked-scan kernel, plus
the single-step decode update used by serve_step."""
from __future__ import annotations

import jax.numpy as jnp

from .ssd import ssd_scan


def ssd(x, b, c, alog, dt, chunk: int = 64, interpret: bool = True):
    """Chunked SSD scan with automatic length padding.

    x: (BH, L, P); b, c: (BH, L, N); alog, dt: (BH, L) -> (BH, L, P).
    """
    BH, L, P = x.shape
    pad = (-L) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, b, c, alog, dt = map(zf, (x, b, c, alog, dt))
    y = ssd_scan(x, b, c, alog, dt, chunk=chunk, interpret=interpret)
    return y[:, :L]


def ssd_decode_step(state, x_t, b_t, c_t, alog_t, dt_t):
    """One recurrent step (decode):   state: (BH, N, P), x_t: (BH, P),
    b_t/c_t: (BH, N), alog_t/dt_t: (BH,).  Returns (state', y_t)."""
    decay = jnp.exp(alog_t)[:, None, None]
    state = decay * state + (dt_t[:, None] * b_t)[:, :, None] * x_t[:, None, :]
    y = jnp.einsum("bn,bnp->bp", c_t, state)
    return state, y.astype(x_t.dtype)
