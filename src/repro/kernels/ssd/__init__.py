"""ssd kernel package."""
from . import ops, ref
