"""Pure-jnp oracle for the SSD chunked scan: the literal per-step
recurrence  h_t = a_t h_{t-1} + dt_t B_t x_t^T,  y_t = C_t h_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, b, c, alog, dt):
    """x: (BH, L, P); b, c: (BH, L, N); alog, dt: (BH, L) -> y: (BH, L, P)."""

    def per_seq(xs, bs, cs, als, dts):
        N, P = bs.shape[-1], xs.shape[-1]

        def step(h, inp):
            xt, bt, ct, at, dtt = inp
            h = jnp.exp(at) * h + dtt * jnp.outer(bt, xt)
            return h, ct @ h

        h0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xs.astype(jnp.float32),
                                        bs.astype(jnp.float32),
                                        cs.astype(jnp.float32),
                                        als.astype(jnp.float32),
                                        dts.astype(jnp.float32)))
        return ys

    return jax.vmap(per_seq)(x, b, c, alog, dt).astype(x.dtype)
