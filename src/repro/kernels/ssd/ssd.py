"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

Recurrence per (batch, head):   h_t = a_t * h_{t-1} + dt_t * B_t x_t^T,
y_t = C_t h_t  — with a_t = exp(A * dt_t) a per-step scalar decay.

The GPU reference implementation leans on warp-level scans; the TPU
adaptation uses the SSD block decomposition: a sequential grid over
chunks with the (N, P) inter-chunk state in VMEM scratch; within a chunk
everything is dense matmuls (MXU) against a causal decay mask — no
per-step recurrence at all.

Grid: (B*H, n_chunks), chunk dim sequential so the state carries across.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, alog_ref, dt_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    alog = alog_ref[0].astype(jnp.float32)    # (Q,)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)

    cum = jnp.cumsum(alog)                    # inclusive within-chunk decay
    total = cum[-1]
    # intra-chunk: y_t += sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
    decay = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = rows >= cols
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = jnp.where(mask, cb * decay, 0.0) * dt[None, :]
    y = jax.lax.dot(m, x, preferred_element_type=jnp.float32)
    # inter-chunk: y_t += exp(cum_t) * C_t @ state
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot(
        c, state_ref[...], preferred_element_type=jnp.float32)
    # state' = exp(total) * state + sum_s exp(total - cum_s) dt_s B_s x_s^T
    w = (jnp.exp(total - cum) * dt)[:, None] * b   # (Q, N)
    state_ref[...] = jnp.exp(total) * state_ref[...] + jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, b, c, alog, dt, chunk: int = 64, interpret: bool = True):
    """x: (BH, L, P); b, c: (BH, L, N); alog, dt: (BH, L).

    L must be a multiple of ``chunk`` (ops.py pads).  Returns y: (BH, L, P).
    """
    BH, L, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0
    grid = (BH, L // chunk)

    def tmap(bh, ci):
        return (bh, ci, 0)

    def smap(bh, ci):
        return (bh, ci)

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), tmap),
            pl.BlockSpec((1, chunk, N), tmap),
            pl.BlockSpec((1, chunk, N), tmap),
            pl.BlockSpec((1, chunk), smap),
            pl.BlockSpec((1, chunk), smap),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), tmap),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, b, c, alog, dt)
