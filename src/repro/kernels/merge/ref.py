"""Pure-jnp oracle for the sorted-merge kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .merge import _sentinel


def merge_sorted_ref(keys_a, vals_a, keys_b, vals_b):
    """Stable two-run merge: ties prefer run A.  Returns (keys, vals, src)."""
    keys = jnp.concatenate([keys_a, keys_b])
    vals = jnp.concatenate([vals_a, vals_b])
    srcs = jnp.concatenate([jnp.zeros(keys_a.shape, jnp.int32),
                            jnp.ones(keys_b.shape, jnp.int32)])
    order = jnp.lexsort((srcs, keys))
    return keys[order], vals[order], srcs[order]


def merge_dedup_ref(keys_a, vals_a, keys_b, vals_b):
    """Oracle for merge + newest-wins dedup, via a plain dict (numpy)."""
    d = {}
    for k, v in zip(np.asarray(keys_b), np.asarray(vals_b)):
        d[int(k)] = v
    for k, v in zip(np.asarray(keys_a), np.asarray(vals_a)):
        d[int(k)] = v          # A (newer) overwrites B
    items = sorted(d.items())
    ks = np.array([k for k, _ in items])
    vs = np.array([v for _, v in items])
    return ks, vs


def merge_dedup_kway_ref(runs):
    """Oracle for the k-way tournament (runs NEWEST first): replay the
    runs oldest -> newest into a dict so later (newer) writes win."""
    d = {}
    for ks, vs in reversed(list(runs)):
        for k, v in zip(np.asarray(ks), np.asarray(vs)):
            d[int(k)] = int(v)
    items = sorted(d.items())
    ks = np.array([k for k, _ in items], np.uint32)
    vs = np.array([v for _, v in items], np.int32)
    return ks, vs
