"""jit'd public API for the sorted-merge kernel: co-rank planning, padding,
the Pallas call, and newest-wins deduplication.

Two entry points: ``merge_dedup`` (the original pairwise compaction step)
and ``merge_dedup_kway`` (a balanced tournament reduction over the
age-carrying pairwise kernel — the k-way merge behind the engine's range
plane and multi-input compactions)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .merge import _sentinel, merge_path_merge, merge_path_merge_age


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def merge_partitions(keys_a, keys_b, n_a: int, n_b: int, block: int):
    """Exact merge-path co-rank for each output-block diagonal d = k*block.

    With the kernel's tie rule (equal keys take run A — the newer LSM
    component — first), element A[p]'s position in the merged sequence is
    exactly ``p + searchsorted(B, A[p], 'left')``; these positions are a
    permutation, so the co-rank at diagonal d is
    ``i(d) = searchsorted(pos_A, d)`` with j(d) = d - i(d).  Closed-form
    and exact — no binary-search boundary repair.
    """
    g = _ceil_to(n_a + n_b, block) // block
    diags = jnp.minimum(jnp.arange(g + 1, dtype=jnp.int32) * block,
                        n_a + n_b)
    ka = keys_a[:n_a]
    kb = keys_b[:n_b]
    pos_a = jnp.arange(n_a, dtype=jnp.int32) + \
        jnp.searchsorted(kb, ka, side="left").astype(jnp.int32)
    i_final = jnp.searchsorted(pos_a, diags, side="left").astype(jnp.int32)
    j_final = diags - i_final
    return jnp.stack([i_final, j_final], axis=1).astype(jnp.int32)


def _pad_run(keys, vals, block: int):
    n = keys.shape[0]
    pad = _ceil_to(n, block) - n + block  # sentinel tail >= block
    sent = _sentinel(keys.dtype)
    keys = jnp.concatenate([keys, jnp.full((pad,), sent, keys.dtype)])
    vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return keys, vals


def merge_sorted(keys_a, vals_a, keys_b, vals_b, block: int = 256,
                 interpret: bool = True):
    """Merge two sorted runs; A is the newer run (wins ties).

    Returns (keys, vals, src, valid_len) where the first ``valid_len``
    entries are the merged output (entries beyond are sentinel padding).
    """
    n_a, n_b = keys_a.shape[0], keys_b.shape[0]
    ka, va = _pad_run(keys_a, vals_a, block)
    kb, vb = _pad_run(keys_b, vals_b, block)
    parts = merge_partitions(ka, kb, n_a, n_b, block)
    mk, mv, ms = merge_path_merge(ka, va, kb, vb, parts, block=block,
                                  interpret=interpret)
    return mk, mv, ms, n_a + n_b


def dedup_newest(keys, vals, srcs, valid_len):
    """Newest-wins dedup of a merged run (A-entries sort before equal
    B-entries): keep an entry iff it is the first of its equal-key group."""
    n = keys.shape[0]
    idx = jnp.arange(n)
    prev_same = jnp.concatenate([jnp.array([False]),
                                 keys[1:] == keys[:-1]])
    keep = (~prev_same) & (idx < valid_len)
    return keep


def merge_dedup(keys_a, vals_a, keys_b, vals_b, block: int = 256,
                interpret: bool = True):
    """Full compaction step: merge + newest-wins dedup.

    Returns (keys, vals, keep_mask, valid_len); callers typically compact
    with ``jnp.where`` + host-side slicing (the engine does this once per
    merge quantum, amortized)."""
    mk, mv, ms, valid = merge_sorted(keys_a, vals_a, keys_b, vals_b,
                                     block=block, interpret=interpret)
    keep = dedup_newest(mk, mv, ms, valid)
    return mk, mv, keep, valid


# --------------------------------------------------------------- k-way
_AGE_PAD = jnp.iinfo(jnp.int32).max    # sentinel tail age (oldest possible)


def _pad_run_age(keys, vals, ages, block: int):
    n = keys.shape[0]
    pad = _ceil_to(n, block) - n + block  # sentinel tail >= block
    sent = _sentinel(keys.dtype)
    keys = jnp.concatenate([keys, jnp.full((pad,), sent, keys.dtype)])
    vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    ages = jnp.concatenate([ages, jnp.full((pad,), _AGE_PAD, jnp.int32)])
    return keys, vals, ages


def merge_sorted_age(keys_a, vals_a, age_a, keys_b, vals_b, age_b,
                     block: int = 256, interpret: bool = True):
    """One tournament round step: merge two (key, age)-sorted runs whose
    age sets are disjoint with every A-age < every B-age.  Returns
    (keys, vals, ages, valid_len) with sentinel padding past valid_len."""
    n_a, n_b = keys_a.shape[0], keys_b.shape[0]
    ka, va, aa = _pad_run_age(keys_a, vals_a, age_a, block)
    kb, vb, ab = _pad_run_age(keys_b, vals_b, age_b, block)
    parts = merge_partitions(ka, kb, n_a, n_b, block)
    mk, mv, ma = merge_path_merge_age(ka, va, aa, kb, vb, ab, parts,
                                      block=block, interpret=interpret)
    return mk, mv, ma, n_a + n_b


def merge_dedup_kway_window(runs, starts, stops, block: int = 256,
                            interpret: bool = True,
                            drop_value: int | None = None):
    """Streaming-quantum (block-stepped) variant of ``merge_dedup_kway``:
    merge only the ``[starts[i], stops[i])`` window of each run.

    The engine's streaming merge cursor cuts windows at a GLOBAL key
    boundary (no equal-key group straddles a cut), so per-window
    newest-wins dedup composes exactly: concatenating successive windows'
    outputs is bit-identical to ``merge_dedup_kway`` over the full runs.
    Run-list order is still newest-first, and an empty window keeps its
    position's age rank (``merge_dedup_kway`` tags ages by list index),
    so the tournament's tie-breaking is unchanged.  Per call the kernel
    touches O(sum(stops - starts) + k*block) entries — each window pads
    to the block grid — which is the bounded-lock-hold contract of the
    engine's background plane.
    """
    windows = [(k[s:e], v[s:e])
               for (k, v), s, e in zip(runs, starts, stops)]
    return merge_dedup_kway(windows, block=block, interpret=interpret,
                            drop_value=drop_value)


def merge_dedup_kway(runs, block: int = 256, interpret: bool = True,
                     drop_value: int | None = None):
    """K-way newest-wins merge of sorted unique runs (NEWEST run first).

    A balanced tournament reduction over the age-carrying pairwise
    merge-path kernel: each element enters tagged with its run index as an
    age (smaller = newer), adjacent pairs are merged per round (left run
    newer — list order keeps age groups contiguous, so every A-age < every
    B-age and the pairwise tie rule stays exact), and duplicates survive
    until ONE final compaction pass masks every non-first element of each
    equal-key group.  O(n log k) merged entries vs O(n*k) for the
    sequential pairwise fold.

    ``drop_value`` fuses tombstone reclamation into the compaction mask:
    an equal-key group whose NEWEST (winning) version carries this value
    is dropped entirely — the read plane passes the engine's tombstone
    sentinel here for scans, and bottom-level merges pass it to reclaim
    deleted keys (older shadowed versions fall to the dedup mask
    regardless, so only the winner's value needs testing).

    Returns compacted (keys, vals) jnp arrays, sorted ascending.
    """
    entries = []
    for i, (k, v) in enumerate(runs):
        k = jnp.asarray(k)
        v = jnp.asarray(v)
        if k.shape[0]:
            entries.append((k, v, jnp.full(k.shape, i, jnp.int32),
                            int(k.shape[0])))
    if not entries:
        return jnp.empty(0, jnp.uint32), jnp.empty(0, jnp.int32)
    while len(entries) > 1:
        nxt = []
        for j in range(0, len(entries) - 1, 2):
            ka, va, aa, na = entries[j]
            kb, vb, ab, nb = entries[j + 1]
            mk, mv, ma, valid = merge_sorted_age(
                ka[:na], va[:na], aa[:na], kb[:nb], vb[:nb], ab[:nb],
                block=block, interpret=interpret)
            nxt.append((mk, mv, ma, valid))
        if len(entries) % 2:
            nxt.append(entries[-1])
        entries = nxt
    keys, vals, _, valid = entries[0]
    keys, vals = keys[:valid], vals[:valid]
    # single compaction pass: runs are (key, age)-sorted, so the first
    # element of each equal-key group is the newest version
    first = jnp.ones(valid, bool).at[1:].set(keys[1:] != keys[:-1])
    if drop_value is not None:
        first = first & (vals != jnp.int32(drop_value))
    return keys[first], vals[first]
