"""jit'd public API for the sorted-merge kernel: co-rank planning, padding,
the Pallas call, and newest-wins deduplication."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .merge import _sentinel, merge_path_merge


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def merge_partitions(keys_a, keys_b, n_a: int, n_b: int, block: int):
    """Exact merge-path co-rank for each output-block diagonal d = k*block.

    With the kernel's tie rule (equal keys take run A — the newer LSM
    component — first), element A[p]'s position in the merged sequence is
    exactly ``p + searchsorted(B, A[p], 'left')``; these positions are a
    permutation, so the co-rank at diagonal d is
    ``i(d) = searchsorted(pos_A, d)`` with j(d) = d - i(d).  Closed-form
    and exact — no binary-search boundary repair.
    """
    g = _ceil_to(n_a + n_b, block) // block
    diags = jnp.minimum(jnp.arange(g + 1, dtype=jnp.int32) * block,
                        n_a + n_b)
    ka = keys_a[:n_a]
    kb = keys_b[:n_b]
    pos_a = jnp.arange(n_a, dtype=jnp.int32) + \
        jnp.searchsorted(kb, ka, side="left").astype(jnp.int32)
    i_final = jnp.searchsorted(pos_a, diags, side="left").astype(jnp.int32)
    j_final = diags - i_final
    return jnp.stack([i_final, j_final], axis=1).astype(jnp.int32)


def _pad_run(keys, vals, block: int):
    n = keys.shape[0]
    pad = _ceil_to(n, block) - n + block  # sentinel tail >= block
    sent = _sentinel(keys.dtype)
    keys = jnp.concatenate([keys, jnp.full((pad,), sent, keys.dtype)])
    vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return keys, vals


def merge_sorted(keys_a, vals_a, keys_b, vals_b, block: int = 256,
                 interpret: bool = True):
    """Merge two sorted runs; A is the newer run (wins ties).

    Returns (keys, vals, src, valid_len) where the first ``valid_len``
    entries are the merged output (entries beyond are sentinel padding).
    """
    n_a, n_b = keys_a.shape[0], keys_b.shape[0]
    ka, va = _pad_run(keys_a, vals_a, block)
    kb, vb = _pad_run(keys_b, vals_b, block)
    parts = merge_partitions(ka, kb, n_a, n_b, block)
    mk, mv, ms = merge_path_merge(ka, va, kb, vb, parts, block=block,
                                  interpret=interpret)
    return mk, mv, ms, n_a + n_b


def dedup_newest(keys, vals, srcs, valid_len):
    """Newest-wins dedup of a merged run (A-entries sort before equal
    B-entries): keep an entry iff it is the first of its equal-key group."""
    n = keys.shape[0]
    idx = jnp.arange(n)
    prev_same = jnp.concatenate([jnp.array([False]),
                                 keys[1:] == keys[:-1]])
    keep = (~prev_same) & (idx < valid_len)
    return keep


def merge_dedup(keys_a, vals_a, keys_b, vals_b, block: int = 256,
                interpret: bool = True):
    """Full compaction step: merge + newest-wins dedup.

    Returns (keys, vals, keep_mask, valid_len); callers typically compact
    with ``jnp.where`` + host-side slicing (the engine does this once per
    merge quantum, amortized)."""
    mk, mv, ms, valid = merge_sorted(keys_a, vals_a, keys_b, vals_b,
                                     block=block, interpret=interpret)
    keep = dedup_newest(mk, mv, ms, valid)
    return mk, mv, keep, valid
