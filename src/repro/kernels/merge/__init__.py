"""merge kernel package."""
from . import ops, ref
