"""Pallas TPU kernel: two-way sorted merge via merge-path partitioning.

This is the compaction inner loop the paper's schedulers meter out I/O to.
The CPU/GPU idiom (an iterator heap) is scalar and branchy; the TPU
adaptation splits the output into fixed-size blocks whose input windows
are located by a *merge-path* co-rank search (done once, vectorized, in
ops.py) and merges each window pair with a data-parallel bitonic merge
network — pure VPU compare/exchange ops, no data-dependent control flow.

Grid: one step per output block.  The co-rank partitions arrive as scalar
prefetch (SMEM) so each step dynamically slices its input windows; the
padded runs carry a +inf-equivalent sentinel tail so window loads never
run out of bounds.  Ties between runs resolve to run A (the *newer* LSM
component), which makes the downstream newest-wins dedup a pure
adjacent-key mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sentinel(dtype: jnp.dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _cmp_swap(k0, k1, s0, s1, *payloads):
    """Compare-exchange on (key, src) lexicographic order; src breaks ties
    toward run A (src=0, the newer component)."""
    swap = (k0 > k1) | ((k0 == k1) & (s0 > s1))
    out_k = (jnp.where(swap, k1, k0), jnp.where(swap, k0, k1))
    out_s = (jnp.where(swap, s1, s0), jnp.where(swap, s0, s1))
    outs = []
    for (p0, p1) in payloads:
        outs.append((jnp.where(swap, p1, p0), jnp.where(swap, p0, p1)))
    return out_k, out_s, outs


def _bitonic_merge(keys, srcs, payloads):
    """Merge two sorted halves of a 2S vector (ascending), stable on src."""
    n = keys.shape[0]
    half = n // 2
    # reverse the second half -> single bitonic sequence
    rev = lambda x: jnp.concatenate([x[:half], x[half:][::-1]])
    keys, srcs = rev(keys), rev(srcs)
    payloads = [rev(p) for p in payloads]
    stride = half
    while stride >= 1:
        shape = (-1, 2, stride)
        k = keys.reshape(shape)
        s = srcs.reshape(shape)
        ps = [p.reshape(shape) for p in payloads]
        (k0, k1), (s0, s1), pout = _cmp_swap(
            k[:, 0], k[:, 1], s[:, 0], s[:, 1],
            *[(p[:, 0], p[:, 1]) for p in ps])
        keys = jnp.stack([k0, k1], axis=1).reshape(n)
        srcs = jnp.stack([s0, s1], axis=1).reshape(n)
        payloads = [jnp.stack([p0, p1], axis=1).reshape(n) for (p0, p1) in pout]
        stride //= 2
    return keys, srcs, payloads


def _merge_kernel(parts_ref, ka_ref, va_ref, kb_ref, vb_ref,
                  ko_ref, vo_ref, so_ref, *, block: int):
    k = pl.program_id(0)
    ia = parts_ref[k, 0]
    ib = parts_ref[k, 1]
    # next-S-element windows from each run (sentinel tail makes this safe)
    wka = ka_ref[pl.ds(ia, block)]
    wva = va_ref[pl.ds(ia, block)]
    wkb = kb_ref[pl.ds(ib, block)]
    wvb = vb_ref[pl.ds(ib, block)]
    keys = jnp.concatenate([wka, wkb])
    vals = jnp.concatenate([wva, wvb])
    srcs = jnp.concatenate([jnp.zeros((block,), jnp.int32),
                            jnp.ones((block,), jnp.int32)])
    mk, ms, (mv,) = _bitonic_merge(keys, srcs, [vals])
    ko_ref[...] = mk[:block]
    vo_ref[...] = mv[:block]
    so_ref[...] = ms[:block]


def _merge_age_kernel(parts_ref, ka_ref, va_ref, aa_ref, kb_ref, vb_ref,
                      ab_ref, ko_ref, vo_ref, ao_ref, *, block: int):
    """Age-carrying variant for the k-way tournament: instead of the
    synthetic 0/1 src, each element carries its ORIGINAL run index
    (smaller = newer), loaded from the input.  The compare-exchange order
    is (key, age) lexicographic, so intermediate tournament runs — which
    contain duplicate keys from different source runs — stay totally
    ordered (runs have unique keys, making (key, age) pairs distinct) and
    the final newest-wins dedup is still a pure adjacent-key mask."""
    k = pl.program_id(0)
    ia = parts_ref[k, 0]
    ib = parts_ref[k, 1]
    wka = ka_ref[pl.ds(ia, block)]
    wva = va_ref[pl.ds(ia, block)]
    waa = aa_ref[pl.ds(ia, block)]
    wkb = kb_ref[pl.ds(ib, block)]
    wvb = vb_ref[pl.ds(ib, block)]
    wab = ab_ref[pl.ds(ib, block)]
    keys = jnp.concatenate([wka, wkb])
    vals = jnp.concatenate([wva, wvb])
    ages = jnp.concatenate([waa, wab])
    mk, ma, (mv,) = _bitonic_merge(keys, ages, [vals])
    ko_ref[...] = mk[:block]
    vo_ref[...] = mv[:block]
    ao_ref[...] = ma[:block]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def merge_path_merge(keys_a, vals_a, keys_b, vals_b, parts,
                     block: int = 256, interpret: bool = True):
    """Merge two sorted (key, value) runs.

    ``parts``: (g+1, 2) int32 co-rank table from ``ops.merge_partitions``;
    inputs must already carry a ``block``-length sentinel tail.  Returns
    (keys, values, src) of length g*block; entries beyond len(a)+len(b)
    are sentinels.
    """
    g = parts.shape[0] - 1
    out_len = g * block
    kdt, vdt = keys_a.dtype, vals_a.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec(keys_a.shape, lambda k, parts: (0,)),
            pl.BlockSpec(vals_a.shape, lambda k, parts: (0,)),
            pl.BlockSpec(keys_b.shape, lambda k, parts: (0,)),
            pl.BlockSpec(vals_b.shape, lambda k, parts: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda k, parts: (k,)),
            pl.BlockSpec((block,), lambda k, parts: (k,)),
            pl.BlockSpec((block,), lambda k, parts: (k,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_merge_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((out_len,), kdt),
            jax.ShapeDtypeStruct((out_len,), vdt),
            jax.ShapeDtypeStruct((out_len,), jnp.int32),
        ],
        interpret=interpret,
    )(parts, keys_a, vals_a, keys_b, vals_b)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def merge_path_merge_age(keys_a, vals_a, age_a, keys_b, vals_b, age_b,
                         parts, block: int = 256, interpret: bool = True):
    """Merge two (key, value, age)-sorted runs; ages (original run
    indices, smaller = newer) replace the synthetic 0/1 src as the
    tie-breaking payload.  Every age in run A must be smaller than every
    age in run B (the tournament pairs adjacent newest-first groups, which
    guarantees this), so the co-rank table from ``ops.merge_partitions``
    — whose tie rule sends equal keys to run A — stays exact.  Returns
    (keys, values, ages) of length g*block; entries beyond
    len(a)+len(b) are sentinels."""
    g = parts.shape[0] - 1
    out_len = g * block
    kdt, vdt = keys_a.dtype, vals_a.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec(keys_a.shape, lambda k, parts: (0,)),
            pl.BlockSpec(vals_a.shape, lambda k, parts: (0,)),
            pl.BlockSpec(age_a.shape, lambda k, parts: (0,)),
            pl.BlockSpec(keys_b.shape, lambda k, parts: (0,)),
            pl.BlockSpec(vals_b.shape, lambda k, parts: (0,)),
            pl.BlockSpec(age_b.shape, lambda k, parts: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda k, parts: (k,)),
            pl.BlockSpec((block,), lambda k, parts: (k,)),
            pl.BlockSpec((block,), lambda k, parts: (k,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_merge_age_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((out_len,), kdt),
            jax.ShapeDtypeStruct((out_len,), vdt),
            jax.ShapeDtypeStruct((out_len,), jnp.int32),
        ],
        interpret=interpret,
    )(parts, keys_a, vals_a, age_a, keys_b, vals_b, age_b)
