"""Figures 19-20: the size-tiered (HBase) policy's measured max is
unsustainable because it merges as many components as possible under
backlog; measuring the force-min lower bound fixes it."""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    kw = dict(min_merge=2, max_merge=10)
    # broken: measure max with merge-as-many (fair), run at 95%
    broken = run_two_phase(
        testing_system=make_system("size_tiered", "fair", size_ratio=1.2,
                                   constraint="fifty", **kw),
        testing_duration=test_s, running_duration=run_s, warmup=warm)
    # fixed: measure the force-min lower bound, run at 95% of that
    fixed = run_two_phase(
        testing_system=make_system("size_tiered", "fair", size_ratio=1.2,
                                   constraint="fifty", force_min=True, **kw),
        running_system=make_system("size_tiered", "fair", size_ratio=1.2,
                                   constraint="fifty", **kw),
        testing_duration=test_s, running_duration=run_s, warmup=warm)
    out = {
        "broken": {"max_tp": broken.max_throughput,
                   "write_p99_s": broken.write_latencies[99],
                   "stall_s": broken.running.stall_time(),
                   "max_components": broken.running.max_components()},
        "fixed": {"max_tp": fixed.max_throughput,
                  "write_p99_s": fixed.write_latencies[99],
                  "stall_s": fixed.running.stall_time(),
                  "max_components": fixed.running.max_components()},
        "claims": {
            "naive_max_unsustainable":
                broken.running.stall_time() > 10.0 or
                broken.write_latencies[99] > 10.0 or
                broken.running.max_components() >
                2 * fixed.running.max_components(),
            "force_min_lower_throughput":
                fixed.max_throughput < 0.9 * broken.max_throughput,
            "force_min_sustainable": fixed.write_latencies[99] < 10.0,
        },
    }
    save("fig19_20_sizetiered", out)
    return out
