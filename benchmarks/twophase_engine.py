"""The paper's central experiment on the REAL data plane: two-phase
(testing/running) write-stall evaluation of the merge schedulers —
fair vs greedy vs single-threaded — measured on ``LSMEngine`` instead of
the fluid simulator (the ROADMAP north-star bridge).

Grid: {tiering, leveling, partitioned} x {fair, greedy, single}, each
cell a full ``run_two_phase`` through ``EngineSystem``: the testing
phase's closed client measures max throughput with real flushes/merges
sharing the bandwidth budget; the running phase's open client replays
95% of it and the engine's own write path records p50/p99 write
latencies and writer-observed stall intervals.  The grid runs on the
deterministic virtual clock (exactly reproducible quanta); a final
realtime cell re-runs one configuration behind the wall-clock
``BackgroundDriver`` to exercise the monotonic-deficit pacing.

A "starved" variant per policy runs the running phase at 1/8 of the
testing bandwidth — 95% of the measured max is then far beyond the
running system's capacity, so it MUST stall and fail the sustainability
bar; the generous variant must pass it.  Those are the claims.
"""
from __future__ import annotations

import math

from repro.core.constraints import GlobalConstraint
from repro.core.engine import LSMEngine
from repro.core.policies import (LevelingPolicy, PartitionedLevelingPolicy,
                                 TieringPolicy)
from repro.core.scheduler import make_scheduler
from repro.core.twophase import EngineSystem, run_two_phase

from .common import save

MEMTABLE = 256
UNIQUE = 1 << 14
BANDWIDTH = 4096 * 1024        # 4096 entries/s of background I/O
STARVED = BANDWIDTH // 8
MEM_RATE = 8000.0              # in-memory insert capacity, entries/s


def _policy(name: str):
    if name == "tiering":
        return TieringPolicy(3, MEMTABLE, UNIQUE)
    if name == "leveling":
        return LevelingPolicy(3, MEMTABLE, UNIQUE)
    if name == "partitioned":
        return PartitionedLevelingPolicy(4, MEMTABLE, UNIQUE,
                                         file_entries=128, l1_capacity=512)
    raise ValueError(name)


def _engine_factory(policy: str, scheduler: str):
    def factory() -> LSMEngine:
        pol = _policy(policy)
        cons = GlobalConstraint(2 * pol.expected_components())
        return LSMEngine(pol, make_scheduler(scheduler), cons,
                         memtable_entries=MEMTABLE, unique_keys=UNIQUE,
                         merge_block=64)
    return factory


def _system(policy: str, scheduler: str, bandwidth: float,
            realtime: bool = False, tick_s: float = 0.02) -> EngineSystem:
    return EngineSystem(_engine_factory(policy, scheduler),
                        bandwidth_bytes_per_s=bandwidth,
                        mem_write_rate=MEM_RATE, tick_s=tick_s,
                        realtime=realtime)


def _cell(res) -> dict:
    return {
        "max_throughput": res.max_throughput,
        "arrival_rate": res.arrival_rate,
        "p50_write_latency": res.write_latencies.get(50),
        "p99_write_latency": res.write_latencies.get(99),
        "running_stalls": len(res.running.stalls),
        "running_stall_time": res.running.stall_time(),
        "testing_stalls": len(res.testing.stalls),
        "merges": res.running.merges_completed,
        "sustainable": res.sustainable,
    }


def run(quick: bool = False) -> dict:
    t_test, t_run, warm = (6.0, 8.0, 1.0) if quick else (12.0, 20.0, 2.0)
    policies = ["tiering", "leveling", "partitioned"]
    schedulers = ["fair", "greedy", "single"]

    grid: dict[str, dict] = {}
    for pol in policies:
        for sched in schedulers:
            rsys = _system(pol, sched, BANDWIDTH)
            res = run_two_phase(
                testing_system=lambda: _system(pol, "fair", BANDWIDTH),
                running_system=lambda: rsys,
                testing_duration=t_test, running_duration=t_run,
                warmup=warm)
            cell = _cell(res)
            # write/space amplification of the running-phase engine
            # (metrics.amplification_stats over the final store state)
            cell["amplification"] = rsys.last_engine.amplification()
            grid[f"{pol}/{sched}"] = cell

    starved: dict[str, dict] = {}
    for pol in policies:
        res = run_two_phase(
            testing_system=lambda: _system(pol, "fair", BANDWIDTH),
            running_system=lambda: _system(pol, "greedy", STARVED),
            testing_duration=t_test, running_duration=3 * t_run,
            warmup=warm)
        starved[pol] = _cell(res)

    # wall-clock pacing through the BackgroundDriver (short: real seconds)
    rt = run_two_phase(
        testing_system=lambda: _system("tiering", "fair", BANDWIDTH,
                                       realtime=True, tick_s=0.005),
        running_system=lambda: _system("tiering", "greedy", BANDWIDTH,
                                       realtime=True, tick_s=0.005),
        testing_duration=1.0, running_duration=1.5, warmup=0.2)

    finite = all(math.isfinite(c["p99_write_latency"]) and
                 c["p99_write_latency"] >= 0.0 for c in grid.values())
    amps = [c["amplification"] for c in grid.values()]
    out = {
        "grid": grid,
        "starved": starved,
        "realtime": _cell(rt),
        "config": {"memtable": MEMTABLE, "unique": UNIQUE,
                   "bandwidth_bytes_per_s": BANDWIDTH,
                   "starved_bytes_per_s": STARVED,
                   "mem_write_rate": MEM_RATE,
                   "testing_s": t_test, "running_s": t_run,
                   "warmup_s": warm},
        "claims": {
            "all_cells_measured": len(grid) == len(policies) * len(schedulers),
            "p99_finite_every_cell": finite,
            "stall_counts_recorded": all("running_stalls" in c
                                         for c in grid.values()),
            "generous_greedy_sustainable": all(
                grid[f"{p}/greedy"]["sustainable"] for p in policies),
            "starved_running_stalls": all(c["running_stalls"] > 0
                                          for c in starved.values()),
            "starved_unsustainable": all(not c["sustainable"]
                                         for c in starved.values()),
            "realtime_completed": math.isfinite(
                rt.write_latencies.get(99, float("inf"))),
            "amplification_every_cell": all(
                "write_amp" in a and "space_amp" in a for a in amps),
            "space_amp_at_least_one": all(
                a["space_amp"] >= 1.0 for a in amps),
            "write_amp_exceeds_logical": max(
                a["write_amp"] for a in amps) > 1.0,
        },
    }
    save("twophase_engine", out)
    return out


if __name__ == "__main__":
    print(run(quick=True)["claims"])
