"""Figure 8: testing-phase scheduler choice.  Fair gives a steady
measured max; single-threaded pauses; greedy over-reports by starving
large merges (unsustainable)."""
from __future__ import annotations

import numpy as np

from repro.core.sim import ClosedClient

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, _, warm = durations(quick)
    out: dict = {"claims": {}}
    for policy in ("tiering", "leveling"):
        row = {}
        for sched in ("single", "fair", "greedy"):
            T = 3 if policy == "tiering" else 10
            sim = make_system(policy, sched, size_ratio=T)()
            tr = sim.run(ClosedClient(n_threads=1), test_s)
            t, w = tr.windowed_throughput(30.0)
            late = w[t > warm]
            row[sched] = {
                "throughput": tr.throughput(t_from=warm),
                "cv": float(np.std(late) / max(np.mean(late), 1e-9)),
                "stall_time": tr.stall_time(),
            }
        out[policy] = row
        out["claims"][f"{policy}_single_has_pauses"] = \
            row["single"]["stall_time"] > row["fair"]["stall_time"] or \
            row["single"]["cv"] > 2 * row["fair"]["cv"]
        out["claims"][f"{policy}_greedy_overreports_vs_fair"] = \
            row["greedy"]["throughput"] > 1.02 * row["fair"]["throughput"]
    save("fig08_testing", out)
    return out
