"""Engine read/write-plane throughput: scalar vs vectorized batch paths.

Measures puts/sec for the seed's per-entry admission loop vs the bulk
``put_batch`` slice path, gets/sec for per-key ``get`` vs the fused
``get_batch`` (one stacked Bloom launch across all tables) at several
table counts, and range-scan throughput for the seed's per-table Python
dict replay vs the vectorized k-way ``scan_range`` plane over
overlapping tables.  The batch plane must amortize per-call Python +
kernel dispatch: the acceptance bar is >= 5x on reads at >= 8 tables,
>= 3x on writes, and >= 10x on full-range scans at >= 8 overlapping
64k-entry tables (>= 3x in --quick mode, which scans smaller tables
where the dict baseline's per-entry cost is less cache-hostile).

    PYTHONPATH=src python -m benchmarks.engine_throughput [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.engine import LSMEngine
from repro.core.policies import TieringPolicy
from repro.core.scheduler import SingleThreadedScheduler

from .common import save

KEY_SPACE = 1 << 20
MEMTABLE = 1024


class _FlushOnlyPolicy(TieringPolicy):
    """Never merges — keeps an exact, stable table count for read benches."""

    def collect_merges(self, tree, now):
        return []


def _seed_scalar_put_batch(eng: LSMEngine, keys, values) -> int:
    """The seed's per-entry admission loop (the pre-batch-plane hot path),
    kept verbatim as the scalar baseline."""
    keys = np.asarray(keys)
    n_ok = 0
    for i in range(len(keys)):
        if not eng.put(int(keys[i]), int(np.asarray(values)[i])):
            break
        n_ok += 1
    return n_ok


def _mk_engine(tables: int = 0, seed: int = 0) -> LSMEngine:
    eng = LSMEngine(_FlushOnlyPolicy(1 << 20, MEMTABLE, KEY_SPACE),
                    SingleThreadedScheduler(), None,
                    memtable_entries=MEMTABLE, num_memtables=2,
                    unique_keys=KEY_SPACE, merge_block=128)
    rng = np.random.default_rng(seed)
    for _ in range(tables):
        keys = rng.integers(0, KEY_SPACE, MEMTABLE, dtype=np.uint32)
        vals = rng.integers(0, 1 << 30, MEMTABLE).astype(np.int32)
        assert eng.put_batch(keys, vals) == MEMTABLE
        eng._seal_active()
        eng.pump(MEMTABLE)          # flush -> exactly one more table
    assert len(eng.tables) == tables
    return eng


def _bench_reads(tables: int, n_keys: int, n_scalar: int, reps: int) -> dict:
    eng = _mk_engine(tables=tables, seed=tables)
    rng = np.random.default_rng(99)
    qs = rng.integers(0, KEY_SPACE, n_keys, dtype=np.uint32)
    eng.get_batch(qs[:8])           # warm both jit paths
    eng.get(int(qs[0]))

    t0 = time.perf_counter()
    for _ in range(reps):
        eng.get_batch(qs)
    batch_s = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for k in qs[:n_scalar]:
        eng.get(int(k))
    scalar_s = time.perf_counter() - t0

    batch_rate = n_keys / batch_s
    scalar_rate = n_scalar / scalar_s
    return {"tables": tables, "batch_gets_per_s": batch_rate,
            "scalar_gets_per_s": scalar_rate,
            "speedup": batch_rate / scalar_rate}


def _seed_scan_range(eng: LSMEngine, lo: int, hi: int) -> dict:
    """The seed's ``scan_range``: per-table Python dict replay
    (oldest-first ``update``), kept verbatim as the scalar baseline."""
    out: dict[int, int] = {}
    for table in reversed(eng._read_view().tables):
        ks, vs = table.scan_range(lo, hi)
        out.update(zip(ks.tolist(), vs.tolist()))
    for mt in eng.sealed:
        sk, sv = mt.seal()
        m = (sk >= lo) & (sk < hi)
        out.update(zip(sk[m].tolist(), sv[m].tolist()))
    sk, sv = eng.active.seal()
    m = (sk >= lo) & (sk < hi)
    out.update(zip(sk[m].tolist(), sv[m].tolist()))
    return out


def _mk_scan_engine(tables: int, entries: int, seed: int = 0) -> LSMEngine:
    """``tables`` overlapping sorted runs of ``entries`` keys each, drawn
    from the shared key space so every table overlaps every other."""
    eng = LSMEngine(_FlushOnlyPolicy(1 << 20, entries, KEY_SPACE),
                    SingleThreadedScheduler(), None,
                    memtable_entries=entries, num_memtables=2,
                    unique_keys=KEY_SPACE, merge_block=128)
    rng = np.random.default_rng(seed)
    for _ in range(tables):
        keys = rng.choice(KEY_SPACE, entries, replace=False).astype(
            np.uint32)
        vals = rng.integers(0, 1 << 30, entries).astype(np.int32)
        assert eng.put_batch(keys, vals) == entries
        eng._seal_active()
        eng.pump(entries)
    assert len(eng.tables) == tables
    return eng


def _bench_scans(tables: int, entries: int, reps: int) -> dict:
    eng = _mk_scan_engine(tables=tables, entries=entries, seed=tables)
    lo, hi = 0, KEY_SPACE

    got_k, got_v = eng.scan_range(lo, hi)          # warm + correctness
    want = _seed_scan_range(eng, lo, hi)
    assert dict(zip(got_k.tolist(), got_v.tolist())) == want, \
        "scan plane diverged from the seed dict replay"

    best_vec = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.scan_range(lo, hi)
        best_vec = min(best_vec, time.perf_counter() - t0)
    best_seed = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _seed_scan_range(eng, lo, hi)
        best_seed = min(best_seed, time.perf_counter() - t0)

    n = len(got_k)
    return {"tables": tables, "entries_per_table": entries,
            "result_entries": n,
            "kway_scans_per_s": n / best_vec,
            "seed_scans_per_s": n / best_seed,
            "speedup": best_seed / best_vec}


def _bench_writes(n_entries: int, reps: int) -> dict:
    rng = np.random.default_rng(7)
    keys = rng.integers(0, KEY_SPACE, n_entries, dtype=np.uint32)
    vals = rng.integers(0, 1 << 30, n_entries).astype(np.int32)

    def one(bulk: bool) -> tuple[float, int]:
        best, accepted = float("inf"), 0
        for _ in range(reps):
            eng = _mk_engine()
            t0 = time.perf_counter()
            if bulk:
                accepted = eng.put_batch(keys, vals)
            else:
                accepted = _seed_scalar_put_batch(eng, keys, vals)
            best = min(best, time.perf_counter() - t0)
        return best, accepted

    bulk_s, n_bulk = one(bulk=True)
    scalar_s, n_scalar = one(bulk=False)
    assert n_bulk == n_scalar, "accept-count divergence"
    return {"entries": n_entries, "accepted": n_bulk,
            "bulk_puts_per_s": n_bulk / bulk_s,
            "scalar_puts_per_s": n_scalar / scalar_s,
            "speedup": scalar_s / bulk_s}


def run(quick: bool = False) -> dict:
    table_counts = [2, 8] if quick else [2, 4, 8, 16]
    n_keys = 256 if quick else 1024
    n_scalar = 32 if quick else 128
    reps = 2 if quick else 5
    scan_entries = 16384 if quick else 65536
    scan_bar = 3.0 if quick else 10.0
    scan_tables = [8] if quick else [8, 16]

    reads = [_bench_reads(t, n_keys, n_scalar, reps) for t in table_counts]
    # both memtables fill exactly: scalar and bulk admit the same count
    writes = _bench_writes(MEMTABLE * 2, reps)
    scans = [_bench_scans(t, scan_entries, max(reps, 3))
             for t in scan_tables]

    out = {"reads": reads, "writes": writes, "scans": scans, "claims": {}}
    at8 = [r for r in reads if r["tables"] >= 8]
    out["claims"]["batch_get_5x_at_8_tables"] = all(
        r["speedup"] >= 5.0 for r in at8) and bool(at8)
    out["claims"]["bulk_put_3x"] = writes["speedup"] >= 3.0
    out["claims"]["accept_counts_equal"] = writes["accepted"] == MEMTABLE * 2
    # fixed claim key across modes (the bar is recorded alongside, not
    # baked into the schema), gating every measured table count
    out["scan_bar"] = scan_bar
    out["claims"]["kway_scan_bar_met"] = all(
        s["speedup"] >= scan_bar for s in scans)
    save("BENCH_engine", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    res = run(quick=ap.parse_args().quick)
    for r in res["reads"]:
        print(f"[engine] gets  @ {r['tables']:3d} tables: "
              f"batch {r['batch_gets_per_s']:9.0f}/s  "
              f"scalar {r['scalar_gets_per_s']:9.0f}/s  "
              f"speedup {r['speedup']:.1f}x")
    w = res["writes"]
    print(f"[engine] puts  @ {w['entries']} entries: "
          f"bulk {w['bulk_puts_per_s']:9.0f}/s  "
          f"scalar {w['scalar_puts_per_s']:9.0f}/s  "
          f"speedup {w['speedup']:.1f}x")
    for s in res["scans"]:
        print(f"[engine] scans @ {s['tables']:3d} tables x "
              f"{s['entries_per_table']} entries: "
              f"kway {s['kway_scans_per_s']:9.0f}/s  "
              f"seed {s['seed_scans_per_s']:9.0f}/s  "
              f"speedup {s['speedup']:.1f}x")
    print(json.dumps(res["claims"], indent=1))
    raise SystemExit(0 if all(res["claims"].values()) else 1)
