"""Durability-plane benchmark: recovery time vs WAL size, budget
starvation during replay, the group-commit trade-off, and tombstone
space reclamation.

Recovery "time" is virtual: ``RecoverySession.run`` epochs at a fixed
per-epoch I/O budget, the same unit the background scheduler meters.
The key cells pin the PR-7 claims:

- replaying a longer WAL takes proportionally more epochs (recovery
  time scales with un-checkpointed log, so snapshot+truncate matters);
- WAL replay is charged against the scheduler budget: starving the
  budget slows recovery, it does not silently overrun;
- larger group-commit windows buy fewer fsync epochs (throughput) at
  the price of a wider loss window after a torn-tail crash (latency of
  durability), the classic trade-off;
- deleting everything and fully compacting returns physical space to
  ~0 — tombstones are dropped at the bottom level, not retained.
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint import EngineSnapshotStore
from repro.core import (LSMEngine, RecoverySession, WriteAheadLog,
                        apply_torn_tail)
from repro.core.constraints import GlobalConstraint
from repro.core.policies import LevelingPolicy
from repro.core.scheduler import GreedyScheduler

from .common import save


def _engine(tmp: Path, unique: int, memtable: int, tag: str,
            wal: bool = True, **kw) -> LSMEngine:
    w = WriteAheadLog(tmp / f"wal-{tag}") if wal else None
    return LSMEngine(LevelingPolicy(3, memtable, unique), GreedyScheduler(),
                     GlobalConstraint(200), memtable_entries=memtable,
                     unique_keys=unique, use_kernels=False,
                     scan_use_kernels=False, wal=w, **kw)


def _feed(eng: LSMEngine, keys, vals, pump: int = 1 << 12) -> None:
    done = 0
    while done < len(keys):
        done += eng.put_batch(keys[done:], vals[done:])
        if done < len(keys):
            eng.pump(pump)


def _load(eng: LSMEngine, n: int, unique: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    for off in range(0, n, 512):
        m = min(512, n - off)
        _feed(eng, rng.integers(0, unique, m, dtype=np.uint32),
              rng.integers(0, 1 << 30, m, dtype=np.int32))
        eng.pump(256)


def _recovery_epochs(tmp: Path, tag: str, unique: int, memtable: int,
                     budget: int) -> int:
    eng = _engine(tmp, unique, memtable, tag)
    n = RecoverySession(eng).run(budget)
    eng.close()
    return n


def run(quick: bool = False) -> dict:
    unique = 2048 if quick else 8192
    memtable = 128 if quick else 256
    sizes = [1024, 2048, 4096] if quick else [4096, 8192, 16384, 32768]
    budgets = [1 << 12, 1 << 10, 1 << 8]
    groups = [16, 64, 256, 1024]
    result: dict = {"quick": quick, "unique_keys": unique,
                    "memtable_entries": memtable}

    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)

        # -- recovery time vs WAL size (no snapshot: replay everything) -----
        by_size = {}
        for n in sizes:
            eng = _engine(tmp, unique, memtable, f"size{n}")
            _load(eng, n, unique)
            eng.close()                       # clean fsync: WAL holds all n
            by_size[n] = {
                "wal_entries": n,
                "recovery_epochs": _recovery_epochs(
                    tmp, f"size{n}", unique, memtable, budget=1 << 10),
            }
        result["recovery_vs_wal_size"] = by_size
        epochs = [by_size[n]["recovery_epochs"] for n in sizes]

        # -- budget starvation: same WAL, shrinking per-epoch budget --------
        big = sizes[-1]
        by_budget = {b: _recovery_epochs(tmp, f"size{big}", unique,
                                         memtable, budget=b)
                     for b in budgets}
        result["recovery_vs_budget"] = {
            "wal_entries": big,
            "epochs_by_budget": {str(b): e for b, e in by_budget.items()},
        }

        # -- snapshot + truncate shortens replay ----------------------------
        eng = _engine(tmp, unique, memtable, "snap")
        _load(eng, big, unique)
        store = EngineSnapshotStore(tmp / "snapdir")
        eng.snapshot(store)
        _load(eng, sizes[0], unique, seed=1)  # small post-snapshot delta
        eng.close()
        e2 = _engine(tmp, unique, memtable, "snap")
        snap_epochs = RecoverySession(e2, store).run(1 << 10)
        result["recovery_with_snapshot"] = {
            "pre_snapshot_entries": big, "post_snapshot_entries": sizes[0],
            "recovery_epochs": snap_epochs,
        }
        e2.close()

        # -- group-commit trade-off -----------------------------------------
        by_group = {}
        for g in groups:
            eng = _engine(tmp, unique, memtable, f"g{g}",
                          group_commit_entries=g)
            rng = np.random.default_rng(2)
            loss_windows = []
            for _ in range(big // 512):
                _feed(eng, rng.integers(0, unique, 512, dtype=np.uint32),
                      rng.integers(0, 1 << 30, 512, dtype=np.int32),
                      pump=1 << 30)
                loss_windows.append(eng.wal.unsynced_entries)
            s = eng.stats
            by_group[g] = {
                "wal_syncs": s["wal_syncs"],
                "sync_budget_entries": s["wal_syncs"] * eng.wal_sync_cost
                + s["wal_entries"],
                "mean_loss_window_entries":
                    float(np.mean(loss_windows)) if loss_windows else 0.0,
                "max_loss_window_entries":
                    int(max(loss_windows)) if loss_windows else 0,
            }
            # actually lose the window: torn tail eats the unsynced suffix
            apply_torn_tail(eng.wal, 0.0)
            by_group[g]["lost_after_crash"] = \
                s["wal_entries"] - WriteAheadLog(tmp / f"wal-g{g}").end_lsn
        result["group_commit"] = {str(g): c for g, c in by_group.items()}

        # -- tombstone space reclamation ------------------------------------
        eng = _engine(tmp, unique, memtable, "reclaim", wal=False)
        keys = np.arange(min(unique, 4096), dtype=np.uint32)
        _feed(eng, keys, np.ones(len(keys), np.int32))
        before = eng.amplification()
        done = 0
        while done < len(keys):
            done += eng.delete_batch(keys[done:])
            eng.pump(1 << 12)
        eng.drain()
        eng.compact_all()
        after = eng.amplification()
        result["reclamation"] = {
            "entries": len(keys),
            "physical_before_delete": before["physical_entries"],
            "physical_after_compact": after["physical_entries"],
            "live_after_compact": after["live_entries"],
            "tombstones_dropped": eng.stats["tombstones_dropped"],
            "write_amp": after["write_amp"],
        }

    syncs = [by_group[g]["wal_syncs"] for g in groups]
    losses = [by_group[g]["max_loss_window_entries"] for g in groups]
    result["claims"] = {
        "recovery_epochs_monotone_in_wal_size":
            all(a <= b for a, b in zip(epochs, epochs[1:]))
            and epochs[-1] > epochs[0],
        "starved_budget_slows_recovery":
            by_budget[budgets[0]] < by_budget[budgets[1]]
            < by_budget[budgets[2]],
        "snapshot_shortens_replay":
            snap_epochs < by_size[big]["recovery_epochs"],
        "group_commit_reduces_syncs":
            all(a >= b for a, b in zip(syncs, syncs[1:]))
            and syncs[0] > syncs[-1],
        "group_commit_widens_loss_window":
            losses[-1] > losses[0],
        "delete_all_compact_reclaims_space":
            after["physical_entries"] == 0 and after["live_entries"] == 0,
    }
    save("recovery", result)
    return result


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True)["claims"], indent=1))
