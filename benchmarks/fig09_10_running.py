"""Figures 9-10: running phase at 95% of the fair-measured max.

Tiering: fair and greedy both sustain; single-threaded stalls.
Leveling: only greedy delivers small write latencies; fair suffers from
merge-time variance; single-threaded is hopeless.
"""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    out: dict = {"claims": {}}
    for policy, T in (("tiering", 3), ("leveling", 10)):
        row = {}
        for sched in ("single", "fair", "greedy"):
            res = run_two_phase(
                testing_system=make_system(policy, "fair", size_ratio=T),
                running_system=make_system(policy, sched, size_ratio=T),
                testing_duration=test_s, running_duration=run_s,
                warmup=warm)
            row[sched] = {
                "arrival_rate": res.arrival_rate,
                "write_p99_s": res.write_latencies[99],
                "stall_time_s": res.running.stall_time(),
                "max_components": res.running.max_components(),
            }
        out[policy] = row
        c = out["claims"]
        c[f"{policy}_single_stalls"] = \
            row["single"]["stall_time_s"] > 10 * max(
                row["greedy"]["stall_time_s"], 1e-3) or \
            row["single"]["write_p99_s"] > 10 * row["greedy"]["write_p99_s"]
        c[f"{policy}_greedy_low_latency"] = row["greedy"]["write_p99_s"] < 10
        if policy == "tiering":
            c["tiering_fair_also_fine"] = row["fair"]["write_p99_s"] < 10
        else:
            c["leveling_fair_worse_than_greedy"] = \
                row["fair"]["write_p99_s"] > 2 * row["greedy"]["write_p99_s"]
    save("fig09_10_running", out)
    return out
