"""Two-phase evaluation of the LSM checkpoint store: what delta cadence
is sustainable under a fixed background-I/O budget?

Testing phase: write deltas as fast as the store accepts them under the
component constraint (closed system) to measure max delta throughput.
Running phase: emit at 95% of that cadence; stall events and component
growth decide sustainability — the paper's methodology verbatim, applied
to checkpoint pressure instead of key-value writes.
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

import numpy as np

from repro.checkpoint import LSMCheckpointStore
from repro.core.constraints import GlobalConstraint
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler, GreedyScheduler

from .common import save


def _mk_store(root, sched):
    return LSMCheckpointStore(
        root, policy=TieringPolicy(3, 1, 1e9),
        scheduler=sched, constraint=GlobalConstraint(10),
        io_budget_bytes_per_s=50e6)


def _delta(step, kb=64):
    rng = np.random.default_rng(step)
    return {"layer/w": rng.standard_normal(kb * 128).astype(np.float32)}


def run(quick: bool = False) -> dict:
    ticks = 120 if quick else 400
    out: dict = {"claims": {}}
    for sname, sched in (("fair", FairScheduler()),
                         ("greedy", GreedyScheduler())):
        root = Path(tempfile.mkdtemp(prefix=f"ckpt_bench_{sname}_"))
        store = _mk_store(root, sched)
        # testing phase: closed system — put as fast as accepted, budget
        # pumped once per tick
        accepted = stalls = 0
        for t in range(ticks):
            if store.put_delta(t, _delta(t)):
                accepted += 1
            else:
                stalls += 1
            store.pump(2.0e5)     # bytes per tick of background budget
        max_rate = accepted / ticks
        # running phase: 95% cadence
        store2 = _mk_store(Path(tempfile.mkdtemp()), sched)
        acc = 0.0
        r_accept = r_stall = 0
        comps = []
        for t in range(ticks):
            acc += 0.95 * max_rate
            while acc >= 1.0:
                if store2.put_delta(t, _delta(t)):
                    r_accept += 1
                else:
                    r_stall += 1
                acc -= 1.0
            store2.pump(2.0e5)
            comps.append(store2.num_components())
        out[sname] = {
            "testing_max_rate": max_rate,
            "testing_stalls": stalls,
            "running_stalls": r_stall,
            "running_accepted": r_accept,
            "mean_components": float(np.mean(comps)),
            "max_components": int(np.max(comps)),
        }
        shutil.rmtree(root, ignore_errors=True)
    out["claims"]["running_phase_sustainable"] = \
        out["greedy"]["running_stalls"] <= out["greedy"]["testing_stalls"]
    out["claims"]["greedy_bounds_components"] = \
        out["greedy"]["max_components"] <= 10
    save("ckpt_twophase", out)
    return out
