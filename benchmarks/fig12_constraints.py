"""Figure 12: global vs local component constraints.

Local (per-level) constraints barely matter for tiering but inflate
leveling's write latencies — and hurt greedy more than fair (small
merges blocked by next-level limits)."""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    out: dict = {"claims": {}}
    for policy, T in (("tiering", 3), ("leveling", 10)):
        row = {}
        for sched in ("fair", "greedy"):
            for cons in ("global", "local"):
                res = run_two_phase(
                    testing_system=make_system(policy, "fair", size_ratio=T),
                    running_system=make_system(policy, sched,
                                               constraint=cons,
                                               size_ratio=T),
                    testing_duration=test_s, running_duration=run_s,
                    warmup=warm)
                row[f"{sched}_{cons}"] = {
                    "write_p99_s": res.write_latencies[99],
                    "stall_time_s": res.running.stall_time(),
                }
        out[policy] = row
    lv = out["leveling"]
    out["claims"]["leveling_local_worse_than_global"] = (
        lv["greedy_local"]["write_p99_s"] >
        2 * lv["greedy_global"]["write_p99_s"] or
        lv["fair_local"]["write_p99_s"] >
        2 * lv["fair_global"]["write_p99_s"])
    out["claims"]["local_hurts_greedy_more"] = (
        lv["greedy_local"]["write_p99_s"] / max(
            lv["greedy_global"]["write_p99_s"], 1e-3) >
        lv["fair_local"]["write_p99_s"] / max(
            lv["fair_global"]["write_p99_s"], 1e-3))
    tv = out["tiering"]
    out["claims"]["tiering_local_little_impact"] = (
        tv["greedy_local"]["write_p99_s"] <
        max(4 * tv["greedy_global"]["write_p99_s"], 10.0))
    save("fig12_constraints", out)
    return out
