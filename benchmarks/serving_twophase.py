"""Two-phase admission calibration for the batched decode server
(paged-KV pool with greedy-scheduled compaction), on a real reduced
model — the serving-side instantiation of the paper's methodology."""
from __future__ import annotations

import jax

from repro.configs import get_smoke
from repro.models import init_params
from repro.serving import BatchServer, ServerConfig, two_phase_admission

from .common import save


def run(quick: bool = False) -> dict:
    cfg = get_smoke("smollm-135m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServerConfig(batch_size=4, max_len=64, n_pages=64,
                        page_tokens=8, max_new_tokens=8)
    rep = two_phase_admission(
        lambda: BatchServer(cfg, params, scfg),
        testing_steps=60 if quick else 200,
        running_steps=120 if quick else 400)
    rep["claims"] = {
        "running_phase_completes_requests": rep["completed"] > 0,
        "bounded_latency_at_95": rep["latency_pcts_steps"][99] < 100,
        "no_admission_collapse": rep["admission_stalls"] < rep["completed"],
    }
    save("serving_twophase", rep)
    return rep
