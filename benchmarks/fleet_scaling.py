"""Fleet scaling: the sharded serving plane vs the single engine
(ISSUE 6).

Section A (closed-loop admission): a batched writer drives
``LSMFleet.put_batch`` as fast as admission allows at shard counts
{1, 2, 4, 8} under one GLOBAL wall-clock background budget
(``FleetBackgroundDriver``), in two regimes:

* A1, burst window — a fixed window at a modest paced budget.  Each
  shard owns its own memtable group, so the fleet absorbs N× more
  in-flight writes before its first stall while background I/O drains
  at the same global budget either way; admitted throughput over the
  window scales with shard count.  Bar: >= 2x admitted at 4 shards vs
  1 shard.  (On a multi-core host the worker pool adds background
  flush/merge parallelism on top; this container is single-CPU, so the
  cell isolates the buffering term — the artifact records
  ``cpu_count`` alongside.)
* A2, sustained — a long window at a budget far below admission speed.
  The paper's invariant, fleet-wide: steady-state throughput equals
  the global I/O budget over the write amplification, so shard count
  must NOT buy sustained throughput — the arbiter conserves one global
  budget.  Bar: 4-shard/1-shard sustained ratio within [0.75, 1.35].

Section B (open-loop tail): the ``latency_tail.py`` methodology —
coordinated-omission-free scheduled arrivals, writer ``put_batch`` +
reader ``scan_range`` against a live background plane over a preloaded
cascading merge workload.  Total resources are held CONSTANT across
shard counts: each shard gets its key-routed preload slice and 1/N of
the memtable capacity, so the comparison isolates the router, not extra
buffer.  Bar: writer p99 at 4 shards within 3x of the single-engine
baseline (a plain ``LSMEngine`` driven exactly like
``latency_tail.py``), measured as the MEDIAN of 5 paired back-to-back
ratios (the box freezes intermittently for tens of ms; pairing cancels
slow phases, the median drops a poisoned rep).  The measured clean
median ratio is ~2.5x and is a single-core artifact: the harness (like
``latency_tail.py``) interleaves ops on one client thread, every 8th op
is a scan that fans to all N shards with N-fold per-run snapshot
overhead, and there is no second core for the pool to hide it on — the
artifact records ``cpu_count``.  Two fleet scan-plane optimizations are
load-bearing here and regression-pinned by this bar: adaptive inline
dispatch (no pool handoff for narrow ops) and the flat one-pass gather
merge (``engine.scan_runs``), which together took the 4-shard writer
p99 from ~4x the baseline to ~2.5x.

Section C (starved global budget): 4 shards preloaded with SKEWED merge
debt, pumped in deterministic epochs at a tiny global budget.  The
paper's scheduler comparison, fleet-wide: the fair arbiter apportions
every epoch across all indebted shards (largest remainder by debt), the
greedy arbiter drains the fewest-remaining-bytes shard first — so
greedy finishes its first shard strictly earlier while fair spreads
grants across strictly more shards per epoch.

Section D: a miniature fleet-vs-single-engine differential (the full
version lives in ``tests/test_fleet.py``) — bit-identical get/scan
results on a shared random trace.

    PYTHONPATH=src python -m benchmarks.fleet_scaling [--quick]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time

import numpy as np

from repro.core.engine import BackgroundDriver, LSMEngine
from repro.core.fleet import FleetBackgroundDriver, LSMFleet
from repro.core.metrics import LatencyRecorder
from repro.core.policies import TieringPolicy
from repro.core.scheduler import FairScheduler
from repro.core.sstable import SSTable

from .common import save

KEY_SPACE = 1 << 22
MEMTABLE = 32_768


def _mk_engine(_shard: int = 0) -> LSMEngine:
    return LSMEngine(TieringPolicy(4, MEMTABLE, KEY_SPACE), FairScheduler(),
                     None, memtable_entries=MEMTABLE, num_memtables=4,
                     unique_keys=KEY_SPACE, use_kernels=False)


def _mk_engine_scaled(n_shards: int):
    """Shard factory holding TOTAL resources constant: each of N shards
    gets 1/N of the single engine's memtable capacity, so the tail cells
    compare equal-footprint configurations (a scan's memtable-window
    extraction touches the same total buffer at every shard count)."""
    per = max(2048, MEMTABLE // n_shards)

    def factory(_shard: int = 0) -> LSMEngine:
        return LSMEngine(TieringPolicy(4, per, KEY_SPACE), FairScheduler(),
                         None, memtable_entries=per, num_memtables=4,
                         unique_keys=KEY_SPACE, use_kernels=False)
    return factory


def _inject_table(eng: LSMEngine, keys: np.ndarray, level: int) -> None:
    vals = keys.astype(np.int32)
    table = SSTable.build(np.sort(keys), vals, level=level,
                          created_at=eng.now, interpret=eng.interpret)
    eng._bind_table(table)


# ---------------------------------------------------------------- section A
def _closed_loop(n_shards: int, duration: float, batch: int,
                 bw_bytes: float) -> dict:
    fleet = LSMFleet(n_shards, _mk_engine, arbiter="fair")
    drv = FleetBackgroundDriver(fleet, bw_bytes, quantum_s=0.005)
    rng = np.random.default_rng(n_shards)
    # pre-generate the write stream: the foreground loop should measure
    # admission + routing, not RNG cost
    pool_n = 1 << 21
    kpool = rng.integers(0, KEY_SPACE, pool_n, dtype=np.uint32)
    vpool = rng.integers(0, 1 << 30, pool_n, dtype=np.int32)
    admitted = 0
    off = 0
    drv.start()
    t0 = time.monotonic()
    try:
        while time.monotonic() - t0 < duration:
            if off + batch > pool_n:
                off = 0
            n = fleet.put_batch(kpool[off:off + batch],
                                vpool[off:off + batch])
            admitted += n
            off += batch
            if n < batch:
                time.sleep(1e-3)        # stalled: let background drain
    finally:
        elapsed = time.monotonic() - t0
        drv.stop()
        stats = fleet.stats
        fleet.close()
    return {"shards": n_shards, "admitted": admitted, "elapsed_s": elapsed,
            "puts_per_s": admitted / elapsed, "flushes": stats["flushes"],
            "merges": stats["merges"], "stalls": stats["stall_events"]}


# ---------------------------------------------------------------- section B
def _preload_cascade(store, n_shards: int, level_sizes: list[int],
                     rng) -> None:
    """3 tables per level per shard, key-routed so each shard holds only
    its own partition; TOTAL entries per level are constant across shard
    counts (each shard gets ~1/N of every table)."""
    engines = store.engines if isinstance(store, LSMFleet) else [store]
    for level, n in enumerate(level_sizes):
        for _ in range(3):
            keys = np.unique(rng.integers(0, KEY_SPACE, int(n * 1.3),
                                          dtype=np.uint32))[:n]
            if isinstance(store, LSMFleet):
                sid = store.shard_ids(keys)
                for s, eng in enumerate(engines):
                    _inject_table(eng, keys[sid == s], level)
            else:
                _inject_table(engines[0], keys, level)


def _open_loop(store, driver, duration: float, rate_ops: float,
               batch: int, read_every: int) -> dict:
    """The latency_tail discipline: ops fire at fixed SCHEDULED times;
    latency is completion - scheduled (no coordinated omission); a
    stalled write retries until its whole batch lands."""
    wrec, rrec = LatencyRecorder(), LatencyRecorder()
    rng = np.random.default_rng(7)
    interval = 1.0 / rate_ops
    driver.start()
    try:
        t0 = time.monotonic()
        i = 0
        while True:
            sched = t0 + i * interval
            lag = sched - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            if time.monotonic() - t0 >= duration:
                break
            if read_every and i % read_every == read_every - 1:
                lo = int(rng.integers(0, KEY_SPACE - 4096))
                store.scan_range(lo, lo + 4096)
                rrec.observe(time.monotonic() - sched)
            else:
                keys = rng.integers(0, KEY_SPACE, batch, dtype=np.uint32)
                vals = rng.integers(0, 1 << 30, batch, dtype=np.int32)
                done = 0
                while done < batch:
                    took = store.put_batch(keys[done:], vals[done:])
                    done += took
                    if took == 0:
                        time.sleep(2e-4)
                wrec.observe(time.monotonic() - sched)
            i += 1
    finally:
        driver.stop()
    stats = store.stats
    return {"writer": wrec.summary(), "reader": rrec.summary(),
            "merges": stats["merges"], "flushes": stats["flushes"]}


def _tail_cell(n_shards: int | None, duration: float,
               level_sizes: list[int], bw_bytes: float, rate_ops: float,
               batch: int, read_every: int) -> dict:
    """``n_shards=None`` is the single-engine baseline (plain LSMEngine +
    BackgroundDriver, exactly the latency_tail.py harness shape)."""
    rng = np.random.default_rng(42)
    if n_shards is None:
        eng = _mk_engine()
        _preload_cascade(eng, 1, level_sizes, rng)
        out = _open_loop(eng, BackgroundDriver(eng, bw_bytes,
                                               quantum_s=0.005),
                         duration, rate_ops, batch, read_every)
        out["shards"] = 0           # 0 == no router, the raw engine
        return out
    fleet = LSMFleet(n_shards, _mk_engine_scaled(n_shards), arbiter="fair")
    try:
        _preload_cascade(fleet, n_shards, level_sizes, rng)
        out = _open_loop(fleet, FleetBackgroundDriver(fleet, bw_bytes,
                                                      quantum_s=0.005),
                         duration, rate_ops, batch, read_every)
    finally:
        fleet.close()
    out["shards"] = n_shards
    return out


# ---------------------------------------------------------------- section C
def _starved_cell(policy: str, shard_table_sizes: list[int],
                  epoch_budget: int, max_epochs: int = 4000) -> dict:
    """Deterministic epochs under a starved global budget: shard i is
    preloaded with 4 same-size L0 tables of ``shard_table_sizes[i]``
    entries (an immediate 4-way merge per shard), then the arbiter splits
    ``epoch_budget`` each epoch until every shard drains."""
    n = len(shard_table_sizes)
    fleet = LSMFleet(n, _mk_engine, arbiter=policy, parallel=False)
    rng = np.random.default_rng(9)
    for s, size in enumerate(shard_table_sizes):
        for _ in range(4):
            keys = np.unique(rng.integers(0, KEY_SPACE, int(size * 1.3),
                                          dtype=np.uint32))[:size]
            _inject_table(fleet.engines[s], keys, 0)
    drain_epoch: dict[int, int] = {}
    nonzero_counts: list[int] = []
    spent_total = 0
    for epoch in range(1, max_epochs + 1):
        debts = fleet.pending_debts()
        for s, d in enumerate(debts):
            if d == 0 and s not in drain_epoch:
                drain_epoch[s] = epoch - 1
        if len(drain_epoch) == n:
            break
        grants = fleet.arbiter.allocate(debts, epoch_budget)
        assert sum(grants) <= epoch_budget
        nonzero_counts.append(sum(1 for g in grants if g > 0))
        for s, g in enumerate(grants):
            if g > 0:
                spent_total += fleet.engines[s].pump(g)
    fleet.close()
    return {"policy": policy, "epoch_budget": epoch_budget,
            "shard_table_sizes": shard_table_sizes,
            "drain_epoch_per_shard": [drain_epoch.get(s)
                                      for s in range(n)],
            "first_drain_epoch": min(drain_epoch.values()),
            "last_drain_epoch": max(drain_epoch.values()),
            "mean_shards_granted_per_epoch":
                float(np.mean(nonzero_counts)) if nonzero_counts else 0.0,
            "spent_total": spent_total}


# ---------------------------------------------------------------- section D
def _mini_differential(n_shards: int = 4) -> bool:
    rng = np.random.default_rng(123)
    eng = _mk_engine()
    fleet = LSMFleet(n_shards, _mk_engine, arbiter="fair")
    try:
        for _ in range(4):
            keys = rng.integers(0, KEY_SPACE, 8192, dtype=np.uint32)
            vals = rng.integers(0, 1 << 30, 8192, dtype=np.int32)
            assert eng.put_batch(keys, vals) == 8192
            assert fleet.put_batch(keys, vals) == 8192
            eng.pump(8192)
            fleet.pump(8192)
        eng.drain()
        fleet.drain()
        qs = rng.integers(0, KEY_SPACE, 4096, dtype=np.uint32)
        f1, v1 = eng.get_batch(qs)
        f2, v2 = fleet.get_batch(qs)
        lo = int(rng.integers(0, KEY_SPACE // 2))
        k1, x1 = eng.scan_range(lo, lo + (1 << 18))
        k2, x2 = fleet.scan_range(lo, lo + (1 << 18))
        return bool((f1 == f2).all() and (v1[f1] == v2[f2]).all()
                    and np.array_equal(k1, k2) and np.array_equal(x1, x2))
    finally:
        fleet.close()


def run(quick: bool = False) -> dict:
    if quick:
        shard_counts = [1, 2, 4]
        burst_dur, burst_bw = 2.0, 4.0e7
        sustained_dur, sustained_bw = 2.0, 1.5e9
        tput_bar = 2.0
        tail_dur, tail_sizes, tail_bw = 2.5, [24_576, 98_304], 2.5e8
        tail_bar = 3.5
        starved_sizes, starved_budget = [512, 2048, 8192, 16_384], 512
    else:
        shard_counts = [1, 2, 4, 8]
        burst_dur, burst_bw = 4.0, 4.0e7
        sustained_dur, sustained_bw = 6.0, 1.5e9
        tput_bar = 2.0
        tail_dur, tail_sizes, tail_bw = 8.0, [98_304, 393_216], 4.0e8
        tail_bar = 3.0
        starved_sizes, starved_budget = [2048, 8192, 32_768, 65_536], 1024
    closed_batch = 8192

    # PAIRED tail claim cells FIRST (before this benchmark's own
    # CPU-saturating closed-loop cells disturb the box): baseline and
    # fleet-4 alternate back to back, 5 reps, and the claim compares the
    # MEDIAN of per-rep ratios.  This shared box intermittently freezes
    # the whole process for tens of ms (observed: the same cell
    # measuring 2 ms and 83 ms minutes apart); pairing cancels
    # slow-machine phases and the median drops poisoned reps.
    pairs = []
    for _ in range(5):
        gc.collect()
        b = _tail_cell(None, tail_dur, tail_sizes, tail_bw,
                       rate_ops=400.0, batch=128, read_every=8)
        gc.collect()
        f = _tail_cell(4, tail_dur, tail_sizes, tail_bw,
                       rate_ops=400.0, batch=128, read_every=8)
        pairs.append((f["writer"]["p99"] / max(b["writer"]["p99"], 1e-9),
                      b, f))
    pairs.sort(key=lambda p: p[0])
    tail_ratio, baseline, fleet4 = pairs[len(pairs) // 2]
    tails = [fleet4 if n == 4 else
             _tail_cell(n, tail_dur, tail_sizes, tail_bw, rate_ops=400.0,
                        batch=128, read_every=8) for n in shard_counts]

    closed = [_closed_loop(n, burst_dur, closed_batch, burst_bw)
              for n in shard_counts]
    tput = {c["shards"]: c["puts_per_s"] for c in closed}
    sustained = [_closed_loop(n, sustained_dur, closed_batch, sustained_bw)
                 for n in (1, 4)]
    sus = {c["shards"]: c["puts_per_s"] for c in sustained}

    starved = {p: _starved_cell(p, starved_sizes, starved_budget)
               for p in ("fair", "greedy")}
    diff_ok = _mini_differential()

    out = {"closed_loop_burst": closed, "closed_loop_sustained": sustained,
           "open_loop_baseline": baseline, "open_loop": tails,
           "starved_budget": starved, "tput_bar": tput_bar,
           "tail_bar": tail_bar,
           "cpu_count": len(os.sched_getaffinity(0)), "claims": {}}
    out["claims"]["burst_window_4shard_admits_2x_single"] = \
        tput.get(4, 0.0) >= tput_bar * tput[1]
    out["claims"]["sustained_tput_budget_bound_not_shard_bound"] = \
        0.75 * sus[1] <= sus[4] <= 1.35 * sus[1]
    out["tail_ratio_median"] = tail_ratio
    out["claims"]["open_loop_writer_p99_within_bar_of_single"] = \
        tail_ratio <= tail_bar
    out["claims"]["fleet_ran_background"] = all(
        c["flushes"] > 0 for c in closed)
    out["claims"]["greedy_drains_first_shard_before_fair"] = \
        starved["greedy"]["first_drain_epoch"] < \
        starved["fair"]["first_drain_epoch"]
    out["claims"]["fair_spreads_grants_across_more_shards"] = \
        starved["fair"]["mean_shards_granted_per_epoch"] > \
        starved["greedy"]["mean_shards_granted_per_epoch"]
    out["claims"]["fleet_single_differential_ok"] = diff_ok
    save("fleet_scaling", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    res = run(quick=ap.parse_args().quick)
    for c in res["closed_loop_burst"]:
        print(f"[fleet] burst-window {c['shards']:2d} shards: "
              f"{c['puts_per_s']:10.0f} puts/s  ({c['flushes']} flushes, "
              f"{c['merges']} merges, {c['stalls']} stalls)")
    for c in res["closed_loop_sustained"]:
        print(f"[fleet] sustained    {c['shards']:2d} shards: "
              f"{c['puts_per_s']:10.0f} puts/s  ({c['flushes']} flushes, "
              f"{c['merges']} merges)")
    b = res["open_loop_baseline"]
    print(f"[fleet] open-loop baseline (engine): writer p99 = "
          f"{b['writer']['p99']*1e3:8.2f} ms  p999 = "
          f"{b['writer']['p999']*1e3:8.2f} ms  reader p99 = "
          f"{b['reader']['p99']*1e3:8.2f} ms")
    for t in res["open_loop"]:
        w, r = t["writer"], t["reader"]
        print(f"[fleet] open-loop {t['shards']:2d} shards: writer p99 = "
              f"{w['p99']*1e3:8.2f} ms  p999 = {w['p999']*1e3:8.2f} ms  "
              f"reader p99 = {r['p99']*1e3:8.2f} ms")
    for p, s in res["starved_budget"].items():
        print(f"[fleet] starved {p:6s}: first drain @ epoch "
              f"{s['first_drain_epoch']:4d}, last @ "
              f"{s['last_drain_epoch']:4d}, mean shards granted/epoch "
              f"{s['mean_shards_granted_per_epoch']:.2f}")
    print(json.dumps(res["claims"], indent=1))
    raise SystemExit(0 if all(res["claims"].values()) else 1)
