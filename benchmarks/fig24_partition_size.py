"""Figure 24: partition (file) size sweep — throughput is flat, but
large partitions turn partitioned merges back into full merges and the
single-threaded scheduler's p99 blows up."""
from __future__ import annotations

from repro.core.twophase import run_two_phase

from .common import MEMTABLE, UNIQUE, durations, make_system, save


def run(quick: bool = False) -> dict:
    test_s, run_s, warm = durations(quick)
    # file sizes from memtable/16 up to ~unique/4 (=> full-merge regime)
    sizes = [MEMTABLE / 16, MEMTABLE, UNIQUE / 16] if quick else \
        [MEMTABLE / 16, MEMTABLE / 2, MEMTABLE, MEMTABLE * 8, UNIQUE / 16,
         UNIQUE / 4]
    tps, p99s = [], []
    for fe in sizes:
        res = run_two_phase(
            testing_system=make_system(
                "partitioned", "single", size_ratio=10, constraint="l0",
                file_entries=fe, l1_capacity=MEMTABLE * 20,
                l0_merge_all=False),
            running_system=make_system(
                "partitioned", "single", size_ratio=10, constraint="l0",
                file_entries=fe, l1_capacity=MEMTABLE * 20,
                l0_merge_all=True),
            testing_duration=test_s, running_duration=run_s, warmup=warm)
        tps.append(res.max_throughput)
        p99s.append(res.write_latencies[99])
    out = {
        "file_entries": [float(s) for s in sizes],
        "max_throughput": tps,
        "write_p99_s": p99s,
        "claims": {
            "throughput_insensitive_to_partition_size":
                max(tps) < 1.5 * min(tps),
            "large_partitions_cause_stalls": p99s[-1] > 5 * max(p99s[0],
                                                                0.2),
        },
    }
    save("fig24_partition_size", out)
    return out
